"""Cluster-simulator throughput: the columnar vectorized engine vs the
per-event Python oracle, plus the 1M-app fleet point.

The per-event oracle (``repro.serving.cluster_sim``) replays one merged
event stream through per-worker warm pools — exact, but every event pays a
Python dict walk over the pool. The columnar engine
(``repro.serving.cluster_vector``) computes the identical trajectory in
three array passes over an ``AppTable``. This benchmark measures both on
the same 100k-app azure_like fleet — once with an infinite HBM budget and
once oversubscribed (per-worker budget of a few model images, so ~17% of
events trigger the fixed-point eviction replay) — asserts the trajectories
agree *bit-for-bit* including per-worker eviction counters before claiming
any speedup (the conformance contract), and records the 1M-app vector-only
fleet run the paper-scale analysis needs.

Results go to ``BENCH_cluster_sim.json`` (repo root); the canonical
records are the 100k-app points (target: >= 20x event throughput, in the
eviction regime too). Reduced/--smoke runs never clobber it.

  PYTHONPATH=src python -m benchmarks.cluster_sim [--smoke] [--apps N]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import numpy as np

from repro.core.experiment import HybridSpec
from repro.core.workload_spec import azure_like
from repro.serving.apptable import AppTable
from repro.serving.cluster_vector import ClusterSpec, run_cluster

# Anchored to the repo root (not the CWD) so re-records always update the
# tracked file.
JSON_PATH = os.environ.get(
    "BENCH_CLUSTER_SIM_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_cluster_sim.json"))

DAYS = 0.5
MAX_EVENTS = 6
FLEET_APPS = 1_000_000
# Oversubscribed budget: this many copies of the fleet's largest model
# image per worker (8 x ~13 GB puts ~17% of the 100k-app fleet's events
# into the eviction path while per-worker assigned bytes run ~3x over).
EVICTION_BUDGET_IMAGES = 8

_COUNTERS = ("cold_starts", "warm_starts", "prewarms", "unloads",
             "evictions", "budget_overflows", "bytes_moved")


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, time.perf_counter() - t0


def _assert_bit_equal(vec, sca):
    np.testing.assert_array_equal(vec.cold_pct_per_app, sca.cold_pct_per_app)
    np.testing.assert_array_equal(vec.latencies_s, sca.latencies_s)
    np.testing.assert_allclose(vec.wasted_gb_minutes, sca.wasted_gb_minutes,
                               rtol=1e-9)
    for w, (sv, ss) in enumerate(zip(vec.stats_per_worker,
                                     sca.stats_per_worker)):
        for key in _COUNTERS:
            assert sv[key] == ss[key], f"worker {w} {key}: " \
                                       f"{sv[key]} != {ss[key]}"


def run(n_apps: int = 100_000, smoke: bool = False):
    n_workers = 64
    if smoke:
        n_apps, n_workers = 2_000, 16
    full_scale = n_apps >= 100_000

    policy = HybridSpec(use_arima=False)
    cluster = ClusterSpec(n_workers=n_workers,
                          hbm_budget_bytes=float("inf"))
    spec = azure_like(n_apps, days=DAYS, seed=17, max_events=MAX_EVENTS)
    table, t_table = _timed(lambda: AppTable.from_spec(spec))
    n_events = table.n_events

    vec, t_vec0 = _timed(
        lambda: run_cluster(table, policy, cluster, engine="vector"))
    _, t_vec = _timed(
        lambda: run_cluster(table, policy, cluster, engine="vector"))
    t_vec = min(t_vec0, t_vec)                   # steady state, but fair
    sca, t_sca = _timed(
        lambda: run_cluster(table, policy, cluster, engine="scalar"))

    # Conformance before any throughput number: the engines must agree
    # bit-for-bit on the trajectory they are being timed on.
    _assert_bit_equal(vec, sca)

    speedup = t_sca / t_vec
    rows = [
        (f"cluster_vector_{n_apps}apps_seconds", t_vec, ""),
        (f"cluster_oracle_{n_apps}apps_seconds", t_sca, ""),
        ("cluster_vector_events_per_sec", n_events / t_vec, ""),
        ("cluster_oracle_events_per_sec", n_events / t_sca, ""),
        ("cluster_vector_over_oracle_speedup", speedup, ""),
        ("cluster_table_build_seconds", t_table, ""),
    ]
    record = {
        "scenario": spec.name,
        "n_apps": n_apps, "n_workers": n_workers,
        "days": DAYS, "max_events": MAX_EVENTS,
        "n_events": int(n_events),
        "policy": "hybrid(arima=off)",
        "vector_seconds": t_vec,
        "oracle_seconds": t_sca,
        "vector_events_per_sec": n_events / t_vec,
        "oracle_events_per_sec": n_events / t_sca,
        "vector_over_oracle_speedup": speedup,
        "table_build_seconds": t_table,
        "conformance": "bit-exact (cold %, latencies; wasted rtol 1e-9)",
        "meta": {
            "platform": platform.platform(),
            "numpy": np.__version__,
            "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    }

    # --- eviction regime: same fleet, per-worker HBM budget of a few
    # images, so the fixed-point eviction replay is on the timed path.
    # Smoke fleets are small enough that 8 images rarely collide; 2 keeps
    # the CI case genuinely oversubscribed (thousands of evictions).
    ev_images = 2 if smoke else EVICTION_BUDGET_IMAGES
    ev_budget = float(table.weight_bytes.max()) * ev_images
    ev_cluster = ClusterSpec(n_workers=n_workers, hbm_budget_bytes=ev_budget)
    evec, t_evec0 = _timed(
        lambda: run_cluster(table, policy, ev_cluster, engine="vector"))
    _, t_evec = _timed(
        lambda: run_cluster(table, policy, ev_cluster, engine="vector"))
    t_evec = min(t_evec0, t_evec)
    esca, t_esca = _timed(
        lambda: run_cluster(table, policy, ev_cluster, engine="scalar"))
    _assert_bit_equal(evec, esca)
    n_evictions = evec.evictions
    ev_speedup = t_esca / t_evec
    rows += [
        (f"cluster_evict_vector_{n_apps}apps_seconds", t_evec, ""),
        (f"cluster_evict_oracle_{n_apps}apps_seconds", t_esca, ""),
        ("cluster_evict_vector_events_per_sec", n_events / t_evec, ""),
        ("cluster_evict_oracle_events_per_sec", n_events / t_esca, ""),
        ("cluster_evict_vector_over_oracle_speedup", ev_speedup, ""),
        ("cluster_evict_evictions", float(n_evictions), ""),
    ]
    assert n_evictions > 0, "eviction benchmark saw no evictions"
    record["eviction_regime"] = {
        "hbm_budget_bytes": ev_budget,
        "budget_images": ev_images,
        "evictions": int(n_evictions),
        "eviction_event_pct": 100.0 * n_evictions / max(n_events, 1),
        "vector_seconds": t_evec,
        "oracle_seconds": t_esca,
        "vector_events_per_sec": n_events / t_evec,
        "oracle_events_per_sec": n_events / t_esca,
        "vector_over_oracle_speedup": ev_speedup,
        "conformance": "bit-exact incl. per-worker eviction counters",
    }

    if full_scale:
        assert speedup >= 20.0, (
            f"vectorized cluster engine only {speedup:.1f}x over the "
            f"per-event oracle at {n_apps} apps (target: >= 20x)")
        assert ev_speedup >= 20.0, (
            f"vectorized cluster engine only {ev_speedup:.1f}x over the "
            f"per-event oracle in the eviction regime at {n_apps} apps "
            f"(target: >= 20x)")
        # The fleet point the oracle cannot reach: 1M apps, vector only.
        fspec = azure_like(FLEET_APPS, days=DAYS, seed=17,
                           max_events=MAX_EVENTS)
        ftable, t_ftable = _timed(lambda: AppTable.from_spec(fspec))
        fcluster = ClusterSpec(n_workers=1024,
                               hbm_budget_bytes=float("inf"))
        _, t_fleet = _timed(
            lambda: run_cluster(ftable, policy, fcluster, engine="vector"))
        rows += [
            (f"cluster_fleet_{FLEET_APPS}apps_seconds", t_fleet, ""),
            ("cluster_fleet_events_per_sec",
             ftable.n_events / t_fleet, ""),
        ]
        record["fleet"] = {
            "n_apps": FLEET_APPS, "n_workers": 1024,
            "n_events": int(ftable.n_events),
            "table_build_seconds": t_ftable,
            "vector_seconds": t_fleet,
            "vector_events_per_sec": ftable.n_events / t_fleet,
        }

    # Only full-scale runs (or explicit env-var targets) touch the tracked
    # record: reduced/smoke invocations must not clobber the canonical
    # 100k-app measurement.
    if full_scale or "BENCH_CLUSTER_SIM_JSON" in os.environ:
        try:
            with open(JSON_PATH, "w") as f:
                json.dump(record, f, indent=2, sort_keys=True)
                f.write("\n")
        except OSError as e:
            print(f"# WARNING: could not record {JSON_PATH}: {e}",
                  file=sys.stderr)
    else:
        print(f"# reduced run: not recording {JSON_PATH}", file=sys.stderr)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fleet (CI): exercises both engines — the "
                         "oversubscribed eviction regime included — and "
                         "the conformance asserts, not the throughput "
                         "claim")
    ap.add_argument("--apps", type=int, default=100_000)
    args = ap.parse_args()
    for key, value, ref in run(n_apps=args.apps, smoke=args.smoke):
        v = f"{value:.6g}" if isinstance(value, float) else value
        print(f"{key},{v},{ref}")


if __name__ == "__main__":
    main()
