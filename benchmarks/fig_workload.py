"""Figures 1/2/5/6: workload-generator marginals vs the paper's anchors,
plus the scenario library's regime statistics (the trace axis of
``sweep(traces=..., specs=...)``)."""
from __future__ import annotations

import numpy as np

from repro.core.workload import generate_trace, sample_apps
from repro.core.workload_spec import SCENARIOS


def run(n_apps: int = 3000, seed: int = 0):
    rows = []
    specs = sample_apps(n_apps, seed)

    # Fig 1: functions per app
    nf = np.array([s.n_functions for s in specs])
    rows.append(("fig1_frac_single_function", float(np.mean(nf == 1)), 0.54))
    rows.append(("fig1_frac_le_10_functions", float(np.mean(nf <= 10)), 0.95))

    # Fig 3a: trigger shares
    http = np.mean([("http" in s.triggers) for s in specs])
    timer = np.mean([("timer" in s.triggers) for s in specs])
    rows.append(("fig3_frac_apps_with_http", float(http), 0.6407))
    rows.append(("fig3_frac_apps_with_timer", float(timer), 0.2915))

    # Fig 5a: invocation-rate CDF anchors
    rates = np.array([s.rate_per_day for s in specs])
    rows.append(("fig5_frac_le_1_per_hour", float(np.mean(rates <= 24)), 0.45))
    rows.append(("fig5_frac_le_1_per_min", float(np.mean(rates <= 1440)), 0.81))
    rows.append(("fig5_orders_of_magnitude",
                 float(np.log10(rates.max() / rates.min())), 8.0))

    # Fig 5b: skew — top 18.6% of apps account for ~99.6% of invocations
    tr = generate_trace(600, days=2.0, seed=seed)
    counts = np.array([len(t) for t in tr.times], float)
    # measured rates are capped at 1/min (dataset granularity);
    # use spec rates for the skew calculation
    srates = np.array([s.rate_per_day for s in tr.specs])
    order = np.argsort(-srates)
    top = int(0.186 * len(srates))
    share = srates[order[:top]].sum() / srates.sum()
    rows.append(("fig5b_top18.6pct_invocation_share", float(share), 0.996))

    # Fig 6: CV classes
    cvs = []
    for i in range(tr.n_apps):
        ia = tr.iats(i)
        if len(ia) >= 5:
            cvs.append(np.std(ia) / max(np.mean(ia), 1e-9))
    cvs = np.array(cvs)
    rows.append(("fig6_frac_cv_near_0", float(np.mean(cvs < 0.1)), 0.20))
    rows.append(("fig6_frac_cv_gt_1", float(np.mean(cvs > 1.0)), 0.40))

    # Scenario library: per-regime CV mix and event mass from the one
    # vectorized engine (each of these is a trace-axis point for sweep()).
    for name in sorted(SCENARIOS):
        spec = SCENARIOS[name](400, days=2.0, seed=seed, max_events=48)
        t = spec.materialize()
        scvs = []
        for i in range(t.n_apps):
            ia = t.iats(i)
            if len(ia) >= 5:
                scvs.append(np.std(ia) / max(np.mean(ia), 1e-9))
        scvs = np.asarray(scvs) if scvs else np.zeros(1)
        _, cnt = t.to_padded()
        rows.append((f"scenario_{name}_frac_cv_gt_1",
                     float(np.mean(scvs > 1.0)), ""))
        rows.append((f"scenario_{name}_mean_events_per_app",
                     float(cnt.mean()), ""))
    return rows
