"""Device-count scaling curve for the sharded sweep engine.

The app axis is embarrassingly parallel (every app simulates
independently), so ``EngineOptions(devices=...)`` partitions each chunk's
app rows across a 1-D mesh via shard_map — results bit-identical to the
single-device run (asserted here before any number is reported). This
benchmark records how the 32-config hybrid sweep (the same grid as
``benchmarks/policy_sweep``) scales with device count.

XLA only honours ``--xla_force_host_platform_device_count`` when it is set
before the first jax import, so the measurement runs in a child process
(``--measure``) with ``XLA_FLAGS`` forced to 8 host devices; the parent
parses the child's JSON and records ``BENCH_scaleout.json`` (repo root) on
full runs.

Read the curve with the host in mind: forced host devices on CPU are
threads of the SAME physical machine sharing one XLA intra-op thread pool,
so on a box with few physical cores the curve measures sharding overhead
(it should stay flat near 1.0x), not parallel speedup. The per-device
speedup claim transfers to real multi-device hosts (one accelerator per
mesh slot); the bit-identity claim is host-independent.

  PYTHONPATH=src python -m benchmarks.scaleout [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Anchored to the repo root (not the CWD) so re-records always update the
# tracked file.
JSON_PATH = os.environ.get(
    "BENCH_SCALEOUT_JSON", os.path.join(REPO_ROOT, "BENCH_scaleout.json"))

DEVICE_COUNTS = (1, 2, 4, 8)
SENTINEL = "SCALEOUT-RESULT:"


def measure(n_apps: int, days: float, max_events: int) -> dict:
    """Child-process body: build the sweep once per device count, assert
    bit-identity against the unsharded run, time warm repeats."""
    import platform

    import jax
    import numpy as np

    from benchmarks.policy_sweep import make_grid
    from repro.core.experiment import EngineOptions, sweep
    from repro.core.workload_spec import WorkloadSpec

    assert jax.device_count() >= max(DEVICE_COUNTS), (
        f"child expected forced host devices, found {jax.device_count()}")

    grid = make_grid()
    trace = WorkloadSpec.uniform(n_apps, days=days, seed=3,
                                 max_events=max_events,
                                 min_events=1).materialize()
    trace.to_padded()             # shared trace construction out of the bill

    def timed_warm(opts):
        res = sweep(trace, grid, engine="fused", options=opts)   # cold
        t0 = time.perf_counter()
        sweep(trace, grid, engine="fused", options=opts)         # warm
        return res, time.perf_counter() - t0

    base, t_base = timed_warm(EngineOptions())
    points = {}
    for d in DEVICE_COUNTS:
        res, t = timed_warm(EngineOptions(devices=d))
        # bit-identity before any throughput number
        np.testing.assert_array_equal(base.cold, res.cold)
        np.testing.assert_array_equal(base.wasted_minutes,
                                      res.wasted_minutes)
        np.testing.assert_array_equal(base.final_prewarm, res.final_prewarm)
        np.testing.assert_array_equal(base.final_keep_alive,
                                      res.final_keep_alive)
        points[d] = t

    return {
        "grid_size": len(grid),
        "n_apps": n_apps, "days": days, "max_events": max_events,
        "timing": "warm second call per device count (steady state)",
        "unsharded_seconds": t_base,
        "warm_seconds_by_devices": {str(d): points[d]
                                    for d in DEVICE_COUNTS},
        "speedup_vs_1_device": {str(d): points[1] / points[d]
                                for d in DEVICE_COUNTS},
        "bit_identical_to_unsharded": True,
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "physical_cpus": os.cpu_count(),
            "platform": platform.platform(),
            "jax": jax.__version__,
            "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    }


def _spawn_child(smoke: bool) -> dict:
    env = dict(os.environ)
    flags = [t for t in env.get("XLA_FLAGS", "").split()
             if not t.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        flags + [f"--xla_force_host_platform_device_count="
                 f"{max(DEVICE_COUNTS)}"])
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO_ROOT, "src"),
         *filter(None, [env.get("PYTHONPATH")])])
    cmd = [sys.executable, "-m", "benchmarks.scaleout", "--measure"]
    if smoke:
        cmd.append("--smoke")
    out = subprocess.run(cmd, env=env, cwd=REPO_ROOT, capture_output=True,
                         text=True)
    if out.returncode != 0:
        raise RuntimeError(f"scaleout child failed:\n{out.stdout}\n"
                           f"{out.stderr}")
    for line in out.stdout.splitlines():
        if line.startswith(SENTINEL):
            return json.loads(line[len(SENTINEL):])
    raise RuntimeError(f"scaleout child printed no result:\n{out.stdout}")


def run(smoke: bool = False):
    record = _spawn_child(smoke)
    points = record["warm_seconds_by_devices"]
    speed = record["speedup_vs_1_device"]
    rows = [(f"scaleout_warm_seconds_{d}dev", points[str(d)], "")
            for d in DEVICE_COUNTS]
    rows += [(f"scaleout_speedup_{d}dev_vs_1dev", speed[str(d)], "")
             for d in DEVICE_COUNTS if d > 1]
    rows.append(("scaleout_bit_identical",
                 int(record["bit_identical_to_unsharded"]), ""))
    # The honest reading of a forced-host-device curve (see module
    # docstring): flat ≈ sharding costs nothing; >1 would need real cores.
    record["note"] = (
        "Forced host devices are threads of one machine "
        f"(physical_cpus={record['meta']['physical_cpus']}), so this curve "
        "measures sharding overhead, not parallel speedup: devices=1 "
        "matching the unsharded time shows the shard_map machinery itself "
        "costs ~nothing, while counts >1 contend for the same cores and "
        "pay per-shard executable dispatch, so wall-clock stays flat or "
        "degrades when forced devices outnumber physical cores. The "
        "per-device win requires real multi-accelerator hosts (one "
        "accelerator per mesh slot). Bit-identity to the unsharded run is "
        "asserted before timing and is host-independent.")
    if not smoke or "BENCH_SCALEOUT_JSON" in os.environ:
        try:
            with open(JSON_PATH, "w") as f:
                json.dump(record, f, indent=2, sort_keys=True)
                f.write("\n")
        except OSError as e:
            print(f"# WARNING: could not record {JSON_PATH}: {e}",
                  file=sys.stderr)
    else:
        print(f"# smoke run: not recording {JSON_PATH}", file=sys.stderr)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI): exercises the paths, not the "
                         "scaling claim")
    ap.add_argument("--measure", action="store_true",
                    help="internal: run the measurement in THIS process "
                         "(expects forced host devices already in "
                         "XLA_FLAGS)")
    args = ap.parse_args()
    if args.measure:
        size = ((2_000, 2.0, 16) if args.smoke
                else (100_000, 14.0, 64))
        print(SENTINEL + json.dumps(measure(*size)))
        return
    for key, value, ref in run(smoke=args.smoke):
        v = f"{value:.6g}" if isinstance(value, float) else value
        print(f"{key},{v},{ref}")


if __name__ == "__main__":
    main()
