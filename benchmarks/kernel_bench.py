"""Kernel micro-benchmarks (CPU: the jnp oracle path gives meaningful
relative numbers; the Pallas interpret path is correctness-only)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _bench(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    r = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)

    B, S, Hq, Hkv, D = 1, 1024, 8, 2, 64
    q, k, v = r(B, Hq, S, D), r(B, Hkv, S, D), r(B, Hkv, S, D)
    fn = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    rows.append(("kernel_attention_ref_1k_us", _bench(fn, q, k, v), ""))

    qd, kc, vc = r(B, Hkv, 4, D), r(B, Hkv, 8192, D), r(B, Hkv, 8192, D)
    fn = jax.jit(lambda q, k, v: ref.decode_attention_ref(q, k, v, jnp.int32(8000)))
    rows.append(("kernel_decode_ref_8k_us", _bench(fn, qd, kc, vc), ""))

    b, l, h, p, n = 1, 1024, 8, 64, 64
    x, dt = r(b, l, h, p), jnp.abs(r(b, l, h)) * 0.1
    A = -jnp.abs(r(h))
    Bm, Cm = r(b, l, n), r(b, l, n)
    fn = jax.jit(lambda x, dt, A, Bm, Cm: ref.ssd_ref(x, dt, A, Bm, Cm, 128)[0])
    rows.append(("kernel_ssd_ref_1k_us", _bench(fn, x, dt, A, Bm, Cm), ""))

    a = jax.nn.sigmoid(r(2, 1024, 512)) * 0.98
    bi = r(2, 1024, 512)
    fn = jax.jit(lambda b_, a_: ref.rglru_ref(b_, a_)[0])
    rows.append(("kernel_rglru_ref_1k_us", _bench(fn, bi, a), ""))
    return rows
