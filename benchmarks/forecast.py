"""Batched ARIMA fit throughput vs the legacy per-app scipy loop.

The paper (Sec. 5.2) reports ~27 ms for the initial pmdarima fit of one
application. The legacy post-pass paid that price app-by-app in a Python
loop; the batched grid fit (``repro.forecast.arima_batched``) runs the
whole OOB cohort through one vmapped program. This benchmark:

  * first asserts the *conformance gate*: on a long-period-timer trace
    (every IT beyond the histogram range, so the ARIMA path governs),
    the fused engine's cold counts and final windows are bit-identical
    to the scalar per-event oracle — throughput claims mean nothing if
    the batched path drifted;
  * then times ``fit_arima_grid`` on ~10k OOB-app windows (steady-state,
    after one warm-up call on the same bucket shapes) against the scalar
    scipy auto-fit loop, sampled and extrapolated (17 Nelder-Mead fits
    per app makes the full 10k-loop a half-hour affair — exactly the
    point). The acceptance bar is a >= 10x speedup.

scipy is optional (dev-only dependency): without it the baseline rows
are skipped and only the batched throughput is recorded.

Results go to ``BENCH_forecast.json`` (repo root). ``--smoke`` runs the
conformance gate plus a tiny timing pass and never clobbers the record.

  PYTHONPATH=src python -m benchmarks.forecast [--smoke] [--apps N]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import platform
import sys
import time

import numpy as np

from repro.core.experiment import HybridSpec, run as run_experiment
from repro.core.policy import HybridConfig, HybridHistogramPolicy
from repro.core.simulator import simulate_scalar
from repro.core.workload import Trace
from repro.forecast import MAX_OBS, ORDER_GRID, fit_arima_grid

JSON_PATH = os.environ.get(
    "BENCH_FORECAST_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_forecast.json"))

FULL_APPS = 10_240
SCIPY_SAMPLE = 24


def _oob_timer_trace(n_apps=40, days=3, seed=5):
    """Long-period timers: periods past the 240-minute histogram range,
    so every inter-arrival is OOB and the hybrid's ARIMA path governs."""
    rng = np.random.default_rng(seed)
    duration = days * 24 * 60.0
    periods = rng.uniform(280.0, 420.0, n_apps)
    times = []
    for i in range(n_apps):
        phase = rng.uniform(0.0, periods[i])
        t = np.arange(phase, duration, periods[i])
        t = t + rng.normal(0.0, 0.5, t.shape)
        times.append(np.sort(np.clip(t, 0.0, duration - 1e-6)))
    return Trace(specs=None, times=times, duration_minutes=duration)


def _parity_gate():
    """Cold counts and windows bit-identical, fused vs scalar oracle, on
    the ARIMA-governed trace. Raises on any drift."""
    trace = _oob_timer_trace()
    spec = HybridSpec(use_arima=True)
    oracle = simulate_scalar(
        trace, HybridHistogramPolicy(HybridConfig(use_arima=True)))
    got = run_experiment(trace, spec, engine="fused")
    np.testing.assert_array_equal(got.cold, oracle.cold)
    np.testing.assert_array_equal(got.final_prewarm, oracle.final_prewarm)
    np.testing.assert_array_equal(got.final_keep_alive,
                                  oracle.final_keep_alive)
    cold_pct = 100.0 * got.cold.sum() / max(int(got.invocations.sum()), 1)
    return float(cold_pct)


def _oob_windows(n_apps: int, seed=11):
    """Synthetic OOB-app observation windows: noisy timer periods with
    ragged lengths — the shape the hybrid replay hands the grid fit."""
    rng = np.random.default_rng(seed)
    rows = np.zeros((n_apps, MAX_OBS), np.float32)
    lens = np.zeros(n_apps, np.int32)
    for i in range(n_apps):
        n = int(rng.integers(8, MAX_OBS + 1))
        period = rng.uniform(250.0, 450.0)
        rows[i, :n] = period + rng.normal(0.0, period * 0.02, n)
        lens[i] = n
    return rows, lens


def _scipy_auto_fit(y):
    """The legacy per-app cost: one Nelder-Mead CSS fit per grid order
    (what ``repro.core.arima.auto_arima`` used to run in the post-pass)."""
    from scipy import optimize

    y = np.asarray(y, float)
    best = math.inf
    for p, d, q in ORDER_GRID:
        w = np.diff(y, n=d) if d else y
        m = len(w)
        if len(y) < d + max(p, q) + 2 or m < p + q + 1:
            continue
        wc = w - np.mean(w)

        def objective(theta):
            if np.any(np.abs(theta) > 1.5):
                return 1e12
            a = np.concatenate([theta[:p], np.zeros(2 - p)])
            b = np.concatenate([theta[p:p + q], np.zeros(2 - q)])
            e = np.zeros(m)
            w1 = w2 = e1 = e2 = 0.0
            for t in range(m):
                e[t] = wc[t] - (a[0] * w1 + a[1] * w2 + b[0] * e1
                                + b[1] * e2)
                w1, w2 = wc[t], w1
                e1, e2 = e[t], e1
            return float(np.sum(e * e))

        theta = np.zeros(p + q)
        if p + q:
            theta = optimize.minimize(
                objective, theta, method="Nelder-Mead",
                options={"maxiter": 300 * (p + q),
                         "xatol": 1e-5, "fatol": 1e-8}).x
        sse = max(objective(theta), 1e-12)
        best = min(best, m * math.log(sse / m) + 2 * (p + q + 1))
    return best


def run(n_apps: int = FULL_APPS, smoke: bool = False):
    if smoke:
        n_apps = 64
    full_scale = n_apps >= FULL_APPS
    rows_out = []
    record = {"host": platform.processor() or platform.machine(),
              "n_apps": n_apps}

    cold_pct = _parity_gate()
    rows_out.append(("forecast_parity_gate_cold_pct", cold_pct, ""))
    record["parity_gate_cold_pct"] = cold_pct

    rows, lens = _oob_windows(n_apps)
    fit_arima_grid(rows, lens)           # warm-up: compile bucket shapes
    t0 = time.perf_counter()
    fit = fit_arima_grid(rows, lens)
    t_batched = time.perf_counter() - t0
    assert fit.valid.any(axis=1).all(), "unusable fits in the benchmark bank"
    batched_rate = n_apps / t_batched
    rows_out += [
        ("forecast_batched_seconds", t_batched, ""),
        ("forecast_batched_apps_per_sec", batched_rate, ""),
        ("forecast_batched_us_per_app", 1e6 * t_batched / n_apps, ""),
    ]
    record.update(batched_seconds=t_batched,
                  batched_apps_per_sec=batched_rate)

    try:
        import scipy  # noqa: F401
        have_scipy = True
    except ImportError:
        have_scipy = False
        print("# scipy unavailable: skipping the scalar-loop baseline",
              file=sys.stderr)
    if have_scipy:
        sample = min(SCIPY_SAMPLE if not smoke else 4, n_apps)
        t0 = time.perf_counter()
        for i in range(sample):
            _scipy_auto_fit(rows[i, :lens[i]])
        t_scipy = time.perf_counter() - t0
        scipy_rate = sample / t_scipy
        speedup = batched_rate / scipy_rate
        rows_out += [
            # paper: ~27 ms initial pmdarima fit per app (Sec. 5.2)
            ("forecast_scipy_ms_per_app", 1e3 * t_scipy / sample, "27"),
            ("forecast_scipy_apps_per_sec_est", scipy_rate, ""),
            ("forecast_speedup_vs_scipy", speedup, ""),
        ]
        record.update(scipy_sampled_apps=sample, scipy_seconds=t_scipy,
                      scipy_apps_per_sec_est=scipy_rate, speedup=speedup)
        if full_scale:
            assert speedup >= 10.0, \
                f"batched fit only {speedup:.1f}x the scipy loop " \
                f"(acceptance bar: 10x)"

    if full_scale or "BENCH_FORECAST_JSON" in os.environ:
        try:
            with open(JSON_PATH, "w") as f:
                json.dump(record, f, indent=2, sort_keys=True)
                f.write("\n")
        except OSError as e:
            print(f"# WARNING: could not record {JSON_PATH}: {e}",
                  file=sys.stderr)
    else:
        print(f"# reduced run: not recording {JSON_PATH}", file=sys.stderr)
    return rows_out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="conformance gate + tiny timing pass (CI); does "
                         "not record the tracked JSON")
    ap.add_argument("--apps", type=int, default=FULL_APPS)
    args = ap.parse_args()
    for key, value, ref in run(n_apps=args.apps, smoke=args.smoke):
        v = f"{value:.6g}" if isinstance(value, float) else value
        print(f"{key},{v},{ref}")


if __name__ == "__main__":
    main()
