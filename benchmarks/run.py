"""Benchmark harness — one module per paper table/figure.

Prints ``name,value,paper_reference`` CSV rows (paper_reference empty when
the paper gives no number for that quantity).

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run --only fig_policy
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names to run")
    ap.add_argument("--apps", type=int, default=800,
                    help="trace size for the policy figures")
    args = ap.parse_args()

    from . import (cluster_sim, fig_cluster, fig_exec_mem, fig_policy,
                   fig_workload, forecast, kernel_bench, policy_overhead,
                   policy_sweep, roofline, scaleout, trace_gen)
    modules = {
        "fig_workload": lambda: fig_workload.run(),
        "fig_exec_mem": lambda: fig_exec_mem.run(),
        "fig_policy": lambda: fig_policy.run(n_apps=args.apps),
        "fig_cluster": lambda: fig_cluster.run(),
        "cluster_sim": lambda: cluster_sim.run(),
        "policy_overhead": lambda: policy_overhead.run(),
        "policy_sweep": lambda: policy_sweep.run(),
        "scaleout": lambda: scaleout.run(),
        "trace_gen": lambda: trace_gen.run(),
        "forecast": lambda: forecast.run(),
        "kernel_bench": lambda: kernel_bench.run(),
        "roofline": lambda: roofline.run(),
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,value,paper_reference")
    failures = 0
    for name, fn in modules.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            for row in fn():
                key, value, ref = row
                v = f"{value:.6g}" if isinstance(value, float) else value
                print(f"{key},{v},{ref}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}")
        print(f"# {name} done in {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
