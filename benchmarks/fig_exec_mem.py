"""Figures 7/8: execution-time (lognormal) and memory (Burr) distributions."""
from __future__ import annotations

import numpy as np

from repro.core.workload import sample_apps


def run(n_apps: int = 20000, seed: int = 1):
    specs = sample_apps(n_apps, seed)
    execs = np.array([s.exec_time_s for s in specs])
    mem = np.array([s.memory_mb for s in specs])
    rows = [
        ("fig7_exec_median_s", float(np.median(execs)), 0.68),   # e^-0.38
        ("fig7_frac_le_1s", float(np.mean(execs <= 1.0)), 0.50),
        ("fig7_frac_le_60s", float(np.mean(execs <= 60.0)), 0.96),
        ("fig7_lognormal_logmean", float(np.mean(np.log(execs))), -0.38),
        ("fig7_lognormal_logstd", float(np.std(np.log(execs))), 2.36),
        ("fig8_mem_median_mb", float(np.median(mem)), 170.0),
        ("fig8_frac_le_400mb", float(np.mean(mem <= 400.0)), 0.90),
        ("fig8_p90_over_p10", float(np.percentile(mem, 90)
                                    / np.percentile(mem, 10)), 4.0),
    ]
    return rows
