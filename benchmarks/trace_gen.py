"""Trace-generation throughput: the vectorized WorkloadSpec engine vs the
per-app Python loop, on a pattern-faithful (azure_like, NOT uniform)
scenario.

Before this engine the repo had two generators: a §3-faithful per-app
Python loop (small traces only) and a fleet-scale path that discarded every
pattern. The spec engine materializes §3-faithful workloads directly in
padded chunked form with batched numpy sampling per cohort block — this
benchmark records how much that vectorization buys at fleet scale, with the
pre-spec per-app loop (same population and pattern semantics, one Python
iteration per app — ``workload_spec.materialize_loop``) as the baseline.

Results go to ``BENCH_trace_gen.json`` (repo root); the canonical record is
the 100k-app azure_like point (target: >= 10x). Reduced/--smoke runs never
clobber it.

  PYTHONPATH=src python -m benchmarks.trace_gen [--smoke] [--apps N]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import numpy as np

from repro.core.workload_spec import azure_like, materialize_loop

# Anchored to the repo root (not the CWD) so re-records always update the
# tracked file.
JSON_PATH = os.environ.get(
    "BENCH_TRACE_GEN_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_trace_gen.json"))


def run(n_apps: int = 100_000, days: float = 7.0, max_events: int = 64,
        smoke: bool = False):
    if smoke:
        n_apps, days, max_events = 1_500, 2.0, 16
    spec = azure_like(n_apps, days=days, seed=17, max_events=max_events)

    def timed(fn):
        t0 = time.perf_counter()
        out = fn()
        return out, time.perf_counter() - t0

    fast, t_fast0 = timed(spec.materialize)
    _, t_fast = timed(spec.materialize)          # steady state (no warmup
    t_fast = min(t_fast0, t_fast)                # effects, but be fair)
    slow, t_slow = timed(lambda: materialize_loop(spec))

    # Sanity before any throughput number: both paths produced the same
    # workload class (same shape contract, comparable event mass).
    pf, cf = fast.to_padded()
    ps, cs = slow.to_padded()
    assert cf.shape == cs.shape == (n_apps,)
    assert np.all(cf <= max_events) and np.all(cs <= max_events)
    mass_ratio = float(cf.mean() / max(cs.mean(), 1e-9))
    assert 0.6 < mass_ratio < 1.7, mass_ratio

    speedup = t_slow / t_fast
    rows = [
        (f"tracegen_vectorized_{n_apps}apps_seconds", t_fast, ""),
        (f"tracegen_python_loop_{n_apps}apps_seconds", t_slow, ""),
        ("tracegen_vectorized_apps_per_sec", n_apps / t_fast, ""),
        ("tracegen_python_loop_apps_per_sec", n_apps / t_slow, ""),
        ("tracegen_vectorized_over_loop_speedup", speedup, ""),
        ("tracegen_event_mass_ratio", mass_ratio, ""),
    ]
    record = {
        "scenario": spec.name,
        "generator": spec.generator,
        "n_apps": n_apps, "days": days, "max_events": max_events,
        "pattern_faithful": True,
        "vectorized_seconds": t_fast,
        "python_loop_seconds": t_slow,
        "vectorized_apps_per_sec": n_apps / t_fast,
        "python_loop_apps_per_sec": n_apps / t_slow,
        "vectorized_over_loop_speedup": speedup,
        "event_mass_ratio_vectorized_over_loop": mass_ratio,
        "total_events_vectorized": int(cf.sum()),
        "total_events_python_loop": int(cs.sum()),
        "meta": {
            "platform": platform.platform(),
            "numpy": np.__version__,
            "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    }
    # Only full-scale runs (or explicit env-var targets) touch the tracked
    # record: reduced/smoke invocations must not clobber the canonical
    # 100k-app measurement.
    if n_apps >= 100_000 or "BENCH_TRACE_GEN_JSON" in os.environ:
        try:
            with open(JSON_PATH, "w") as f:
                json.dump(record, f, indent=2, sort_keys=True)
                f.write("\n")
        except OSError as e:
            print(f"# WARNING: could not record {JSON_PATH}: {e}",
                  file=sys.stderr)
    else:
        print(f"# reduced run: not recording {JSON_PATH}", file=sys.stderr)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI): exercises the paths, not the "
                         "throughput claim")
    ap.add_argument("--apps", type=int, default=100_000)
    args = ap.parse_args()
    for key, value, ref in run(n_apps=args.apps, smoke=args.smoke):
        v = f"{value:.6g}" if isinstance(value, float) else value
        print(f"{key},{v},{ref}")


if __name__ == "__main__":
    main()
