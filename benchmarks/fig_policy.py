"""Figures 14-18: the policy evaluation (the paper's core results).

All simulations share one 7-day synthetic trace generated from the paper's
published distributions, and each figure is a declarative spec grid over
``experiment.sweep`` — the whole figure's configurations are evaluated in
one vectorized pass. Wasted memory is normalized to the 10-minute fixed
keep-alive policy, exactly like Figure 15.
"""
from __future__ import annotations

import numpy as np

from repro.core import generate_trace
from repro.core.experiment import FixedSpec, HybridSpec, NoUnloadSpec, sweep

_TRACE_CACHE = {}

FIXED_KAS = (10, 20, 30, 60, 120, 240)


def get_trace(n_apps=800, days=7.0, seed=42):
    key = (n_apps, days, seed)
    if key not in _TRACE_CACHE:
        _TRACE_CACHE[key] = generate_trace(n_apps, days=days, seed=seed)
    return _TRACE_CACHE[key]


def run(n_apps: int = 800, seed: int = 42):
    trace = get_trace(n_apps, seed=seed)
    rows = []

    # --- Fig 14: fixed keep-alive sweep --------------------------------------
    fig14 = sweep(trace, [FixedSpec(float(ka)) for ka in FIXED_KAS]
                  + [NoUnloadSpec()])
    fixed = {ka: fig14.row(i) for i, ka in enumerate(FIXED_KAS)}
    nou = fig14.row(len(FIXED_KAS))
    for ka in FIXED_KAS:
        rows.append((f"fig14_fixed_{ka}m_cold_p75",
                     fixed[ka].cold_pct_percentile(75),
                     {10: 50.3, 60: 25.0}.get(ka, "")))
    rows.append(("fig14_no_unloading_always_cold_pct",
                 100.0 * nou.always_cold_fraction, 3.5))

    base_waste = fixed[10].total_wasted

    # --- Fig 15: hybrid Pareto vs fixed ---------------------------------------
    ranges = (60, 120, 240, 480)
    fig15 = sweep(trace, [HybridSpec(range_minutes=float(r), use_arima=False)
                          for r in ranges])
    hybrids = {r: fig15.row(i) for i, r in enumerate(ranges)}
    for rng_min, res in hybrids.items():
        rows.append((f"fig15_hybrid_{rng_min}m_cold_p75",
                     res.cold_pct_percentile(75), ""))
        rows.append((f"fig15_hybrid_{rng_min}m_rel_waste",
                     res.total_wasted / base_waste, ""))
    for ka, res in fixed.items():
        rows.append((f"fig15_fixed_{ka}m_rel_waste",
                     res.total_wasted / base_waste, ""))
    # headline: cold-start ratio at matched memory (paper: ~2.5x at 4h range)
    h4 = hybrids[240]
    rows.append(("fig15_fixed10_over_hybrid4h_cold_ratio",
                 fixed[10].cold_pct_percentile(75)
                 / max(h4.cold_pct_percentile(75), 1e-9), 2.5))
    rows.append(("fig15_hybrid4h_rel_waste_vs_fixed10",
                 h4.total_wasted / base_waste, 1.0))
    # paper: fixed-2h costs ~1.5x the memory of hybrid-4h at similar colds
    rows.append(("fig15_fixed120_waste_over_hybrid4h",
                 fixed[120].total_wasted / h4.total_wasted, 1.5))

    # --- Fig 16: cutoff percentiles -------------------------------------------
    fig16 = sweep(trace, [
        HybridSpec(head_percentile=5, tail_percentile=99, use_arima=False),
        HybridSpec(head_percentile=0, tail_percentile=100, use_arima=False),
    ])
    cut, nocut = fig16.row(0), fig16.row(1)
    rows.append(("fig16_waste_saving_5_99_vs_0_100_pct",
                 100.0 * (1 - cut.total_wasted / nocut.total_wasted), 15.0))
    rows.append(("fig16_cold_p75_5_99", cut.cold_pct_percentile(75), ""))
    rows.append(("fig16_cold_p75_0_100", nocut.cold_pct_percentile(75), ""))

    # --- Fig 17: CV threshold ---------------------------------------------------
    cv_ts = (0.0, 1.0, 2.0, 4.0)
    fig17 = sweep(trace, [HybridSpec(cv_threshold=cv_t, use_arima=False)
                          for cv_t in cv_ts])
    for i, cv_t in enumerate(cv_ts):
        res = fig17.row(i)
        rows.append((f"fig17_cv{cv_t:g}_cold_p75",
                     res.cold_pct_percentile(75), ""))
        rows.append((f"fig17_cv{cv_t:g}_rel_waste",
                     res.total_wasted / base_waste, ""))

    # --- Fig 18: ARIMA impact on always-cold apps ------------------------------
    fig18 = sweep(trace, [HybridSpec(use_arima=False),
                          HybridSpec(use_arima=True)])
    no_arima, with_arima = fig18.row(0), fig18.row(1)
    multi = np.asarray(no_arima.invocations) > 1
    rows.append(("fig18_always_cold_pct_fixed240",
                 100.0 * fixed[240].always_cold_fraction, ""))
    rows.append(("fig18_always_cold_pct_hybrid_noarima",
                 100.0 * no_arima.always_cold_fraction, 10.5))
    rows.append(("fig18_always_cold_pct_hybrid_arima",
                 100.0 * with_arima.always_cold_fraction, 5.2))
    nz = lambda r: float(np.mean((r.cold >= r.invocations)[multi]))
    rows.append(("fig18_always_cold_excl_single_noarima",
                 100.0 * nz(no_arima), 6.9))
    rows.append(("fig18_always_cold_excl_single_arima",
                 100.0 * nz(with_arima), 1.7))
    return rows
