"""Figures 14-18: the policy evaluation (the paper's core results).

All simulations share one 7-day synthetic trace generated from the paper's
published distributions. Wasted memory is normalized to the 10-minute fixed
keep-alive policy, exactly like Figure 15.
"""
from __future__ import annotations

import numpy as np

from repro.core import (FixedKeepAlivePolicy, HybridConfig, NoUnloadingPolicy,
                        generate_trace, simulate)
from repro.core.histogram import HistogramConfig

_TRACE_CACHE = {}


def get_trace(n_apps=800, days=7.0, seed=42):
    key = (n_apps, days, seed)
    if key not in _TRACE_CACHE:
        _TRACE_CACHE[key] = generate_trace(n_apps, days=days, seed=seed)
    return _TRACE_CACHE[key]


def run(n_apps: int = 800, seed: int = 42):
    trace = get_trace(n_apps, seed=seed)
    rows = []

    # --- Fig 14: fixed keep-alive sweep --------------------------------------
    fixed = {}
    for ka in (10, 20, 30, 60, 120, 240):
        res = simulate(trace, FixedKeepAlivePolicy(float(ka)))
        fixed[ka] = res
        rows.append((f"fig14_fixed_{ka}m_cold_p75",
                     res.cold_pct_percentile(75),
                     {10: 50.3, 60: 25.0}.get(ka, "")))
    nou = simulate(trace, NoUnloadingPolicy())
    rows.append(("fig14_no_unloading_always_cold_pct",
                 100.0 * nou.always_cold_fraction, 3.5))

    base_waste = fixed[10].total_wasted

    # --- Fig 15: hybrid Pareto vs fixed ---------------------------------------
    hybrids = {}
    for rng_min in (60, 120, 240, 480):
        cfg = HybridConfig(histogram=HistogramConfig(range_minutes=float(rng_min)),
                           use_arima=False)
        res = simulate(trace, cfg)
        hybrids[rng_min] = res
        rows.append((f"fig15_hybrid_{rng_min}m_cold_p75",
                     res.cold_pct_percentile(75), ""))
        rows.append((f"fig15_hybrid_{rng_min}m_rel_waste",
                     res.total_wasted / base_waste, ""))
    for ka, res in fixed.items():
        rows.append((f"fig15_fixed_{ka}m_rel_waste",
                     res.total_wasted / base_waste, ""))
    # headline: cold-start ratio at matched memory (paper: ~2.5x at 4h range)
    h4 = hybrids[240]
    rows.append(("fig15_fixed10_over_hybrid4h_cold_ratio",
                 fixed[10].cold_pct_percentile(75)
                 / max(h4.cold_pct_percentile(75), 1e-9), 2.5))
    rows.append(("fig15_hybrid4h_rel_waste_vs_fixed10",
                 h4.total_wasted / base_waste, 1.0))
    # paper: fixed-2h costs ~1.5x the memory of hybrid-4h at similar colds
    rows.append(("fig15_fixed120_waste_over_hybrid4h",
                 fixed[120].total_wasted / h4.total_wasted, 1.5))

    # --- Fig 16: cutoff percentiles -------------------------------------------
    cut = simulate(trace, HybridConfig(
        histogram=HistogramConfig(head_percentile=5, tail_percentile=99),
        use_arima=False))
    nocut = simulate(trace, HybridConfig(
        histogram=HistogramConfig(head_percentile=0, tail_percentile=100),
        use_arima=False))
    rows.append(("fig16_waste_saving_5_99_vs_0_100_pct",
                 100.0 * (1 - cut.total_wasted / nocut.total_wasted), 15.0))
    rows.append(("fig16_cold_p75_5_99", cut.cold_pct_percentile(75), ""))
    rows.append(("fig16_cold_p75_0_100", nocut.cold_pct_percentile(75), ""))

    # --- Fig 17: CV threshold ---------------------------------------------------
    for cv_t in (0.0, 1.0, 2.0, 4.0):
        res = simulate(trace, HybridConfig(cv_threshold=cv_t, use_arima=False))
        rows.append((f"fig17_cv{cv_t:g}_cold_p75",
                     res.cold_pct_percentile(75), ""))
        rows.append((f"fig17_cv{cv_t:g}_rel_waste",
                     res.total_wasted / base_waste, ""))

    # --- Fig 18: ARIMA impact on always-cold apps ------------------------------
    no_arima = simulate(trace, HybridConfig(use_arima=False))
    with_arima = simulate(trace, HybridConfig(use_arima=True))
    multi = np.asarray(no_arima.invocations) > 1
    rows.append(("fig18_always_cold_pct_fixed240",
                 100.0 * fixed[240].always_cold_fraction, ""))
    rows.append(("fig18_always_cold_pct_hybrid_noarima",
                 100.0 * no_arima.always_cold_fraction, 10.5))
    rows.append(("fig18_always_cold_pct_hybrid_arima",
                 100.0 * with_arima.always_cold_fraction, 5.2))
    nz = lambda r: float(np.mean((r.cold >= r.invocations)[multi]))
    rows.append(("fig18_always_cold_excl_single_noarima",
                 100.0 * nz(no_arima), 6.9))
    rows.append(("fig18_always_cold_excl_single_arima",
                 100.0 * nz(with_arima), 1.7))
    return rows
