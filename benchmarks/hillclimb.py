"""Perf hillclimbing driver for the training/serving roofline cells.

Runs named experiment variants against the chosen cells (the ``CELLS``
table below: the most collective-bound dense model, the big-vocab
memory-bound cell, the MoE dispatch cell, and the paper-representative
decode cell) and reports the roofline terms before/after, so every
hypothesis -> change -> measure cycle is one command. The ``VARIANTS``
table is the experiment registry — each entry is (config overrides, lower
kwargs), annotated inline with the cell it targets and the bandwidth
arithmetic behind it; see also ``benchmarks/roofline.py`` for the cost
model the terms come from.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell A --variant baseline
  PYTHONPATH=src python -m benchmarks.hillclimb --cell A --variant bf16_comm
"""
from __future__ import annotations

import argparse
import json
import sys

# must run before jax init (module may be first to import jax)
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

from repro import configs
from repro.configs.base import SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

CELLS = {
    # most collective-bound + largest dense model
    "A": ("qwen2-72b", "train_4k"),
    # memory-bound big-vocab cell (d_model 1024, vocab 256k)
    "D": ("seamless-m4t-medium", "train_4k"),
    # the MoE (GShard dispatch) training cell
    "B": ("qwen3-moe-30b-a3b", "train_4k"),
    # the paper-representative serving cell (decode against a 32k cache)
    "C": ("qwen2-72b", "decode_32k"),
}

# variant -> (cfg overrides, lower kwargs)
VARIANTS = {
    "baseline": ({}, {}),
    "bf16_comm": ({}, {"cast_bf16": True}),                  # cell A
    "moe_gather": ({"moe_impl": "gather"}, {}),              # cell B
    "moe_gather_bf16": ({"moe_impl": "gather"}, {"cast_bf16": True}),
    "moe_cap1": ({"moe_capacity_factor": 1.0}, {}),
    "tp4_cap1": ({"moe_capacity_factor": 1.0}, {"mesh_shape": (64, 4)}),
    "group2k": ({"moe_group_size": 2048}, {}),
    "dist_decode": ({"use_kernels": False, "decode_shard_map": True}, {}),
    # mesh rebalance: activation AG/AR bytes scale with (TP-1)/TP * n_coll;
    # weight-gather bytes scale with 1/TP. At 72B the activations dominate
    # by ~20x, so shrink TP 16 -> 4 and grow ZeRO-DP 16 -> 64.
    "tp4": ({}, {"mesh_shape": (64, 4)}),
    "tp8": ({}, {"mesh_shape": (32, 8)}),
    "tp2": ({}, {"mesh_shape": (128, 2)}),
    "tp2_bf16": ({}, {"mesh_shape": (128, 2), "cast_bf16": True}),
    "chunked_xent": ({"chunked_xent": True}, {}),
    "tp4_bf16": ({}, {"mesh_shape": (64, 4), "cast_bf16": True}),
}


def measure(arch, shape_name, overrides, lower_kwargs, multi_pod=False):
    from repro.launch import dryrun as dr
    lower_kwargs = dict(lower_kwargs)
    mesh_shape = lower_kwargs.pop("mesh_shape", None)
    if mesh_shape is not None:
        import numpy as np
        import jax
        from jax.sharding import Mesh
        n = int(np.prod(mesh_shape))
        mesh = Mesh(np.asarray(jax.devices()[:n]).reshape(mesh_shape),
                    ("data", "model"))
    else:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get(arch)
    ov = dict(overrides)
    if ov.pop("decode_shard_map", None):
        from repro.distributed import dist_decode
        dist_decode.ENABLED = True
    if ov:
        cfg = cfg.with_(**ov)
    shape = SHAPES[shape_name]
    with mesh:
        costs = dr.depth_scaled_costs(cfg, shape, mesh, **lower_kwargs)
        compiled, model = dr._lower_one(cfg, shape, mesh, **lower_kwargs)
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    return {
        "arch": arch, "shape": shape_name,
        "flops": costs["flops"],
        "bytes_accessed": costs["bytes_accessed"],
        "collective_bytes": costs["collective_bytes"],
        "collectives": costs["collectives"],
        "compute_s": costs["flops"] / PEAK_FLOPS_BF16,
        "memory_s": costs["bytes_accessed"] / HBM_BW,
        "collective_s": costs["collective_bytes"] / ICI_BW,
        "peak_gib": (mem.argument_size_in_bytes
                     + mem.temp_size_in_bytes) / 2**30,
        "upcast_gib": dr.cpu_upcast_bytes(hlo) / 2**30,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    arch, shape = CELLS[args.cell]
    overrides, lower_kwargs = VARIANTS[args.variant]
    r = measure(arch, shape, overrides, lower_kwargs, args.multi_pod)
    r["cell"] = args.cell
    r["variant"] = args.variant
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: r[k])
    print(f"{args.cell}:{arch}:{shape} variant={args.variant}")
    print(f"  compute    {r['compute_s']:.4f}s")
    print(f"  memory     {r['memory_s']:.4f}s")
    print(f"  collective {r['collective_s']:.4f}s   <- dominant: {dom}")
    print(f"  collectives: { {k: f'{v:.3e}' for k, v in r['collectives'].items()} }")
    print(f"  peak/dev {r['peak_gib']:.1f} GiB (upcast artifact "
          f"{r['upcast_gib']:.1f} GiB)")
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(r) + "\n")


if __name__ == "__main__":
    main()
