"""Sweep-engine throughput: a whole policy grid in one device pass.

The paper's Figs. 16-17 ablations are a grid over the histogram cutoff
percentiles and the CV threshold. Before the sweep engine, each grid point
was a separate Python-level ``simulate(trace, cfg)`` call that re-bucketed,
re-transferred, and re-scanned the whole fleet; ``experiment.sweep`` stacks
the grid into one traced config axis, shares the trace pass AND the
per-group histogram update (this grid has ONE histogram shape), and pays
per config only for the window/gate/accounting layers.

Measured here, both cold (first call: jit compile + transfers included)
and warm (second call: the steady-state configs/sec a design-space search
actually sustains):

  * baseline — the equivalent Python loop of single-config ``run()`` calls;
  * sweep    — one ``sweep(trace, grid)`` call.

Every sweep row is asserted bit-identical to its single-config run before
any number is reported. Results are recorded to ``BENCH_policy_sweep.json``
(repo root) so the speedup is tracked across PRs; reduced/--smoke runs do
not clobber the canonical 100k-app record.

  PYTHONPATH=src python -m benchmarks.policy_sweep [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import jax
import numpy as np

from repro.core.experiment import HybridSpec, run as run_config, sweep
from repro.core.workload_spec import WorkloadSpec

# Anchored to the repo root (not the CWD) so re-records always update the
# tracked file.
JSON_PATH = os.environ.get(
    "BENCH_POLICY_SWEEP_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_policy_sweep.json"))

CUTS = ((0.0, 100.0), (5.0, 99.0), (10.0, 95.0), (15.0, 90.0))
CVS = (0.5, 1.0, 2.0, 4.0)
MARGINS = (0.10, 0.20)


def make_grid(range_minutes: float = 60.0):
    """32 hybrid configs: cutoffs x CV threshold x margin (Figs. 16-17)."""
    return [
        HybridSpec(range_minutes=range_minutes, head_percentile=h,
                   tail_percentile=t, cv_threshold=cv, margin=m,
                   use_arima=False,
                   label=f"hyb-cut[{h:g},{t:g}]-cv{cv:g}-m{m:g}")
        for m in MARGINS for cv in CVS for (h, t) in CUTS
    ]


def run(n_apps: int = 100_000, days: float = 14.0, max_events: int = 64,
        smoke: bool = False):
    if smoke:
        n_apps, days, max_events = 2_000, 2.0, 16
    grid = make_grid()
    S = len(grid)
    # min_events=1 keeps the record comparable with pre-spec measurements
    # (the legacy synthesize clamped counts to >= 1)
    trace = WorkloadSpec.uniform(n_apps, days=days, seed=3,
                                 max_events=max_events,
                                 min_events=1).materialize()
    trace.to_padded()          # shared trace construction out of both bills

    def timed(fn):
        t0 = time.perf_counter()
        out = fn()
        return out, time.perf_counter() - t0

    do_loop = lambda: [run_config(trace, spec, engine="fused")
                       for spec in grid]
    do_sweep = lambda: sweep(trace, grid, engine="fused")
    loop_rows, t_loop_cold = timed(do_loop)   # first call: compiles included
    _, t_loop = timed(do_loop)                # steady state
    swept, t_sweep_cold = timed(do_sweep)
    _, t_sweep = timed(do_sweep)

    # The contract before any throughput number: sweep rows are
    # bit-identical to the single-config runs they replace.
    for s in range(S):
        np.testing.assert_array_equal(swept.cold[s], loop_rows[s].cold)
        np.testing.assert_array_equal(swept.wasted_minutes[s],
                                      loop_rows[s].wasted_minutes)
        np.testing.assert_array_equal(swept.final_keep_alive[s],
                                      loop_rows[s].final_keep_alive)

    speedup = t_loop / t_sweep
    rows = [
        (f"sweep_{S}cfg_{n_apps}apps_seconds", t_sweep, ""),
        (f"loop_{S}cfg_{n_apps}apps_seconds", t_loop, ""),
        (f"sweep_{S}cfg_{n_apps}apps_cold_seconds", t_sweep_cold, ""),
        (f"loop_{S}cfg_{n_apps}apps_cold_seconds", t_loop_cold, ""),
        ("sweep_configs_per_sec", S / t_sweep, ""),
        ("loop_configs_per_sec", S / t_loop, ""),
        ("sweep_over_loop_speedup", speedup, ""),
        ("sweep_over_loop_cold_speedup", t_loop_cold / t_sweep_cold, ""),
    ]
    record = {
        "grid": {"size": S, "range_minutes": 60.0,
                 "cut_percentiles": [list(c) for c in CUTS],
                 "cv_thresholds": list(CVS), "margins": list(MARGINS)},
        "n_apps": n_apps, "days": days, "max_events": max_events,
        "timing": ("cold = first call (jit compile + transfers); "
                   "warm = second call (steady-state design-space search)"),
        "python_loop_seconds": t_loop,
        "sweep_seconds": t_sweep,
        "python_loop_cold_seconds": t_loop_cold,
        "sweep_cold_seconds": t_sweep_cold,
        "python_loop_configs_per_sec": S / t_loop,
        "sweep_configs_per_sec": S / t_sweep,
        "sweep_over_loop_speedup": speedup,
        "sweep_over_loop_cold_speedup": t_loop_cold / t_sweep_cold,
        "rows_bit_identical_to_single_runs": True,
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "platform": platform.platform(),
            "jax": jax.__version__,
            "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
    }
    # Only full-scale runs (or explicit env-var targets) touch the tracked
    # record: reduced/smoke invocations must not clobber the canonical
    # 100k-app measurement.
    if n_apps >= 100_000 or "BENCH_POLICY_SWEEP_JSON" in os.environ:
        try:
            with open(JSON_PATH, "w") as f:
                json.dump(record, f, indent=2, sort_keys=True)
                f.write("\n")
        except OSError as e:
            print(f"# WARNING: could not record {JSON_PATH}: {e}",
                  file=sys.stderr)
    else:
        print(f"# reduced run: not recording {JSON_PATH}", file=sys.stderr)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI): exercises the paths, not the "
                         "throughput claim")
    ap.add_argument("--apps", type=int, default=100_000)
    args = ap.parse_args()
    for key, value, ref in run(n_apps=args.apps, smoke=args.smoke):
        v = f"{value:.6g}" if isinstance(value, float) else value
        print(f"{key},{v},{ref}")


if __name__ == "__main__":
    main()
