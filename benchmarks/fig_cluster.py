"""Figure 19 / Section 5.3: the serving-cluster experiment analog.

18 workers (paper: 18 invoker VMs), mid-range-popularity apps (paper:
randomly selected mid-range apps), 8 simulated hours. Hybrid vs 10-minute
fixed keep-alive; also straggler hedging on/off tail latency.

Runs through the cluster front door
(``repro.serving.cluster_vector.run_cluster``) on a single shared
``AppTable`` with ``engine="vector"``: this scenario packs ~228 GB of
model weights onto 18 x 16 GB workers, so HBM evictions are part of the
experiment — the vectorized engine replays them to a fixed point and its
fig19 rows (including per-worker eviction counters) are bit-identical to
the scalar oracle (pinned by ``tests/test_cluster_conformance.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.experiment import FixedSpec, HybridSpec
from repro.core.workload import Trace, generate_trace
from repro.launch.serve import build_registry
from repro.runtime.straggler import HedgePolicy
from repro.serving.apptable import AppTable
from repro.serving.cluster_vector import ClusterSpec, run_cluster


def _midrange_trace(n_apps=68, minutes=480.0, seed=5):
    """Paper: '68 randomly selected mid-range popularity applications'."""
    big = generate_trace(800, days=minutes / 1440.0, seed=seed)
    rates = np.array([s.rate_per_day for s in big.specs])
    lo, hi = np.percentile(rates, 35), np.percentile(rates, 85)
    idx = [i for i in range(big.n_apps) if lo <= rates[i] <= hi]
    if len(idx) < n_apps:
        raise ValueError(
            f"mid-range percentile filter matched only {len(idx)} apps "
            f"(need {n_apps}) for seed={seed}: enlarge the source trace or "
            f"pick another seed instead of silently running a smaller "
            f"experiment")
    idx = idx[:n_apps]
    specs = []
    times = []
    for j, i in enumerate(idx):
        s = big.specs[i]
        # re-id so registry keys line up
        specs.append(dataclasses.replace(s, app_id=f"app-{j:06d}"))
        times.append(big.times[i])
    return Trace(specs=specs, times=times, duration_minutes=minutes)


def run(seed: int = 5, n_apps: int = 68):
    trace = _midrange_trace(n_apps=n_apps, seed=seed)
    reg = build_registry(len(trace.specs), seed, hbm_budget_bytes=16e9)
    table = AppTable.from_trace(
        trace, weight_bytes=[reg.get(s.app_id).weight_bytes
                             for s in trace.specs])
    # The 16 GB budget is oversubscribed by design; the vector engine
    # replays evictions to a fixed point, bit-identical to the oracle.
    base = ClusterSpec(n_workers=18)
    cell = lambda policy, cl: run_cluster(table, policy, cl, engine="vector")
    # Scenario parameters ride in every row label so a rerun with a
    # different seed / app count is distinguishable in the CSV output.
    tag = f"[n={n_apps};seed={seed}]"
    rows = []

    hybrid_spec = HybridSpec(use_arima=False)
    fixed = cell(FixedSpec(10.0), base)
    hyb = cell(hybrid_spec, base)

    rows.append((f"fig19_fixed10_cold_p75{tag}", fixed.cold_pct_p75, ""))
    rows.append((f"fig19_hybrid_cold_p75{tag}", hyb.cold_pct_p75, ""))
    rows.append((f"fig19_fixed10_wasted_gb_min{tag}",
                 fixed.wasted_gb_minutes, ""))
    rows.append((f"fig19_hybrid_wasted_gb_min{tag}",
                 hyb.wasted_gb_minutes, ""))
    saving = 100.0 * (1 - hyb.wasted_gb_minutes
                      / max(fixed.wasted_gb_minutes, 1e-9))
    rows.append((f"fig19_hybrid_memory_saving_pct{tag}", saving, 15.6))
    rows.append((f"fig19_fixed10_lat_p99_s{tag}", fixed.latency_pct(99), ""))
    rows.append((f"fig19_hybrid_lat_p99_s{tag}", hyb.latency_pct(99), ""))
    rows.append((f"fig19_fixed10_evictions{tag}", float(fixed.evictions), ""))
    rows.append((f"fig19_hybrid_evictions{tag}", float(hyb.evictions), ""))

    # straggler mitigation (beyond-paper, required at 1000+ node scale)
    hedged = cell(hybrid_spec,
                  dataclasses.replace(base, hedge=HedgePolicy()))
    unhedged = cell(hybrid_spec,
                    dataclasses.replace(base, hedge=HedgePolicy(enabled=False)))
    rows.append((f"straggler_hedged_lat_p99_s{tag}",
                 hedged.latency_pct(99), ""))
    rows.append((f"straggler_unhedged_lat_p99_s{tag}",
                 unhedged.latency_pct(99), ""))

    # controller restart resilience (fault tolerance)
    restart = cell(hybrid_spec,
                   dataclasses.replace(base, checkpoint_at_minute=240.0))
    rows.append((f"controller_restart_cold_p75{tag}",
                 restart.cold_pct_p75, ""))
    rows.append((f"controller_restart_mid_run{tag}",
                 1.0 if restart.restored_mid_run else 0.0, 1.0))
    return rows
