"""Section 5.3 'Policy overhead': µs per policy update.

Paper: 835.7 µs per invocation in the Scala controller. Ours:
  * scalar host path (per-invocation, like the paper's controller);
  * batched-JAX fleet update (all apps in one vectorized op);
  * Pallas kernel (interpret mode on CPU — the TPU-native path; interpret
    timing is NOT meaningful on CPU, reported for completeness only).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.histogram import HistogramConfig
from repro.core.policy import HybridConfig, HybridHistogramPolicy
from repro.kernels import ref as kref


def run(n_apps: int = 4096, n_bins: int = 240):
    rows = []
    rng = np.random.default_rng(0)

    # scalar path
    p = HybridHistogramPolicy(HybridConfig(use_arima=False))
    for i in range(200):
        p.on_invocation("warm-app", float(rng.integers(1, 60)))
    t0 = time.perf_counter()
    n = 2000
    for i in range(n):
        p.on_invocation("warm-app", float(rng.integers(1, 60)))
    scalar_us = (time.perf_counter() - t0) / n * 1e6
    rows.append(("overhead_scalar_us_per_invocation", scalar_us, 835.7))

    # batched jnp fleet update (jitted oracle — what a TPU controller runs)
    counts = jnp.asarray(rng.integers(0, 5, (n_apps, n_bins)), jnp.int32)
    total = counts.sum(1)
    oob = jnp.zeros((n_apps,), jnp.int32)
    cvs = total.astype(jnp.float32)
    cvss = jnp.asarray((np.asarray(counts) ** 2).sum(1), jnp.float32)
    bins = jnp.asarray(rng.integers(0, n_bins, n_apps), jnp.int32)
    active = jnp.ones((n_apps,), jnp.int32)

    fn = jax.jit(kref.policy_update_ref)
    out = fn(counts, oob, total, cvs, cvss, bins, active)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    reps = 50
    for _ in range(reps):
        out = fn(counts, oob, total, cvs, cvss, bins, active)
    jax.block_until_ready(out)
    batched_us = (time.perf_counter() - t0) / reps * 1e6
    rows.append(("overhead_batched_us_per_tick_4096apps", batched_us, ""))
    rows.append(("overhead_batched_us_per_app", batched_us / n_apps, ""))
    rows.append(("overhead_speedup_vs_paper_per_app",
                 835.7 / max(batched_us / n_apps, 1e-9), ""))
    return rows
