"""Section 5.3 'Policy overhead' + fleet-scale simulator step-throughput.

Paper: 835.7 µs per invocation in the Scala controller. Ours:
  * scalar host path (per-invocation, like the paper's controller);
  * batched-JAX fleet update (all apps in one vectorized op);
  * the fused hybrid simulator engine (incremental cumulative-count state,
    chunked over apps) vs the pre-PR batched engine at 100k apps, and a
    ~1M-app synthetic run through the chunked driver;
  * the S=1 sweep-generalized engine (what ``run()`` executes) vs a scan
    of the dedicated single-config step ``fused_hybrid_step_math`` over
    the same bucketed chunks — the carried-windows sweep step must hold
    parity with the pre-sweep dedicated engine it replaced
    (``fused_vs_dedicated_step_ratio`` ~ 1.0).

Results are also recorded to ``BENCH_policy_overhead.json`` (repo root) so
the step-throughput gain of the fused engine is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy_math
from repro.core.experiment import HybridSpec, run as run_config
from repro.core.policy import HybridConfig, HybridHistogramPolicy
from repro.core.workload import Trace
from repro.core.workload_spec import WorkloadSpec
from repro.kernels import ref as kref

# Anchored to the repo root (not the CWD) so re-records always update the
# tracked file.
JSON_PATH = os.environ.get(
    "BENCH_POLICY_OVERHEAD_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_policy_overhead.json"))


def _app_steps(trace: Trace) -> int:
    """Scanned app-steps: what the batched engines actually execute after
    event-count bucketing (sum of bucket_size * bucket_scan_length)."""
    from repro.core.simulator import _buckets
    times, counts = trace.to_padded()
    return sum(len(sel) * sub.shape[1] for sel, sub in _buckets(times, counts))


@partial(jax.jit, static_argnums=(1,))
def _dedicated_scan(times, cfg: "policy_math.HybridStepConfig"):
    """The pre-sweep dedicated engine's inner loop: scan the single-config
    fused step (full per-app histogram carry, per-step decide) over one
    bucket's time columns. The A/B baseline for the S=1 sweep engine."""
    n = times.shape[0]
    dt = times.dtype
    init = (
        jnp.full((n,), -jnp.inf, dt),                        # prev time
        jnp.zeros((n, cfg.n_bins), jnp.int32),               # cum histogram
        jnp.zeros((n,), jnp.int32),                          # oob count
        jnp.zeros((n,), dt),                                 # Welford sum
        jnp.zeros((n,), dt),                                 # Welford sum sq
        jnp.zeros((n,), dt),                                 # load bound
        jnp.full((n,), jnp.asarray(cfg.standard_keep, dt)),  # unload bound
        jnp.zeros((n,), jnp.int32),                          # cold count
        jnp.zeros((n,), dt),                                 # waste
    )

    def body(carry, t_col):
        return policy_math.fused_hybrid_step_math(
            t_col, *carry, cfg=cfg, gather=True), None

    final, _ = jax.lax.scan(body, init, times.T)
    return final[7], final[8]


def _run_dedicated(trace: Trace, spec: HybridSpec):
    """Drive ``_dedicated_scan`` over the same event-count buckets the
    fused engine scans, accumulating host-side like the engines do."""
    from repro.core.simulator import _buckets, _step_config_for, enable_x64
    cfg = _step_config_for(spec.to_config())
    times, counts = trace.to_padded()
    cold = np.zeros(times.shape[0], np.int64)
    with enable_x64():
        for sel, sub in _buckets(times, counts):
            c, _ = _dedicated_scan(jnp.asarray(sub, jnp.float64), cfg)
            cold[sel] = np.asarray(c)
    return cold


def _time(fn, repeats=1):
    fn()                       # warmup: jit compile + first transfer
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_apps_compare: int = 100_000, n_apps_scale: int = 1_000_000,
        days: float = 14.0, max_events: int = 64):
    rows = []
    record = {}
    rng = np.random.default_rng(0)

    # scalar path
    p = HybridHistogramPolicy(HybridConfig(use_arima=False))
    for i in range(200):
        p.on_invocation("warm-app", float(rng.integers(1, 60)))
    t0 = time.perf_counter()
    n = 2000
    for i in range(n):
        p.on_invocation("warm-app", float(rng.integers(1, 60)))
    scalar_us = (time.perf_counter() - t0) / n * 1e6
    rows.append(("overhead_scalar_us_per_invocation", scalar_us, 835.7))

    # batched jnp fleet update (jitted oracle — what a TPU controller runs)
    n_apps, n_bins = 4096, 240
    counts = jnp.asarray(rng.integers(0, 5, (n_apps, n_bins)), jnp.int32)
    total = counts.sum(1)
    oob = jnp.zeros((n_apps,), jnp.int32)
    cvs = total.astype(jnp.float32)
    cvss = jnp.asarray((np.asarray(counts) ** 2).sum(1), jnp.float32)
    bins = jnp.asarray(rng.integers(0, n_bins, n_apps), jnp.int32)
    active = jnp.ones((n_apps,), jnp.int32)

    fn = jax.jit(kref.policy_update_ref)
    out = fn(counts, oob, total, cvs, cvss, bins, active)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    reps = 50
    for _ in range(reps):
        out = fn(counts, oob, total, cvs, cvss, bins, active)
    jax.block_until_ready(out)
    batched_us = (time.perf_counter() - t0) / reps * 1e6
    rows.append(("overhead_batched_us_per_tick_4096apps", batched_us, ""))
    rows.append(("overhead_batched_us_per_app", batched_us / n_apps, ""))
    rows.append(("overhead_speedup_vs_paper_per_app",
                 835.7 / max(batched_us / n_apps, 1e-9), ""))
    record["overhead_scalar_us_per_invocation"] = scalar_us
    record["overhead_batched_us_per_app"] = batched_us / n_apps

    # ---- step-throughput: fused engine vs pre-sweep batched engine ---------
    spec = HybridSpec(use_arima=False)
    trace_c = WorkloadSpec.uniform(n_apps_compare, days=days, seed=0,
                                   max_events=max_events,
                                   min_events=1).materialize()
    steps_c = _app_steps(trace_c)

    t_ref = _time(lambda: run_config(trace_c, spec, engine="reference"))
    t_fused = _time(lambda: run_config(trace_c, spec, engine="fused"))
    ref_tput = steps_c / t_ref
    fused_tput = steps_c / t_fused
    speedup = t_ref / t_fused
    rows.append((f"fused_vs_reference_{n_apps_compare}apps_speedup",
                 speedup, ""))
    rows.append((f"fused_step_throughput_{n_apps_compare}apps_per_s",
                 fused_tput, ""))
    rows.append((f"reference_step_throughput_{n_apps_compare}apps_per_s",
                 ref_tput, ""))
    record["compare_point"] = {
        "n_apps": n_apps_compare, "days": days, "max_events": max_events,
        "app_steps": steps_c,
        "reference_seconds": t_ref, "fused_seconds": t_fused,
        "reference_app_steps_per_s": ref_tput,
        "fused_app_steps_per_s": fused_tput,
        "fused_over_reference_speedup": speedup,
    }

    # ---- S=1 parity: sweep-generalized engine vs the dedicated step --------
    # run() executes the S=1 sweep scan (carried residency bounds, shared
    # group state); the dedicated scan is what the engine looked like before
    # the config axis existed. The carried-windows step must not tax the
    # single-config case — the ratio is the regression guard.
    res_fused = run_config(trace_c, spec, engine="fused")
    np.testing.assert_array_equal(res_fused.cold, _run_dedicated(trace_c, spec))
    t_dedicated = _time(lambda: _run_dedicated(trace_c, spec))
    ratio = t_fused / t_dedicated
    rows.append((f"fused_vs_dedicated_step_ratio_{n_apps_compare}apps",
                 ratio, ""))
    record["s1_parity"] = {
        "note": ("t_fused / t_dedicated for the single-config run; ~1.0 "
                 "means the sweep generalization costs the S=1 case "
                 "nothing (cold counts asserted equal first)"),
        "dedicated_seconds": t_dedicated,
        "fused_seconds": t_fused,
        "fused_vs_dedicated_step_ratio": ratio,
    }

    # ---- ~1M-app synthetic trace through the chunked fused driver ----------
    trace_m = WorkloadSpec.uniform(n_apps_scale, days=days, seed=1,
                                   max_events=max_events,
                                   min_events=1).materialize()
    steps_m = _app_steps(trace_m)
    t0 = time.perf_counter()
    res = run_config(trace_m, spec, engine="fused")
    t_scale = time.perf_counter() - t0
    rows.append((f"fused_{n_apps_scale}apps_seconds", t_scale, ""))
    rows.append((f"fused_{n_apps_scale}apps_step_throughput_per_s",
                 steps_m / t_scale, ""))
    rows.append((f"fused_{n_apps_scale}apps_cold_p75_pct",
                 res.cold_pct_percentile(75), ""))
    record["scale_point"] = {
        # deliberately a COLD end-to-end run: includes jit compiles and
        # host->device transfers, unlike compare_point's warmed best-of
        "timing": "cold end-to-end (includes jit compile + transfers)",
        "n_apps": n_apps_scale, "days": days, "max_events": max_events,
        "app_steps": steps_m, "seconds": t_scale,
        "app_steps_per_s": steps_m / t_scale,
        "total_invocations": int(res.invocations.sum()),
        "cold_p75_pct": res.cold_pct_percentile(75),
        "always_cold_fraction": res.always_cold_fraction,
    }

    record["meta"] = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "jax": jax.__version__,
        "recorded_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }
    # Only full-scale runs (or explicit env-var targets) touch the tracked
    # record: reduced smoke invocations must not clobber the canonical
    # 100k/1M-app measurement.
    full_scale = n_apps_compare >= 100_000 and n_apps_scale >= 1_000_000
    if full_scale or "BENCH_POLICY_OVERHEAD_JSON" in os.environ:
        try:
            with open(JSON_PATH, "w") as f:
                json.dump(record, f, indent=2, sort_keys=True)
                f.write("\n")
        except OSError as e:
            print(f"# WARNING: could not record {JSON_PATH}: {e}",
                  file=sys.stderr)
    else:
        print(f"# reduced run: not recording {JSON_PATH}", file=sys.stderr)
    return rows
