"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three per-device time terms:

  compute    = HLO_flops_per_dev / peak_flops        (197 TFLOP/s bf16)
  memory     = HLO_bytes_per_dev / hbm_bw            (819 GB/s)
  collective = collective_bytes_per_dev / ici_bw     (~50 GB/s/link)

plus MODEL_FLOPS (6*N*D train / 2*N_active*D inference) and the useful-
compute ratio MODEL_FLOPS / (HLO_flops * n_dev). The dominant term is the
bottleneck the perf loop iterates on.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.configs.base import SHAPES

RESULTS_DIR = os.environ.get("REPRO_RESULTS", "results")


def load_cells(paths=None) -> List[dict]:
    paths = paths or [os.path.join(RESULTS_DIR, "dryrun_single.jsonl"),
                      os.path.join(RESULTS_DIR, "dryrun_multi.jsonl")]
    cells = []
    for p in paths:
        if not os.path.exists(p):
            continue
        with open(p) as f:
            for line in f:
                try:
                    cells.append(json.loads(line))
                except json.JSONDecodeError:
                    pass
    return cells


def analyze(cell: dict) -> dict:
    shape = SHAPES[cell["shape"]]
    n_dev = cell["n_devices"]
    compute_s = cell["flops"] / PEAK_FLOPS_BF16
    memory_s = cell["bytes_accessed"] / HBM_BW
    collective_s = cell["collective_bytes"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6*N*D training (N_active for MoE), 2*N_active*D inference
    if shape.kind == "train":
        tokens = shape.tokens
        model_flops = 6.0 * cell["n_params_active"] * tokens
    elif shape.kind == "prefill":
        tokens = shape.tokens
        model_flops = 2.0 * cell["n_params_active"] * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        model_flops = 2.0 * cell["n_params_active"] * tokens
    hlo_total = cell["flops"] * n_dev
    useful = model_flops / hlo_total if hlo_total else 0.0

    bound_s = max(terms.values())
    # roofline fraction: achievable-step-time lower bound over the dominant
    # term if it ran at peak = useful-model-time / bound-time
    model_time = model_flops / (n_dev * PEAK_FLOPS_BF16)
    frac = model_time / bound_s if bound_s > 0 else 0.0
    return {
        **{f"{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "peak_gib_per_dev": cell["peak_bytes"] / 2**30,
        # TPU-adjusted: subtract CPU bf16->f32 legalization artifacts, but
        # never below the argument+output floor (the upcast estimate can
        # over-count transients that don't coexist).
        "peak_adj_gib_per_dev": max(
            cell["peak_bytes"] - cell.get("cpu_upcast_bytes", 0.0),
            cell.get("argument_size", 0.0) + cell.get("output_size", 0.0),
        ) / 2**30,
        "fits_16g": max(
            cell["peak_bytes"] - cell.get("cpu_upcast_bytes", 0.0),
            cell.get("argument_size", 0.0) + cell.get("output_size", 0.0),
        ) < 16 * 2**30,
    }


def table(cells: Optional[List[dict]] = None) -> List[dict]:
    cells = cells if cells is not None else load_cells()
    out = []
    for c in cells:
        row = {"arch": c["arch"], "shape": c["shape"],
               "mesh": c.get("mesh_name", c["mesh"]), **analyze(c)}
        out.append(row)
    return out


def run():
    """Benchmark-harness entry: emit key roofline stats per cell."""
    rows = []
    for r in table():
        tag = f"{r['mesh']}:{r['arch']}:{r['shape']}"
        rows.append((f"roofline_{tag}_dominant_term",
                     {"compute": 0, "memory": 1, "collective": 2}[r["dominant"]],
                     r["dominant"]))
        rows.append((f"roofline_{tag}_fraction", r["roofline_fraction"], ""))
    if not rows:
        rows.append(("roofline_no_dryrun_results_found", 0.0,
                     "run launch/dryrun.py --all first"))
    return rows


def markdown_table(mesh_name: str = "single-pod") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | peak GiB/dev (adj) |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in table():
        if r["mesh"] != mesh_name:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | "
            f"{r['peak_gib_per_dev']:.1f} ({r['peak_adj_gib_per_dev']:.1f}) |")
    return "\n".join(lines)
