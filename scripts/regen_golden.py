#!/usr/bin/env python
"""Regenerate the golden-trace regression fixtures under tests/golden/.

The goldens pin the float64 scalar oracle's per-app cold counts, final
policy windows, and wasted minutes on the deterministic traces defined in
``tests/golden_traces.py``. ``tests/test_golden.py`` replays every engine
against them, so an (intentional or accidental) policy-formula change fails
loudly instead of silently shifting Fig. 12-style numbers.

Run after a DELIBERATE formula change, then review the diff of the JSON:

    PYTHONPATH=src python scripts/regen_golden.py
"""
import dataclasses
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, os.path.join(REPO, "tests"))

from repro.core.policy import HybridHistogramPolicy           # noqa: E402
from repro.core.simulator import simulate_scalar              # noqa: E402

from golden_traces import (GOLDEN_TRACES, cluster_oversubscribed_fleet,  # noqa: E402
                           cluster_small_fleet)

GOLDEN_DIR = os.path.join(REPO, "tests", "golden")

CLUSTER_STAT_KEYS = ("cold_starts", "warm_starts", "prewarms", "unloads",
                     "evictions", "budget_overflows", "bytes_moved")

CLUSTER_GOLDENS = {
    # json filename -> fixture returning (workload, policy, cluster)
    "cluster_small.json": cluster_small_fleet,
    "cluster_oversub.json": cluster_oversubscribed_fleet,
}


def regen_cluster(fname: str, fixture) -> None:
    """A cluster golden: the per-event scalar oracle's cold %, wasted
    GB-minutes, latency percentiles and per-worker counters (evictions and
    budget overflows included); both cluster engines replay against it."""
    from repro.serving.cluster_vector import run_cluster

    workload, policy, cluster = fixture()
    res = run_cluster(workload, policy, cluster, engine="scalar")
    record = {
        "workload": getattr(workload, "name", type(workload).__name__),
        "n_apps": workload.n_apps,
        "n_workers": cluster.n_workers,
        "balancing": cluster.balancing,
        "policy": policy.name,
        "cold_pct_per_app": res.cold_pct_per_app.tolist(),
        "wasted_gb_minutes": res.wasted_gb_minutes,
        "latency_pct": {q: res.latency_pct(float(q))
                        for q in ("50", "90", "99")},
        "stats_per_worker": [
            {k: s[k] for k in CLUSTER_STAT_KEYS}
            for s in res.stats_per_worker],
    }
    path = os.path.join(GOLDEN_DIR, fname)
    with open(path, "w") as f:
        json.dump(record, f, indent=1, sort_keys=True)
        f.write("\n")
    evict = sum(s["evictions"] for s in res.stats_per_worker)
    print(f"wrote {path}: {workload.n_apps} apps on {cluster.n_workers} "
          f"workers, {len(res.latencies_s)} events, {evict} evictions")


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for fname, fixture in sorted(CLUSTER_GOLDENS.items()):
        regen_cluster(fname, fixture)
    for name, (make_trace, cfg) in sorted(GOLDEN_TRACES.items()):
        trace = make_trace()
        res = simulate_scalar(trace, HybridHistogramPolicy(cfg))
        record = {
            "trace": name,
            "n_apps": trace.n_apps,
            "duration_minutes": trace.duration_minutes,
            "config": dataclasses.asdict(cfg),
            "cold": res.cold.tolist(),
            "invocations": res.invocations.tolist(),
            "final_prewarm": res.final_prewarm.tolist(),
            "final_keep_alive": res.final_keep_alive.tolist(),
            "wasted_minutes": res.wasted_minutes.tolist(),
        }
        path = os.path.join(GOLDEN_DIR, f"{name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}: {trace.n_apps} apps, "
              f"{int(res.invocations.sum())} invocations, "
              f"{int(res.cold.sum())} cold starts")


if __name__ == "__main__":
    main()
