#!/usr/bin/env bash
# Tier-1 CI gate: install dev deps, then run the full test suite.
#
# A missing dev dependency (e.g. hypothesis) must never kill collection
# again — requirements-dev.txt is installed first, and the suite runs with
# -x so the first regression fails fast, matching ROADMAP.md's tier-1
# command.
set -euo pipefail
cd "$(dirname "$0")/.."

# Invariant linter first: stdlib-only (no jax needed), catches contract
# violations (repro/analysis passes) in seconds before the test suite runs.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis src

python -c "import jax, numpy" 2>/dev/null || \
    python -m pip install "jax[cpu]" numpy
python -m pip install -r requirements-dev.txt
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
