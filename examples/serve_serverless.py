"""End-to-end serverless model serving (the paper's kind of system, live).

Real JAX models behind a warm pool driven by the hybrid histogram policy:
requests arrive on a generated trace; cold starts do an actual weight
device_put + executable-cache warmup, warm requests hit resident weights.
Measures the realized cold/warm latency gap and the policy's hit rate, then
compares against the fixed 10-minute keep-alive.

  PYTHONPATH=src python examples/serve_serverless.py [--minutes 90] [--apps 6]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.configs import get, reduced
from repro.core.experiment import FixedSpec, HybridSpec
from repro.core.workload import generate_trace
from repro.serving.engine import ServeEngine
from repro.serving.registry import ModelEndpoint, Registry
from repro.serving.warmpool import WarmPool

MIN = 60.0


def drive(policy_spec, trace, registry, max_events=150):
    engine = ServeEngine(registry)
    pool = WarmPool(registry, policy_spec)
    events = []
    for i, spec in enumerate(trace.specs):
        for t in trace.times[i]:
            events.append((t * MIN, spec.app_id))
    events.sort()
    events = events[:max_events]

    lat_cold, lat_warm = [], []
    toks = jnp.zeros((1, 8), jnp.int32)
    for t, app in events:
        was_cold, _ = pool.on_request(app, t)
        if was_cold and not engine.is_loaded(app):
            engine.load(app)
        if not engine.is_loaded(app):
            engine.load(app)
        _, wall = engine.generate(app, toks, max_new=4, max_len=16)
        (lat_cold if was_cold else lat_warm).append(wall)
        pool.on_request_end(app, t)
        # mirror policy decisions onto the engine
        st = pool.state[app]
        if not st.loaded:
            engine.unload(app)
    stats = pool.finalize(events[-1][0] if events else 0.0)
    total = stats.cold_starts + stats.warm_starts
    print(f"[{policy_spec.name}] requests={total} "
          f"cold={stats.cold_starts} ({100 * stats.cold_starts / total:.1f}%) "
          f"prewarms={stats.prewarms} "
          f"resident GB-min={stats.resident_byte_seconds / 1e9 / 60:.2f}")
    if lat_cold and lat_warm:
        print(f"   measured latency: cold p50 {np.median(lat_cold) * 1e3:.1f} ms"
              f" vs warm p50 {np.median(lat_warm) * 1e3:.1f} ms")
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", type=int, default=4)
    ap.add_argument("--minutes", type=float, default=600.0,
                    help="simulated minutes (virtual time is free)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    registry = Registry()
    arch_ids = ["smollm-135m", "mamba2-2.7b", "recurrentgemma-2b",
                "olmoe-1b-7b", "qwen2-7b", "seamless-m4t-medium"]
    for i in range(args.apps):
        cfg = reduced(get(arch_ids[i % len(arch_ids)]))
        registry.register(ModelEndpoint(app_id=f"app-{i:06d}", cfg=cfg,
                                        seed=i, weight_bytes=int(50e6)))
    # periodic endpoints (period >> 10 min): the regime where the histogram
    # policy's pre-warming beats any fixed keep-alive
    import numpy as np_
    from repro.core.workload import AppSpec, Trace
    rng = np_.random.default_rng(args.seed)
    specs, times = [], []
    for i in range(args.apps):
        period = float(rng.choice([15.0, 20.0, 30.0, 40.0]))
        t = np_.arange(rng.uniform(0, 5), args.minutes, period)
        specs.append(AppSpec(app_id=f"app-{i:06d}", pattern="periodic",
                             rate_per_day=1440.0 / period,
                             period_minutes=period, exec_time_s=0.5,
                             memory_mb=100.0, n_functions=1,
                             triggers=("timer",)))
        times.append(t)
    trace = Trace(specs=specs, times=times, duration_minutes=args.minutes)

    print(f"serving {args.apps} endpoints over {args.minutes:g} simulated "
          f"minutes (real model executions)\n")
    hybrid = drive(HybridSpec(use_arima=False, label="hybrid"), trace,
                   registry)
    fixed = drive(FixedSpec(10.0), trace, registry)
    saving = 100 * (1 - hybrid.resident_byte_seconds
                    / max(fixed.resident_byte_seconds, 1e-9))
    print(f"\nhybrid policy memory saving vs fixed-10m: {saving:.1f}% "
          f"(paper's OpenWhisk experiment: 15.6%)")


if __name__ == "__main__":
    main()
