"""Export a generated trace in the AzurePublicDataset format — the analog of
the paper's released sanitized dataset (contribution #4). Tools written
against github.com/Azure/AzurePublicDataset run unchanged on these files.

  PYTHONPATH=src python examples/export_dataset.py --apps 200 --days 2
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core.dataset_export import export
from repro.core.workload import generate_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", type=int, default=200)
    ap.add_argument("--days", type=float, default=2.0)
    ap.add_argument("--out", default="results/dataset")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    trace = generate_trace(args.apps, days=args.days, seed=args.seed)
    paths = export(trace, args.out)
    n_inv = sum(len(t) for t in trace.times)
    print(f"exported {args.apps} apps / {n_inv:,} invocations:")
    for p in paths:
        print(" ", p)


if __name__ == "__main__":
    main()
