"""Quickstart: reproduce the paper's headline result in one minute.

Generates an Azure-like FaaS trace from the paper's published distributions,
then evaluates the whole policy grid — fixed keep-alives, the hybrid
histogram policy, and the no-unloading bound — with ONE ``sweep()`` call
(Fig. 15's Pareto comparison in a single vectorized pass).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import generate_trace, pareto_frontier
from repro.core.experiment import FixedSpec, HybridSpec, NoUnloadSpec, sweep


def main():
    print("generating 7-day trace (400 apps) from the paper's distributions...")
    trace = generate_trace(n_apps=400, days=7.0, seed=0)
    n_inv = sum(len(t) for t in trace.times)
    print(f"  {trace.n_apps} apps, {n_inv:,} invocations\n")

    grid = (
        [FixedSpec(float(ka)) for ka in (10, 60, 120)]
        + [HybridSpec(range_minutes=float(rng), use_arima=False)
           for rng in (120, 240)]
        + [NoUnloadSpec()]
    )
    points = sweep(trace, grid).points()

    base = points[0].wasted_memory
    print(f"{'policy':>14s} {'cold% (p75 app)':>16s} {'rel. memory':>12s}")
    for p in points:
        print(f"{p.name:>14s} {p.cold_pct_p75:>15.1f}% "
              f"{p.wasted_memory / base:>11.2f}x")

    frontier = {p.name for p in pareto_frontier(points)}
    print(f"\nPareto-optimal policies: {sorted(frontier)}")
    hybrid = next(p for p in points if p.name == "hybrid-240m")
    fixed10 = points[0]
    print(f"\nPaper's claim: the hybrid policy beats the 10-min fixed "
          f"keep-alive on BOTH axes:\n"
          f"  cold starts: {fixed10.cold_pct_p75:.1f}% -> "
          f"{hybrid.cold_pct_p75:.1f}%   "
          f"memory: 1.00x -> {hybrid.wasted_memory / base:.2f}x")


if __name__ == "__main__":
    main()
