"""Quickstart: reproduce the paper's headline result in one minute.

Generates an Azure-like FaaS trace from the paper's published distributions,
evaluates the whole policy grid — fixed keep-alives, the hybrid histogram
policy, and the no-unloading bound — with ONE ``sweep()`` call (Fig. 15's
Pareto comparison in a single vectorized pass), then repeats the comparison
across workload *regimes* with the trace axis:
``sweep(traces=[...], specs=[...])`` is "Fig. 14 across five workload
scenarios" in one call.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import generate_trace, pareto_frontier
from repro.core.experiment import FixedSpec, HybridSpec, NoUnloadSpec, sweep
from repro.core.workload_spec import azure_like, bursty, timer_heavy


def main():
    print("generating 7-day trace (400 apps) from the paper's distributions...")
    trace = generate_trace(n_apps=400, days=7.0, seed=0)
    n_inv = sum(len(t) for t in trace.times)
    print(f"  {trace.n_apps} apps, {n_inv:,} invocations\n")

    grid = (
        [FixedSpec(float(ka)) for ka in (10, 60, 120)]
        + [HybridSpec(range_minutes=float(rng), use_arima=False)
           for rng in (120, 240)]
        + [NoUnloadSpec()]
    )
    points = sweep(trace, grid).points()

    base = points[0].wasted_memory
    print(f"{'policy':>14s} {'cold% (p75 app)':>16s} {'rel. memory':>12s}")
    for p in points:
        print(f"{p.name:>14s} {p.cold_pct_p75:>15.1f}% "
              f"{p.wasted_memory / base:>11.2f}x")

    frontier = {p.name for p in pareto_frontier(points)}
    print(f"\nPareto-optimal policies: {sorted(frontier)}")
    hybrid = next(p for p in points if p.name == "hybrid-240m")
    fixed10 = points[0]
    print(f"\nPaper's claim: the hybrid policy beats the 10-min fixed "
          f"keep-alive on BOTH axes:\n"
          f"  cold starts: {fixed10.cold_pct_p75:.1f}% -> "
          f"{hybrid.cold_pct_p75:.1f}%   "
          f"memory: 1.00x -> {hybrid.wasted_memory / base:.2f}x")

    # --- the trace axis: the same policy grid across workload regimes -------
    print("\nsame grid across workload scenarios (trace x policy sweep):")
    scenarios = [azure_like(2000, days=3.0, seed=0, max_events=48),
                 bursty(2000, days=3.0, seed=0, max_events=48),
                 timer_heavy(2000, days=3.0, seed=0, max_events=48)]
    regime_grid = [FixedSpec(10.0), HybridSpec(use_arima=False)]
    res = sweep(traces=scenarios, specs=regime_grid)
    print(f"{'scenario':>22s} {'fixed-10m p75':>14s} {'hybrid p75':>11s}")
    for t in range(len(res)):
        f10, hyb = res.row(t, 0), res.row(t, 1)
        print(f"{res.trace_name(t):>22s} "
              f"{f10.cold_pct_percentile(75):>13.1f}% "
              f"{hyb.cold_pct_percentile(75):>10.1f}%")


if __name__ == "__main__":
    main()
