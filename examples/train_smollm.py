"""End-to-end training driver with checkpoint/restart.

Trains a SmolLM-family model on the synthetic deterministic pipeline,
checkpoints every 50 steps, and (optionally) injects a mid-run crash to
demonstrate bit-exact restart. On CPU the default is a ~10M-parameter
reduction; pass --full for the real 135M config (TPU recommended).

  PYTHONPATH=src python examples/train_smollm.py --steps 200
  PYTHONPATH=src python examples/train_smollm.py --steps 200 --crash-at 120
"""
import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs import SHAPES, get
from repro.runtime.fault_tolerance import run_with_restarts
from repro.training import optimizer as opt
from repro.training.train_loop import LoopConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="real 135M config (use on TPU)")
    ap.add_argument("--crash-at", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    cfg = get("smollm-135m")
    if not args.full:
        cfg = cfg.with_(n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                        head_dim=32, d_ff=688, vocab=8192, dtype="float32",
                        remat=False)
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=args.seq,
                                global_batch=args.batch)
    ckdir = args.checkpoint_dir or tempfile.mkdtemp(prefix="smollm_ckpt_")
    loop = LoopConfig(steps=args.steps, checkpoint_every=50,
                      checkpoint_dir=ckdir, log_every=10)
    opt_cfg = opt.OptConfig(lr=6e-4, warmup_steps=20, total_steps=args.steps)

    if args.crash_at:
        report = run_with_restarts(cfg, shape, loop, opt_cfg,
                                   fault_at_step=args.crash_at)
        res = report.result
        print(f"\nsurvived {report.attempts - 1} crash(es); "
              f"resumed from step {res['resumed_from']}")
    else:
        res = train(cfg, shape, loop, opt_cfg)
    print(f"loss: {res['first_loss']:.3f} -> {res['final_loss']:.3f} "
          f"(checkpoints in {ckdir})")


if __name__ == "__main__":
    main()
