"""Policy design-space exploration: sweep the hybrid policy's knobs
(histogram range, CV threshold, cutoff percentiles) and print the Pareto
frontier — the tool you'd use to re-tune the policy for a new fleet.

The whole design space is one declarative spec grid over
``experiment.sweep``: the trace is prepared and scanned once for every
configuration (grid points sharing a histogram shape also share its
sufficient statistics), so adding a candidate policy costs a config row,
not another simulation pass. ``--scenario`` swaps the workload regime the
frontier is tuned against (any name in ``workload_spec.SCENARIOS``);
``--scenario all`` explores every regime in one trace x policy sweep.

  PYTHONPATH=src python examples/policy_explorer.py [--apps 500]
  PYTHONPATH=src python examples/policy_explorer.py --scenario bursty
  PYTHONPATH=src python examples/policy_explorer.py --scenario all
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import generate_trace, pareto_frontier
from repro.core.experiment import FixedSpec, HybridSpec, sweep
from repro.core.workload_spec import SCENARIOS


def build_grid():
    grid = [FixedSpec(float(ka)) for ka in (10, 30, 60, 120, 240)]
    for rng in (60, 120, 240):
        for cv in (0.5, 2.0, 4.0):
            grid.append(HybridSpec(range_minutes=float(rng), cv_threshold=cv,
                                   use_arima=False,
                                   label=f"hyb-r{rng}-cv{cv:g}"))
    for head, tail in ((0, 100), (5, 99), (10, 95)):
        grid.append(HybridSpec(head_percentile=float(head),
                               tail_percentile=float(tail), use_arima=False,
                               label=f"hyb-cut[{head},{tail}]"))
    return grid


def show_frontier(points, title):
    base = next(p for p in points if p.name == "fixed-10m").wasted_memory
    frontier = {p.name for p in pareto_frontier(points)}
    print(f"-- {title}")
    print(f"{'policy':>18s} {'cold% p75':>10s} {'rel.mem':>8s}  pareto")
    for p in sorted(points, key=lambda p: p.wasted_memory):
        star = "  *" if p.name in frontier else ""
        print(f"{p.name:>18s} {p.cold_pct_p75:>9.1f}% "
              f"{p.wasted_memory / base:>7.2f}x{star}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", type=int, default=500)
    ap.add_argument("--days", type=float, default=7.0)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--scenario", default=None,
                    choices=sorted(SCENARIOS) + ["all"],
                    help="workload regime (default: the eager azure-like "
                         "generate_trace); 'all' sweeps every scenario")
    args = ap.parse_args()

    grid = build_grid()
    if args.scenario is None:
        trace = generate_trace(args.apps, days=args.days, seed=args.seed)
        show_frontier(sweep(trace, grid).points(), "generate_trace")
        return
    names = sorted(SCENARIOS) if args.scenario == "all" else [args.scenario]
    specs = [SCENARIOS[n](args.apps, days=args.days, seed=args.seed,
                          max_events=64) for n in names]
    res = sweep(traces=specs, specs=grid)      # (T, S) in one call
    for t, pts in enumerate(res.points()):
        show_frontier(pts, res.trace_name(t))


if __name__ == "__main__":
    main()
