"""Policy design-space exploration: sweep the hybrid policy's knobs
(histogram range, CV threshold, cutoff percentiles) and print the Pareto
frontier — the tool you'd use to re-tune the policy for a new fleet.

  PYTHONPATH=src python examples/policy_explorer.py [--apps 500]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import (FixedKeepAlivePolicy, HybridConfig, evaluate,
                        generate_trace, pareto_frontier, simulate)
from repro.core.histogram import HistogramConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", type=int, default=500)
    ap.add_argument("--days", type=float, default=7.0)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    trace = generate_trace(args.apps, days=args.days, seed=args.seed)
    points = []
    for ka in (10, 30, 60, 120, 240):
        points.append(evaluate(f"fixed-{ka}m",
                               simulate(trace, FixedKeepAlivePolicy(ka))))
    for rng in (60, 120, 240):
        for cv in (0.5, 2.0, 4.0):
            cfg = HybridConfig(
                histogram=HistogramConfig(range_minutes=float(rng)),
                cv_threshold=cv, use_arima=False)
            points.append(evaluate(f"hyb-r{rng}-cv{cv:g}",
                                   simulate(trace, cfg)))
    for head, tail in ((0, 100), (5, 99), (10, 95)):
        cfg = HybridConfig(histogram=HistogramConfig(
            head_percentile=head, tail_percentile=tail), use_arima=False)
        points.append(evaluate(f"hyb-cut[{head},{tail}]",
                               simulate(trace, cfg)))

    base = next(p for p in points if p.name == "fixed-10m").wasted_memory
    frontier = {p.name for p in pareto_frontier(points)}
    print(f"{'policy':>18s} {'cold% p75':>10s} {'rel.mem':>8s}  pareto")
    for p in sorted(points, key=lambda p: p.wasted_memory):
        star = "  *" if p.name in frontier else ""
        print(f"{p.name:>18s} {p.cold_pct_p75:>9.1f}% "
              f"{p.wasted_memory / base:>7.2f}x{star}")


if __name__ == "__main__":
    main()
