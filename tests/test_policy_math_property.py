"""Hypothesis property tests for the single-source policy math.

Gated on the ``hypothesis`` import exactly like ``tests/test_property.py``
(requirements-dev.txt installs it in CI; absent locally these skip).

Covered invariants:
  * the scaled integer percentile threshold equals the exact rational
    ``ceil(total * pct / 100)`` for every dtype-free input;
  * the bisect (gather) and reduction (Pallas/numpy) forms of the
    percentile-bin search agree, and the search is monotone in the
    percentile — tail windows never undercut head windows;
  * window values are well-ordered (0 <= load_at <= unload_at <= inflated
    range);
  * warm/cold verdicts and loaded-idle waste are invariant under time
    translation — the property per-chunk rebasing relies on — checked
    end-to-end through the scalar engine.
"""
import math
from fractions import Fraction

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp  # noqa: E402

from repro.core import policy_math  # noqa: E402
from repro.core.histogram import HistogramConfig  # noqa: E402
from repro.core.policy import (HybridConfig, HybridHistogramPolicy,  # noqa: E402
                               PolicyWindows)
from repro.core.simulator import simulate_scalar  # noqa: E402
from repro.core.workload import Trace  # noqa: E402


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 200_000),
       st.sampled_from([0.0, 1.0, 5.0, 25.0, 50.0, 75.0, 99.0, 99.5, 100.0]))
def test_scaled_threshold_is_exact_ceil(total, pct):
    thr = policy_math.percentile_threshold_scaled(total, pct)
    exact = max(math.ceil(Fraction(total) * Fraction(policy_math.pct_numer(pct),
                                                     policy_math.PCT_SCALE)), 1)
    # cum hits the percentile iff cum*PCT_SCALE >= thr iff cum >= exact
    for cum in (exact - 1, exact, exact + 1):
        assert (cum * policy_math.PCT_SCALE >= int(thr)) == (cum >= exact)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=2, max_size=64),
       st.integers(1, 3000))
def test_first_bin_search_forms_agree(counts, raw_thr):
    cum = np.cumsum(np.asarray(counts, np.int64))[None, :]
    thr = np.asarray([raw_thr * policy_math.PCT_SCALE])
    want = policy_math.first_bin_ge_scaled(cum, thr, gather=False)  # numpy
    got_bisect = policy_math.first_bin_ge_scaled(
        jnp.asarray(cum, jnp.int32), jnp.asarray(thr, jnp.int32), gather=True)
    got_reduce = policy_math.first_bin_ge_scaled(
        jnp.asarray(cum, jnp.int32), jnp.asarray(thr, jnp.int32), gather=False)
    naive = np.flatnonzero(cum[0] >= raw_thr)
    naive = int(naive[0]) if len(naive) else cum.shape[-1]
    assert int(want[0]) == int(got_bisect[0]) == int(got_reduce[0]) == naive


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(0, 20), min_size=2, max_size=64),
       st.sampled_from([0.0, 5.0, 50.0, 99.0]),
       st.sampled_from([5.0, 75.0, 99.0, 100.0]))
def test_percentile_window_monotonicity(counts, pct_lo, pct_hi):
    """Higher percentile -> later (or equal) bin; derived windows ordered."""
    pct_lo, pct_hi = min(pct_lo, pct_hi), max(pct_lo, pct_hi)
    cum = np.cumsum(np.asarray(counts, np.int64))[None, :]
    total = int(cum[0, -1])
    bin_lo = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(total, pct_lo),
        gather=False)[0]
    bin_hi = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(total, pct_hi),
        gather=False)[0]
    assert bin_lo <= bin_hi
    load_at, unload_at = policy_math.window_values(
        int(bin_lo), int(bin_hi) + 1, bin_minutes=1.0,
        range_minutes=float(len(counts)), margin=0.10)
    assert 0.0 <= float(load_at) <= float(unload_at)
    assert float(unload_at) <= len(counts) * 1.1 + 1e-4


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 500.0), st.floats(0.0, 500.0), st.floats(0.0, 1000.0))
def test_bounds_verdicts_consistent(prewarm, keep, it):
    load_at, unload_at = policy_math.window_bounds(prewarm, keep)
    assert 0.0 <= float(load_at) <= float(unload_at)
    waste = float(policy_math.idle_from_bounds(it, load_at, unload_at))
    assert 0.0 <= waste <= keep + 1e-9
    if policy_math.warm_from_bounds(it, load_at, unload_at):
        assert waste <= it + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(1, 64 * 64), min_size=1, max_size=30),
       st.integers(0, 10_000 * 64))
def test_verdicts_invariant_under_time_translation(iat_units, shift_units):
    """Shifting a whole trace by a constant changes no decision — the
    property that makes per-chunk rebasing semantics-preserving."""
    iats = np.asarray(iat_units, np.float64) / 64.0
    shift = shift_units / 64.0
    t = np.concatenate([[0.0], np.cumsum(iats)])
    duration = float(t[-1] + 10.0)
    cfg = HybridConfig(histogram=HistogramConfig(range_minutes=48.0),
                       use_arima=False)

    def run(offset, dur):
        trace = Trace(specs=None, times=[t + offset], duration_minutes=dur)
        return simulate_scalar(trace, HybridHistogramPolicy(cfg))

    a = run(0.0, duration)
    b = run(shift, duration + shift)
    np.testing.assert_array_equal(a.cold, b.cold)
    np.testing.assert_array_equal(a.wasted_minutes, b.wasted_minutes)
    np.testing.assert_array_equal(a.final_prewarm, b.final_prewarm)
    np.testing.assert_array_equal(a.final_keep_alive, b.final_keep_alive)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.0, 200.0, allow_nan=False), min_size=1,
                max_size=50))
def test_scalar_windows_reconstruct_float32_bounds(values):
    """PolicyWindows(prewarm, keep) from the scalar path must reconstruct
    the float32 unload bound exactly: prewarm + keep == float64(unload_f32).
    This is what lets the float64 oracle agree with engines that carry the
    bounds directly."""
    p = HybridHistogramPolicy(HybridConfig(use_arima=False))
    p.on_invocation("a", None)
    w = PolicyWindows(0.0, 0.0)
    for v in values:
        w = p.on_invocation("a", float(v))
    ub = np.float64(w.prewarm) + np.float64(w.keep_alive)
    assert np.float32(w.prewarm) == np.float64(w.prewarm)
    assert np.float32(ub) == ub
