"""Property tests for the batched ARIMA grid fit.

Requires hypothesis (dev-only, like scipy); the whole module skips when
it is absent. Three contracts:

  * every fitted AR/MA pair lies in the shrunken stationarity /
    invertibility triangle, so the lag-polynomial roots are strictly
    inside the unit circle — the legacy scipy fit only had a soft
    ``|coef| <= 1.5`` guard and could return explosive models;
  * the batched Gauss-Newton optimum is never materially worse than the
    triangle-constrained scipy Nelder-Mead oracle (AIC within 4.0);
  * degenerate inputs are handled exactly: NaN series and too-short
    series invalidate every grid entry (the engines fall back to the
    standard keep-alive verdict), while a zero-variance series — the
    perfectly-periodic timer — stays valid and forecasts the constant.
"""
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.forecast import MAX_OBS, ORDER_GRID, fit_arima_grid, fit_window

RELAXED = settings(max_examples=25, deadline=None,
                   suppress_health_check=[HealthCheck.too_slow])


def _seeded_series(seed: int) -> np.ndarray:
    """A deterministic series family keyed by one integer: mixes AR,
    drift, periodicity and scale so the grid's branches all get visited
    across the example budget."""
    rng = np.random.default_rng(seed)
    length = int(rng.integers(5, MAX_OBS + 1))
    base = rng.uniform(1.0, 400.0)
    phi = rng.uniform(-0.8, 0.9)
    drift = rng.uniform(-2.0, 2.0)
    y = [base]
    for t in range(length - 1):
        y.append(base + phi * (y[-1] - base) + drift * t
                 + rng.normal(0.0, rng.uniform(0.01, 5.0)))
    return np.asarray(y, np.float32)


def _roots_inside_unit_circle(c1: float, c2: float) -> bool:
    """Roots of ``1 - c1 L - c2 L^2`` outside the unit circle, i.e. the
    companion roots of ``z^2 - c1 z - c2`` strictly inside it."""
    return bool(np.all(np.abs(np.roots([1.0, -c1, -c2])) < 1.0))


@RELAXED
@given(st.integers(0, 2 ** 31 - 1))
def test_fitted_models_are_stationary_and_invertible(seed):
    fit = fit_window(_seeded_series(seed))
    for i in range(len(ORDER_GRID)):
        if not bool(fit.valid[0, i]):
            continue
        a1, a2, b1, b2 = (float(c) for c in fit.coef[0, i])
        assert abs(a2) <= 0.98 + 1e-6 and abs(b2) <= 0.98 + 1e-6
        assert _roots_inside_unit_circle(a1, a2), (ORDER_GRID[i], a1, a2)
        assert _roots_inside_unit_circle(b1, b2), (ORDER_GRID[i], b1, b2)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(st.integers(0, 2 ** 31 - 1))
def test_batched_aic_tracks_scipy_oracle(seed):
    pytest.importorskip("scipy")
    from arima_oracle import fit_css_oracle

    y = _seeded_series(seed)
    fit = fit_window(y)
    for i, order in enumerate(ORDER_GRID):
        if not bool(fit.valid[0, i]):
            continue
        oracle = fit_css_oracle(np.asarray(y, float), order)
        if oracle is None:
            continue
        p, _, q = order
        # 4-coefficient orders have boundary optima fixed-iteration LM
        # does not always reach; see test_forecast_conformance.
        tol = 4.0 if p + q <= 3 else 12.0
        assert float(fit.aic[0, i]) <= oracle[0] + tol, \
            f"order {order}: batched {float(fit.aic[0, i])} vs " \
            f"oracle {oracle[0]}"


@RELAXED
@given(st.integers(0, 2 ** 31 - 1), st.integers(0, MAX_OBS - 1))
def test_nan_poisoned_series_invalidates_every_order(seed, nan_at):
    y = _seeded_series(seed)
    y[nan_at % len(y)] = np.nan
    fit = fit_window(y)
    assert not fit.valid.any()
    assert np.all(np.isinf(fit.aic))


@given(st.integers(0, 2))
@settings(max_examples=3, deadline=None)
def test_short_series_invalidates_every_order(length):
    fit = fit_window([100.0] * length)
    assert not fit.valid.any()


@RELAXED
@given(st.floats(0.5, 1e4, allow_nan=False),
       st.integers(4, MAX_OBS))
def test_zero_variance_series_forecasts_the_constant(value, length):
    """Perfectly-periodic timers must forecast their period exactly —
    the legacy SSE-floor contract, not a degenerate fallback."""
    v32 = np.float32(value)
    fit = fit_window([float(v32)] * length)
    for i, (p, d, q) in enumerate(ORDER_GRID):
        if not bool(fit.valid[0, i]):
            continue
        assert float(fit.pred[0, i]) == float(v32), (ORDER_GRID[i],)
    assert fit.valid.any()


def test_batched_rows_independent_of_neighbors():
    """A NaN row must not poison its batch neighbors (vmap rows are
    independent programs)."""
    good = _seeded_series(123)
    rows = np.zeros((2, MAX_OBS), np.float32)
    rows[0, :len(good)] = good
    rows[1, :4] = [1.0, np.nan, 3.0, 4.0]
    fit = fit_arima_grid(rows, [len(good), 4])
    alone = fit_arima_grid(rows[:1], [len(good)])
    np.testing.assert_array_equal(fit.aic[0], alone.aic[0])
    assert not fit.valid[1].any()
