"""Scipy CSS ARIMA oracle for the batched-fit conformance tests.

This is the legacy ``repro.core.arima`` implementation, kept out of the
library as a test-only reference (scipy is a dev dependency — import this
module only behind ``pytest.importorskip("scipy")``). Two deliberate
changes versus the retired library code make it a fair oracle for
:mod:`repro.forecast.arima_batched`:

  * the objective is minimized over coefficients projected into the same
    shrunken stationarity/invertibility triangle (``|c2| <= 0.98``,
    ``|c1| <= 0.98 * (1 - c2)``) the batched Gauss-Newton uses — the old
    soft ``|coef| <= 1.5`` guard lets Nelder-Mead wander into
    non-invertible optima the batched fit is explicitly barred from;
  * the series is centered by the mean of the differenced window and the
    AIC uses the same ``m * log(max(sse, 1e-12) / m) + 2k`` form, so AIC
    values are directly comparable.

Multi-start Nelder-Mead keeps the oracle honest on MA-heavy orders where
a single zero start stalls in the flat region around the origin.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import numpy as np
from scipy import optimize

COEF_BOUND = 0.98
SSE_FLOOR = 1e-12


def project_triangle(c1: float, c2: float) -> Tuple[float, float]:
    c2 = min(max(c2, -COEF_BOUND), COEF_BOUND)
    lim = COEF_BOUND * (1.0 - c2)
    return min(max(c1, -lim), lim), c2


def css_residuals(wc: np.ndarray, ar: np.ndarray, ma: np.ndarray) -> np.ndarray:
    """Zero-pre-sample CSS residuals on the centered differenced series."""
    a = np.zeros(2)
    a[:len(ar)] = ar
    b = np.zeros(2)
    b[:len(ma)] = ma
    e = np.zeros(len(wc))
    w1 = w2 = e1 = e2 = 0.0
    for t, x in enumerate(wc):
        e[t] = x - (a[0] * w1 + a[1] * w2 + b[0] * e1 + b[1] * e2)
        w1, w2 = x, w1
        e1, e2 = e[t], e1
    return e


def fit_css_oracle(y, order: Tuple[int, int, int]
                   ) -> Optional[Tuple[float, float]]:
    """Constrained scipy CSS fit of one order; returns ``(aic, pred)``.

    ``None`` when the series is too short for the order — the same
    length gate as the batched fit.
    """
    p, d, q = order
    y = np.asarray(y, float)
    n = len(y)
    w = np.diff(y, n=d) if d > 0 else y.copy()
    m = len(w)
    if n < d + max(p, q) + 2 or m < p + q + 1:
        return None
    mu = float(np.mean(w))
    wc = w - mu

    def unpack(theta):
        a1, a2 = project_triangle(theta[0] if p >= 1 else 0.0,
                                  theta[1] if p >= 2 else 0.0)
        b1, b2 = project_triangle(theta[2] if q >= 1 else 0.0,
                                  theta[3] if q >= 2 else 0.0)
        return np.array([a1, a2][:max(p, 0)] if p else []), \
            np.array([b1, b2][:max(q, 0)] if q else [])

    def objective(theta):
        ar, ma = unpack(theta)
        e = css_residuals(wc, ar, ma)
        return float(np.sum(e * e))

    best_theta = np.zeros(4)
    best_sse = objective(best_theta)
    if p + q > 0:
        r1 = 0.0
        denom = float(np.sum(wc * wc))
        if denom > SSE_FLOOR:
            r1 = float(np.clip(np.sum(wc[1:] * wc[:-1]) / denom, -0.9, 0.9))
        for start in (np.zeros(4),
                      np.array([r1, 0.0, r1, 0.0]),
                      np.array([0.5, 0.0, -0.5, 0.0]),
                      np.array([-0.5, 0.0, 0.5, 0.0])):
            res = optimize.minimize(
                objective, start, method="Nelder-Mead",
                options={"maxiter": 400 * (p + q),
                         "xatol": 1e-6, "fatol": 1e-10})
            if res.fun < best_sse:
                best_sse = float(res.fun)
                best_theta = res.x
    ar, ma = unpack(best_theta)
    e = css_residuals(wc, ar, ma)
    sse = max(float(np.sum(e * e)), SSE_FLOOR)
    k = p + q + 1
    aic = m * math.log(sse / m) + 2.0 * k

    lags_w = [wc[-1] if m >= 1 else 0.0, wc[-2] if m >= 2 else 0.0]
    lags_e = [e[-1] if m >= 1 else 0.0, e[-2] if m >= 2 else 0.0]
    a = np.zeros(2)
    a[:len(ar)] = ar
    b = np.zeros(2)
    b[:len(ma)] = ma
    pred_w = mu + a[0] * lags_w[0] + a[1] * lags_w[1] \
        + b[0] * lags_e[0] + b[1] * lags_e[1]
    pred = float(y[-1] + pred_w) if d == 1 else float(pred_w)
    return aic, pred
