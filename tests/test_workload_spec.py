"""WorkloadSpec scenario API: distribution faithfulness of the cohort
samplers against the paper's §3 anchors, seed-determinism and
chunk-size-invariance of the one vectorized engine, scenario semantics
(flash crowd / weekend dip / timer mix), and the tiny trace x policy grid
smoke that CI runs so the (T, S) path cannot rot.
"""
import numpy as np
import pytest

import jax.tree_util as tree_util

from repro.core.experiment import FixedSpec, HybridSpec, run, sweep
from repro.core.workload import (MINUTES_PER_DAY, PATTERNS, Trace,
                                 generate_trace)
from repro.core import workload as wl
from repro.core import workload_spec as ws
from repro.core.workload_spec import (SCENARIOS, Cohort, WorkloadSpec,
                                      azure_like, bursty, flash_crowd,
                                      materialize_loop, scenario, timer_heavy,
                                      weekend_dip)


# --- distribution faithfulness: cohort samplers vs the paper's anchors -------


@pytest.fixture(scope="module")
def population():
    rng = np.random.default_rng(123)
    return ws._sample_population(rng, 6000, Cohort())


def test_rate_marginal_matches_fig5_anchors(population):
    """Fig. 5(a): 45% of apps <= 1/hour, 81% <= 1/minute, ~8 orders of
    magnitude end to end (timer snapping moves mass only within its band)."""
    rates = population["rates"]
    assert np.mean(rates <= 24.0) == pytest.approx(0.45, abs=0.06)
    assert np.mean(rates <= MINUTES_PER_DAY) == pytest.approx(0.81, abs=0.05)
    assert rates.max() / rates.min() > 1e6


def test_memory_marginal_matches_burr_quantiles(population):
    """Fig. 8 Burr XII fit: sampled quantiles match the analytic inverse
    CDF x_p = lambda * ((1-p)^(-1/k) - 1)^(1/c) at fixed percentiles."""
    mem = population["memory"]
    for p in (25.0, 50.0, 75.0, 95.0):
        want = wl.MEM_BURR_LAMBDA * (
            (1.0 - p / 100.0) ** (-1.0 / wl.MEM_BURR_K) - 1.0
        ) ** (1.0 / wl.MEM_BURR_C)
        got = np.percentile(mem, p)
        assert got == pytest.approx(want, rel=0.12), (p, got, want)


def test_exec_marginal_matches_lognormal_quantiles(population):
    """Fig. 7 lognormal(mu=-0.38, sigma=2.36) seconds: quantiles of the log
    samples sit on mu + sigma * z_p."""
    logs = np.log(population["execs"])
    assert logs.mean() == pytest.approx(wl.EXEC_LOG_MEAN, abs=0.12)
    assert logs.std() == pytest.approx(wl.EXEC_LOG_SIGMA, rel=0.05)
    # z-scores for 25/75/95th percentiles
    for p, z in ((25.0, -0.67449), (75.0, 0.67449), (95.0, 1.64485)):
        want = wl.EXEC_LOG_MEAN + wl.EXEC_LOG_SIGMA * z
        assert np.percentile(logs, p) == pytest.approx(want, abs=0.25)


def test_trigger_marginals_match_fig3(population):
    trig = population["trig"]
    combos = [wl._TRIGGER_COMBOS[i] for i in trig]
    http = np.mean([("http" in c) for c in combos])
    timer = np.mean([("timer" in c) for c in combos])
    assert http == pytest.approx(0.6407, abs=0.05)
    assert timer == pytest.approx(0.2915, abs=0.05)


def test_rate_band_cohort_truncates_the_cdf():
    rng = np.random.default_rng(7)
    pop = ws._sample_population(
        rng, 2000, Cohort(rate_log10_min=0.0, rate_log10_max=2.0))
    rates = pop["rates"]
    # timer snapping can nudge rates to the nearest round period, so allow
    # one snapping notch of slack around the band
    assert rates.min() >= 10.0 ** 0.0 / 1.5
    assert rates.max() <= 10.0 ** 2.0 * 1.5
    assert len(np.unique(np.round(np.log10(rates), 2))) > 50


def test_pattern_mix_is_rate_conditioned(population):
    """Low-rate apps are predominantly bursty HTTP; high-rate apps lean
    Poisson/machine (Sections 3.2-3.3)."""
    rates, pat = population["rates"], population["pattern"]
    low, high = rates <= 24.0, rates > MINUTES_PER_DAY
    assert np.mean(pat[low] == PATTERNS.index("bursty")) > 0.5
    assert (np.mean(pat[high] == PATTERNS.index("poisson"))
            > np.mean(pat[low] == PATTERNS.index("poisson")))


# --- engine determinism / invariance ----------------------------------------


def test_materialize_is_seed_deterministic_and_spec_pure():
    spec = azure_like(3000, days=2.0, seed=5, max_events=32)
    a, b = spec.materialize(), spec.materialize()
    pa, ca = a.to_padded()
    pb, cb = b.to_padded()
    np.testing.assert_array_equal(pa, pb)
    np.testing.assert_array_equal(ca, cb)
    other = azure_like(3000, days=2.0, seed=6, max_events=32).materialize()
    assert not np.array_equal(other.to_padded()[1], ca)


def test_population_columns_replay_eager_appspecs():
    """Generation blocks are aligned to absolute app indices with a counter
    RNG per block, so replaying ONLY the population draw
    (``population_columns``, the columnar AppTable path) is bit-identical
    to the values an eager materialization writes into AppSpec objects."""
    from repro.core.workload_spec import population_columns
    spec = azure_like(700, days=1.0, seed=3, max_events=16)
    cols = population_columns(spec)
    eager = spec.materialize(eager=True)
    np.testing.assert_array_equal(
        cols["execs"], [s.exec_time_s for s in eager.specs])
    np.testing.assert_array_equal(
        cols["memory"], [s.memory_mb for s in eager.specs])
    np.testing.assert_array_equal(
        cols["rates"], [s.rate_per_day for s in eager.specs])
    # uniform specs carry no population — the columnar path says so loudly
    with pytest.raises(ValueError, match="patterns"):
        population_columns(WorkloadSpec.uniform(10))


def test_eager_and_padded_share_population_blocks():
    """Eager materialization of the same spec yields the same app count,
    deterministic AppSpecs, and events inside the window."""
    spec = azure_like(500, days=1.0, seed=2, max_events=24, min_events=1)
    t1 = spec.materialize(eager=True)
    t2 = spec.materialize(eager=True)
    assert t1.n_apps == 500 and t1.specs is not None
    assert [s.app_id for s in t1.specs][:3] == ["app-000000", "app-000001",
                                                "app-000002"]
    for i in (0, 250, 499):
        np.testing.assert_array_equal(t1.times[i], t2.times[i])
        assert t1.specs[i] == t2.specs[i]
        assert len(t1.times[i]) >= 1
        assert np.all((t1.times[i] >= 0) & (t1.times[i] < spec.duration_minutes))
        # pattern-mode events respect the dataset's 1-minute binning
        assert np.all(np.diff(t1.times[i]) >= 1.0 - 1e-9)


def test_zero_event_apps_allowed_by_default():
    t = WorkloadSpec.uniform(200, days=0.05, seed=1, max_events=8).materialize()
    _, counts = t.to_padded()
    assert counts.min() == 0                      # the old >=1 clamp is gone
    t1 = WorkloadSpec.uniform(200, days=0.05, seed=1, max_events=8,
                              min_events=1).materialize()
    assert t1.to_padded()[1].min() >= 1


def test_uniform_is_padded_only():
    with pytest.raises(ValueError, match="padded-only"):
        WorkloadSpec.uniform(10).materialize(eager=True)


def test_spec_pytree_roundtrip_and_mix():
    spec = WorkloadSpec.mix(
        [Cohort(name="a", weight=3.0), Cohort(name="b", weight=1.0,
                                              rate_log10_min=2.0)],
        n_apps=100, days=3.0, seed=9, label="mixed")
    leaves, treedef = tree_util.tree_flatten(spec)
    assert tree_util.tree_unflatten(treedef, leaves) == spec
    assert spec.name == "mixed"
    segs = ws._cohort_segments(spec.n_apps, spec.cohorts)
    assert [(hi - lo) for _, lo, hi in segs] == [75, 25]
    with pytest.raises(ValueError, match="unknown scenario"):
        scenario("nope")
    assert scenario("bursty", 50, days=1.0).n_apps == 50


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="days"):
        WorkloadSpec(n_apps=1, days=0.0).materialize()
    with pytest.raises(ValueError, match="generator"):
        dataclass_replace = WorkloadSpec(n_apps=1, generator="nope")
        dataclass_replace.materialize()
    with pytest.raises(ValueError, match="weight"):
        WorkloadSpec.mix([Cohort(weight=0.0)], n_apps=1).materialize()
    with pytest.raises(ValueError, match="probability vector"):
        WorkloadSpec.mix([Cohort(pattern_probs=(1.0,))], n_apps=1).materialize()
    with pytest.raises(ValueError, match="diurnal_amplitude"):
        WorkloadSpec(n_apps=1, diurnal_amplitude=2.0).materialize()


# --- scenario semantics ------------------------------------------------------


def test_timer_heavy_is_low_cv_and_bursty_is_high_cv():
    def cvs(trace):
        out = []
        for i in range(trace.n_apps):
            ia = trace.iats(i)
            if len(ia) >= 5:
                out.append(np.std(ia) / max(np.mean(ia), 1e-9))
        return np.asarray(out)

    cv_timer = cvs(timer_heavy(300, days=3.0, seed=1,
                               max_events=48).materialize())
    cv_burst = cvs(bursty(300, days=3.0, seed=1, max_events=48).materialize())
    assert np.mean(cv_timer < 0.1) > 0.35
    assert np.mean(cv_burst > 1.0) > 0.5
    assert np.mean(cv_burst > 1.0) > np.mean(cv_timer > 1.0)


def test_multi_timer_covers_full_window_despite_slot_split():
    """Regression: each of the two merged timers owns only max_ev//2+1
    slots. With asymmetric periods the faster timer can pass the combined
    count guard yet overrun its own half — it must be rate-capped
    (period-stretched), never silently truncated mid-window. Truncation
    shows up as an event-density cliff: the faster timer goes dark for the
    window tail while the slow one keeps ticking."""
    rng = np.random.default_rng(0)
    g, duration, max_ev = 40, 2880.0, 64
    # per1 = 64 -> the fast timer needs ~46 slots; apps whose period ratio
    # lands near 3 used to pass the combined <= max_ev guard unstretched
    pop = dict(rates=np.full(g, 45.0), pattern=np.full(g, 1, np.int32),
               period=np.full(g, 32.0))
    frame, counts = ws._gen_patterns_block(rng, pop, duration, max_ev,
                                           warp=None, min_events=0)
    assert counts.min() >= 4
    q = duration / 4.0
    finite = np.isfinite(frame)
    first_q = (finite & (frame < q)).sum(axis=1)
    last_q = (finite & (frame >= 3.0 * q)).sum(axis=1)
    # timers are periodic: per-app density must not collapse in the tail
    # (pre-fix, truncated apps showed last/first ratios of ~0.25)
    assert np.all(last_q >= 0.4 * first_q), (last_q / np.maximum(first_q, 1))


def test_flash_crowd_concentrates_events():
    spec = flash_crowd(400, days=1.0, seed=4, max_events=64)
    t = spec.materialize()
    padded, counts = t.to_padded()
    ev = padded[np.isfinite(padded)]
    lo, hi = spec.flash_start, spec.flash_start + spec.flash_duration
    in_window = np.mean((ev >= lo) & (ev < hi))
    base_rate = (hi - lo) / t.duration_minutes
    assert in_window > 2.0 * base_rate       # the window runs far hotter


def test_weekend_dip_reduces_weekend_share():
    def share(spec):
        padded, _ = spec.materialize().to_padded()
        ev = padded[np.isfinite(padded)]
        day = (ev // MINUTES_PER_DAY).astype(np.int64) % 7
        return np.mean(day >= 5)

    dipped = share(weekend_dip(400, days=14.0, seed=4, max_events=64))
    flat = share(azure_like(400, days=14.0, seed=4, max_events=64))
    # timers keep firing on weekends; the warped (human) traffic dips
    assert dipped < 0.75 * (2.0 / 7.0)
    assert dipped < 0.75 * flat


def test_loop_baseline_agrees_distributionally():
    """The per-app Python baseline (benchmarks/trace_gen.py) is the same
    workload class: comparable total event mass and per-app count spread."""
    spec = azure_like(400, days=2.0, seed=8, max_events=32)
    fast = spec.materialize()
    slow = materialize_loop(spec)
    cf, cs = fast.to_padded()[1], slow.to_padded()[1]
    assert cs.shape == cf.shape
    assert np.abs(cf.mean() - cs.mean()) / max(cs.mean(), 1e-9) < 0.35
    with pytest.raises(ValueError, match="patterns"):
        materialize_loop(WorkloadSpec.uniform(10))


# --- the (T, S) smoke CI runs ------------------------------------------------


def test_scenario_grid_smoke():
    """Tiny sweep(traces=scenarios, specs=grid): every scenario library
    entry materializes, sweeps against a mixed policy grid, and each cell
    matches its single-trace run()."""
    traces = [SCENARIOS[name](60, days=1.0, seed=1, max_events=16)
              for name in sorted(SCENARIOS) if name != "weekend_dip"]
    traces.append(weekend_dip(60, days=2.0, seed=1, max_events=16))
    grid = [FixedSpec(10.0), HybridSpec(range_minutes=48.0, use_arima=False)]
    res = sweep(traces=traces, specs=grid)
    assert res.shape == (len(traces), len(grid))
    assert [p.name for p in res.points()[0]] == ["fixed-10m", "hybrid-48m"]
    for t, spec in enumerate(traces):
        one = run(spec.materialize(), grid[1])
        np.testing.assert_array_equal(res.row(t, 1).cold, one.cold)
        np.testing.assert_array_equal(res.row(t, 1).wasted_minutes,
                                      one.wasted_minutes)
