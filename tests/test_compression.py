"""Gradient compression (int8 + error feedback) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (Compressed, ErrorFeedback,
                                           compress, decompress)


def test_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.02, (1000,)), jnp.float32)
    c = compress(x)
    y = decompress(c, x.shape)
    # int8 symmetric: relative block error bounded by ~1/127
    assert float(jnp.abs(x - y).max()) <= float(jnp.abs(x).max()) / 127 + 1e-8


def test_compression_ratio():
    x = jnp.ones((4096,), jnp.float32)
    c = compress(x)
    payload = c.q.size * 1 + c.scale.size * 4
    assert payload < 0.3 * x.size * 4      # ~4x smaller than f32


def test_error_feedback_unbiased_accumulation():
    """Sum of compressed grads + final residual == sum of true grads."""
    rng = np.random.default_rng(1)
    grads = [jnp.asarray(rng.normal(0, 0.1, (300,)), jnp.float32)
             for _ in range(20)]
    residual = ErrorFeedback.init({"g": grads[0]})
    acc = jnp.zeros((300,))
    for g in grads:
        g_hat, residual = ErrorFeedback.apply({"g": g}, residual)
        acc = acc + g_hat["g"]
    true = sum(np.asarray(g) for g in grads)
    # error feedback: accumulated compressed updates track the true sum to
    # within one step's quantization error
    np.testing.assert_allclose(np.asarray(acc + residual["g"]), true,
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(acc - true).max()) < 0.01


def test_error_feedback_sgd_converges():
    """Quadratic optimization with compressed grads still converges."""
    w = jnp.asarray([5.0, -3.0, 2.0])
    residual = ErrorFeedback.init({"w": w})
    for _ in range(300):
        g = {"w": w}                      # grad of ||w||^2/2
        g_hat, residual = ErrorFeedback.apply(g, residual)
        w = w - 0.05 * g_hat["w"]
    assert float(jnp.abs(w).max()) < 0.05
