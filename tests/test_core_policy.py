"""Unit tests for the paper's core: histogram, windows, policies, ARIMA."""
import numpy as np
import pytest

from repro.core.arima import ArimaForecaster, auto_arima, fit_arima
from repro.core.histogram import AppHistogram, HistogramConfig
from repro.core.policy import (FixedKeepAlivePolicy, HybridConfig,
                               HybridHistogramPolicy, NoUnloadingPolicy,
                               PolicyWindows, is_warm, loaded_idle_time)
from repro.core.welford import CVState


def test_histogram_windows_concentrated():
    """All ITs in one bin -> prewarm just below it, keep-alive tight."""
    cfg = HistogramConfig()
    h = AppHistogram(cfg)
    for _ in range(100):
        h.record(30.5)   # bin 30
    pw, ka = h.windows()
    assert pw == pytest.approx(30 * 0.9)          # head bin 30, -10%
    assert pw + ka == pytest.approx(31 * 1.1)     # tail bin 31 (upper), +10%


def test_histogram_percentile_rounding():
    """Head rounds down to the bin lower edge, tail up to the upper edge."""
    cfg = HistogramConfig(margin=0.0)
    h = AppHistogram(cfg)
    for v in [5.2] * 50 + [90.7] * 50:
        h.record(v)
    pw, ka = h.windows()
    assert pw == 5.0          # 5th pct in bin 5 -> lower edge
    assert pw + ka == 91.0    # 99th pct in bin 90 -> upper edge 91


def test_histogram_oob():
    cfg = HistogramConfig(range_minutes=60.0)
    h = AppHistogram(cfg)
    for v in [10.0, 30.0, 100.0, 500.0, 70.0]:
        h.record(v)
    assert h.total == 2
    assert h.oob == 3
    assert h.oob_fraction == pytest.approx(0.6)


def test_welford_cv_matches_direct():
    cfg = HistogramConfig(range_minutes=50.0)
    h = AppHistogram(cfg)
    rng = np.random.default_rng(0)
    for v in rng.uniform(0, 50, 200):
        h.record(float(v))
    direct = np.std(h.counts) / np.mean(h.counts)
    assert h.cv == pytest.approx(float(direct), rel=1e-9)


def test_cvstate_incremental():
    s = CVState(n_bins=10)
    counts = np.zeros(10)
    rng = np.random.default_rng(1)
    for _ in range(100):
        b = rng.integers(0, 10)
        s.update(counts[b])
        counts[b] += 1
    assert s.cv == pytest.approx(float(np.std(counts) / np.mean(counts)),
                                 rel=1e-9)


def test_is_warm_semantics():
    w = PolicyWindows(prewarm=10.0, keep_alive=20.0)
    assert not is_warm(5.0, w)       # arrived before pre-warm: cold
    assert is_warm(10.0, w)
    assert is_warm(30.0, w)
    assert not is_warm(31.0, w)      # after keep-alive expiry: cold
    w0 = PolicyWindows(prewarm=0.0, keep_alive=20.0)
    assert is_warm(0.5, w0)
    assert not is_warm(21.0, w0)


def test_loaded_idle_time():
    w = PolicyWindows(prewarm=10.0, keep_alive=20.0)
    assert loaded_idle_time(5.0, w) == 0.0         # never loaded
    assert loaded_idle_time(15.0, w) == 5.0        # loaded at 10, hit at 15
    assert loaded_idle_time(100.0, w) == 20.0      # full keep-alive wasted
    w0 = PolicyWindows(prewarm=0.0, keep_alive=20.0)
    assert loaded_idle_time(5.0, w0) == 5.0
    assert loaded_idle_time(100.0, w0) == 20.0


def test_fixed_policy_constant():
    p = FixedKeepAlivePolicy(10.0)
    w = p.on_invocation("a", None)
    assert w == PolicyWindows(0.0, 10.0)
    assert p.on_invocation("a", 55.0) == w


def test_no_unloading():
    p = NoUnloadingPolicy()
    w = p.windows("x")
    assert w.prewarm == 0.0 and w.keep_alive == float("inf")


def test_hybrid_cold_start_then_learn():
    """Few samples -> standard keep-alive; concentrated ITs -> histogram."""
    cfg = HybridConfig(use_arima=False)
    p = HybridHistogramPolicy(cfg)
    w = p.on_invocation("a", None)
    assert w.prewarm == 0.0
    assert w.keep_alive == cfg.histogram.range_minutes
    for _ in range(50):
        w = p.on_invocation("a", 30.0)
    assert w.prewarm == pytest.approx(30 * 0.9)
    assert w.prewarm > 0.0


def test_hybrid_flat_histogram_falls_back():
    """Uniformly spread ITs -> low CV -> standard keep-alive."""
    cfg = HybridConfig(use_arima=False)
    p = HybridHistogramPolicy(cfg)
    p.on_invocation("a", None)
    for it in np.linspace(1, 239, 120):
        w = p.on_invocation("a", float(it))
    assert w.prewarm == 0.0
    assert w.keep_alive == cfg.histogram.range_minutes


def test_hybrid_state_roundtrip():
    cfg = HybridConfig()
    p = HybridHistogramPolicy(cfg)
    p.on_invocation("a", None)
    for it in [5, 5, 6, 5, 7, 5]:
        p.on_invocation("a", float(it))
    sd = p.state_dict()
    q = HybridHistogramPolicy(cfg)
    q.load_state_dict(sd)
    assert q.windows("a") == p.windows("a")
    assert q.on_invocation("a", 5.0) == p.on_invocation("a", 5.0)


# --- ARIMA ------------------------------------------------------------------

def test_arima_fits_ar1():
    rng = np.random.default_rng(0)
    y = [0.0]
    for _ in range(60):
        y.append(0.8 * y[-1] + rng.normal(0, 0.1))
    m = fit_arima(np.asarray(y) + 10.0, (1, 0, 0))
    assert m is not None
    assert m.ar[0] == pytest.approx(0.8, abs=0.15)


def test_arima_forecast_trend():
    y = np.arange(20, dtype=float) * 2.0 + 5.0   # linear trend
    m = auto_arima(y)
    assert m is not None
    pred = m.forecast(y)
    assert pred == pytest.approx(45.0, abs=3.0)


def test_arima_forecaster_periodic():
    f = ArimaForecaster()
    for _ in range(12):
        f.observe(300.0)   # constant 5-hour ITs
    pred = f.forecast()
    assert pred is not None
    assert pred == pytest.approx(300.0, rel=0.1)


def test_hybrid_uses_arima_for_oob_apps():
    """App with 6-hour ITs (beyond 4h range) gets ARIMA windows."""
    cfg = HybridConfig(use_arima=True)
    p = HybridHistogramPolicy(cfg)
    p.on_invocation("a", None)
    for _ in range(10):
        w = p.on_invocation("a", 360.0)
    # ARIMA path: prewarm ~ 0.85 * 360, keep-alive ~ 0.3 * 360
    assert 250 < w.prewarm < 360
    assert 50 < w.keep_alive < 160
