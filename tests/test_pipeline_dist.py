"""Multi-device distribution tests (pipeline parallelism, distributed
flash-decode). These need >1 device, so they run in a subprocess with
forced host devices — the main pytest process keeps its single-device view.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_pipeline_matches_sequential():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get, reduced
        from repro.models import build
        from repro.distributed import ctx

        cfg = reduced(get('smollm-135m')).with_(remat=False, n_layers=2)
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
        ref = m.forward(params, toks)
        def loss_ref(p):
            return (m.forward(p, toks).astype(jnp.float32) ** 2).mean()
        g_ref = jax.grad(loss_ref)(params)

        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 2, 2),
                    ('pod', 'data', 'model'))
        m_pp = build(cfg.with_(pipeline_stages=2, pipeline_microbatches=4))
        with ctx.use_mesh(mesh), mesh:
            out = jax.jit(m_pp.forward)(params, toks)
            def loss(p):
                return (m_pp.forward(p, toks).astype(jnp.float32) ** 2).mean()
            g = jax.jit(jax.grad(loss))(params)
        assert float(jnp.abs(ref - out).max()) < 1e-5
        errs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), g, g_ref)
        assert max(jax.tree.leaves(errs)) < 1e-6
        print('PIPELINE_OK')
    """)
    assert "PIPELINE_OK" in out


def test_distributed_flash_decode_matches():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs import get, reduced
        from repro.models import build
        from repro.distributed import ctx, dist_decode

        cfg = reduced(get('qwen2-72b')).with_(remat=False)
        m = build(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
        logits, cache = m.prefill(params, toks, max_len=64)
        lg_ref, cache_ref = m.decode_step(params, toks[:, 0], cache)

        mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                    ('data', 'model'))
        dist_decode.ENABLED = True
        with ctx.use_mesh(mesh), mesh:
            lg, cache2 = jax.jit(m.decode_step)(params, toks[:, 0], cache)
        dist_decode.ENABLED = False
        assert float(jnp.abs(lg_ref - lg).max()) < 1e-4
        assert float(jnp.abs(cache_ref['k'] - cache2['k']).max()) < 1e-4
        # decode a few more steps distributed: stays finite & consistent
        with ctx.use_mesh(mesh), mesh:
            dist_decode.ENABLED = True
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            for _ in range(3):
                lg, cache2 = jax.jit(m.decode_step)(params, t, cache2)
                t = jnp.argmax(lg, -1).astype(jnp.int32)
            dist_decode.ENABLED = False
        assert bool(jnp.all(jnp.isfinite(lg)))
        print('DIST_DECODE_OK')
    """)
    assert "DIST_DECODE_OK" in out
