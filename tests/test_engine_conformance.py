"""Differential conformance harness across the four hybrid-policy engines.

Engines under test (all routed through ``repro.core.policy_math`` and the
``repro.core.experiment.run`` front door):

  * ``engine="scalar"``     — float64 event-driven oracle
  * ``engine="fused"``      — float64 factored lax.scan sweep engine
  * ``engine="pallas"``     — float32 sweep TPU kernel (interpret on CPU),
                              SMEM config block via scalar prefetch
  * ``engine="reference"``  — float32 legacy per-step-cumsum engine

Assertions: exact cold-count, invocation, and final-window parity for every
engine; waste is bit-exact for the float64 engine (same accumulation order
as the oracle) and machine-precision-close for the float32 engines (their
per-gap terms accumulate in float32).

The traces (see ``golden_traces``) include a two-week trace with
sub-millisecond inter-arrivals — absolute timestamps beyond float32 — which
the float32 engines only survive because of per-chunk time rebasing, plus
OOB-heavy and sub-``min_samples`` apps that exercise every decision-gate
branch. This suite is also run by CI under ``JAX_ENABLE_X64=0`` to emulate
TPU's float64-free numerics.
"""
import numpy as np
import pytest

from repro.core.experiment import EngineOptions, HybridSpec, run
from repro.core.policy import HybridConfig, HybridHistogramPolicy
from repro.core.simulator import simulate_scalar

from golden_traces import (CFG48, bursty_subms_multiweek, coarse_twoweek,
                           synthesized_small, GOLDEN_TRACES)


def _run(t, cfg, engine, **opts):
    return run(t, HybridSpec.from_config(cfg), engine=engine,
               options=EngineOptions(**opts))


# name -> (runner, waste is bit-exact vs the float64 oracle)
ENGINES = {
    "jnp_f64": (lambda t, cfg: _run(t, cfg, "fused"), True),
    "jnp_f64_chunked": (lambda t, cfg: _run(t, cfg, "fused", app_chunk=7),
                        True),
    "pallas_f32": (lambda t, cfg: _run(t, cfg, "pallas", app_chunk=16),
                   False),
    "reference_f32": (lambda t, cfg: _run(t, cfg, "reference"), False),
}

TRACES = {
    "bursty_subms_multiweek": bursty_subms_multiweek,
    "coarse_twoweek": coarse_twoweek,
    "synthesized_small": synthesized_small,
}


@pytest.fixture(scope="module", params=sorted(TRACES))
def case(request):
    name = request.param
    trace = TRACES[name]()
    cfg = GOLDEN_TRACES[name][1]
    oracle = simulate_scalar(trace, HybridHistogramPolicy(cfg))
    return name, trace, cfg, oracle


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_engine_conformance(case, engine):
    name, trace, cfg, oracle = case
    runner, waste_exact = ENGINES[engine]
    got = runner(trace, cfg)
    err = f"{engine} vs scalar oracle on {name}"
    np.testing.assert_array_equal(got.invocations, oracle.invocations,
                                  err_msg=err)
    np.testing.assert_array_equal(got.cold, oracle.cold, err_msg=err)
    # the float32 decision layer is dtype-invariant: windows match exactly
    np.testing.assert_array_equal(got.final_prewarm, oracle.final_prewarm,
                                  err_msg=err)
    np.testing.assert_array_equal(got.final_keep_alive,
                                  oracle.final_keep_alive, err_msg=err)
    if waste_exact:
        np.testing.assert_array_equal(got.wasted_minutes,
                                      oracle.wasted_minutes, err_msg=err)
    else:
        np.testing.assert_allclose(got.wasted_minutes, oracle.wasted_minutes,
                                   rtol=1e-5, atol=1e-3, err_msg=err)


def test_float32_engines_agree_exactly():
    """The two float32 engines share the math AND the dtype: identical
    results bit-for-bit, waste included."""
    trace = coarse_twoweek()
    a = _run(trace, CFG48, "pallas", app_chunk=16)
    b = _run(trace, CFG48, "reference")
    np.testing.assert_array_equal(a.cold, b.cold)
    np.testing.assert_array_equal(a.final_prewarm, b.final_prewarm)
    np.testing.assert_array_equal(a.final_keep_alive, b.final_keep_alive)
    np.testing.assert_allclose(a.wasted_minutes, b.wasted_minutes, rtol=1e-6)


def test_time_translation_invariance_batched():
    """The property per-chunk rebasing relies on: shifting every timestamp
    by a constant changes no verdict, window, or waste."""
    base = coarse_twoweek(n_apps=16, seed=3)
    shift = 4096.0 + 1.0 / 64.0   # on the trace grid, keeps times exact
    shifted = type(base)(
        specs=None, times=[t + shift for t in base.times],
        duration_minutes=base.duration_minutes + shift)
    for tr_a, tr_b in ((base, shifted),):
        a = _run(tr_a, CFG48, "fused", include_trailing=False)
        b = _run(tr_b, CFG48, "fused", include_trailing=False)
        np.testing.assert_array_equal(a.cold, b.cold)
        np.testing.assert_array_equal(a.wasted_minutes, b.wasted_minutes)
        np.testing.assert_array_equal(a.final_prewarm, b.final_prewarm)
        np.testing.assert_array_equal(a.final_keep_alive, b.final_keep_alive)


def test_arima_postpass_override_consistency():
    """With ARIMA enabled, OOB-heavy apps are re-simulated through the
    scalar policy; the batched result (cold, waste, windows) must equal the
    scalar oracle's for every app."""
    trace = coarse_twoweek(n_apps=16, seed=13)
    cfg = HybridConfig(histogram=CFG48.histogram, use_arima=True)
    oracle = simulate_scalar(trace, HybridHistogramPolicy(cfg))
    got = _run(trace, cfg, "fused")
    np.testing.assert_array_equal(got.cold, oracle.cold)
    np.testing.assert_array_equal(got.final_prewarm, oracle.final_prewarm)
    np.testing.assert_array_equal(got.final_keep_alive,
                                  oracle.final_keep_alive)
    np.testing.assert_allclose(got.wasted_minutes, oracle.wasted_minutes,
                               rtol=1e-9)


def test_subms_trace_actually_needs_rebasing():
    """Sanity check on the showcase trace: its absolute timestamps do NOT
    round-trip through float32 (the sub-ms structure is lost), while the
    per-app rebased timestamps do — this is exactly the gap rebasing
    closes."""
    trace = bursty_subms_multiweek()
    broken = exact = 0
    for t in trace.times:
        t = np.asarray(t)
        if not np.array_equal(t.astype(np.float32).astype(np.float64), t):
            broken += 1
        reb = t - t[0]
        if np.array_equal(reb.astype(np.float32).astype(np.float64), reb):
            exact += 1
    assert broken > 0, "trace no longer exercises float32-unrepresentable times"
    assert exact == trace.n_apps
