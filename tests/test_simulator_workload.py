"""Simulator + workload generator tests, including engine equivalence and
reproduction of the paper's headline policy comparisons (trend-level)."""
import numpy as np
import pytest

from repro.core import (EngineOptions, FixedKeepAlivePolicy, FixedSpec,
                        HybridConfig, HybridHistogramPolicy, HybridSpec,
                        NoUnloadSpec, generate_trace, run, simulate_scalar)
from repro.core.workload import sample_apps
from repro.core.workload_spec import WorkloadSpec


def uniform_trace(n_apps, days, seed, max_events):
    """Legacy-equivalent scaling trace (the old ``Trace.synthesize``)."""
    return WorkloadSpec.uniform(n_apps, days=days, seed=seed,
                                max_events=max_events,
                                min_events=1).materialize()


@pytest.fixture(scope="module")
def trace():
    return generate_trace(n_apps=300, days=5.0, seed=7)


@pytest.fixture(scope="module")
def int_trace():
    t = generate_trace(n_apps=60, days=3.0, seed=3)
    for i in range(t.n_apps):
        t.times[i] = np.unique(np.floor(t.times[i]))
    return t


def test_fixed_batch_matches_scalar(int_trace):
    fb = run(int_trace, FixedSpec(10.0), engine="fused")
    fs = simulate_scalar(int_trace, FixedKeepAlivePolicy(10.0))
    np.testing.assert_array_equal(fb.cold, fs.cold)
    np.testing.assert_allclose(fb.wasted_minutes, fs.wasted_minutes,
                               rtol=1e-4, atol=0.5)


def test_hybrid_batch_matches_scalar(int_trace):
    cfg = HybridConfig(use_arima=False)
    hb = run(int_trace, HybridSpec.from_config(cfg))
    hs = simulate_scalar(int_trace, HybridHistogramPolicy(cfg))
    np.testing.assert_array_equal(hb.cold, hs.cold)
    np.testing.assert_allclose(hb.wasted_minutes, hs.wasted_minutes,
                               rtol=1e-4, atol=0.5)


def test_first_invocation_always_cold(trace):
    res = run(trace, NoUnloadSpec())
    assert np.all(res.cold >= 1)


def test_no_unloading_is_lower_bound(trace):
    nou = run(trace, NoUnloadSpec())
    f10 = run(trace, FixedSpec(10.0))
    assert np.all(nou.cold <= f10.cold)
    # no-unloading: exactly one cold start per app
    assert np.all(nou.cold == 1)


def test_longer_keepalive_fewer_colds_more_waste(trace):
    f10 = run(trace, FixedSpec(10.0))
    f120 = run(trace, FixedSpec(120.0))
    assert f120.cold.sum() < f10.cold.sum()
    assert f120.total_wasted > f10.total_wasted
    assert f120.cold_pct_percentile(75) < f10.cold_pct_percentile(75)


def test_hybrid_pareto_dominates_fixed(trace):
    """The paper's headline (Fig. 15): hybrid gives fewer cold starts than
    the 10-minute fixed policy while using LESS memory."""
    f10 = run(trace, FixedSpec(10.0))
    hyb = run(trace, HybridSpec(use_arima=False))
    assert hyb.cold_pct_percentile(75) < f10.cold_pct_percentile(75) / 1.5
    assert hyb.total_wasted < 1.15 * f10.total_wasted


def test_cutoffs_reduce_waste(trace):
    """Fig. 16: [5,99] cutoffs cut memory vs [0,100] without hurting colds."""
    from repro.core.histogram import HistogramConfig
    h_cut = run(trace, HybridSpec(head_percentile=5, tail_percentile=99,
                                  use_arima=False))
    h_all = run(trace, HybridSpec(head_percentile=0, tail_percentile=100,
                                  use_arima=False))
    assert h_cut.total_wasted <= h_all.total_wasted


def test_arima_reduces_always_cold():
    """Fig. 18: ARIMA halves the fraction of 100%-cold-start apps among
    infrequently invoked ones."""
    # apps with ITs beyond the 4h histogram range: periodic ~6h
    from repro.core.workload import AppSpec, Trace
    n = 30
    times = []
    specs = []
    rng = np.random.default_rng(0)
    for i in range(n):
        period = 360.0 + rng.uniform(-5, 5)   # ~6h, OOB for 4h histogram
        t = np.arange(rng.uniform(0, 60), 7 * 1440.0, period)
        times.append(t)
        specs.append(AppSpec(app_id=f"app-{i:06d}", pattern="periodic",
                             rate_per_day=1440.0 / period,
                             period_minutes=period, exec_time_s=1.0,
                             memory_mb=100.0, n_functions=1, triggers=("timer",)))
    trace = Trace(specs=specs, times=times, duration_minutes=7 * 1440.0)
    no_arima = run(trace, HybridSpec(use_arima=False))
    with_arima = run(trace, HybridSpec(use_arima=True))
    assert with_arima.cold.sum() < 0.6 * no_arima.cold.sum()


# --- fused engine: float64 parity, chunking, scale path ----------------------

def test_fixed_batch_float64_boundary_parity():
    """ITs sitting exactly on the keep-alive boundary of a two-week trace:
    float32 time arithmetic flips warm/cold verdicts vs the float64 oracle
    (t ~ 2e4 minutes loses the sub-millisecond IAT bits)."""
    from repro.core.workload import AppSpec, Trace
    c = 1.0 / 3.0
    times = np.arange(0.0, 20160.0, 10.0) + c
    spec = AppSpec(app_id="app-000000", pattern="periodic", rate_per_day=144.0,
                   period_minutes=10.0, exec_time_s=1.0, memory_mb=100.0,
                   n_functions=1, triggers=("timer",))
    trace = Trace(specs=[spec], times=[times], duration_minutes=20160.0)
    fb = run(trace, FixedSpec(10.0), engine="fused")
    fs = simulate_scalar(trace, FixedKeepAlivePolicy(10.0))
    np.testing.assert_array_equal(fb.cold, fs.cold)
    np.testing.assert_allclose(fb.wasted_minutes, fs.wasted_minutes, rtol=1e-9)


def test_hybrid_fused_exact_parity_two_week_trace():
    """Cross-engine: fused batched engine == float64 scalar oracle, exact
    cold counts and ~machine-precision waste, on a full two-week float trace
    (the pre-PR float32 engine diverges here)."""
    t = generate_trace(n_apps=40, days=14.0, seed=11)
    cfg = HybridConfig(use_arima=False)
    hs = simulate_scalar(t, HybridHistogramPolicy(cfg))
    hb = run(t, HybridSpec.from_config(cfg))
    np.testing.assert_array_equal(hb.cold, hs.cold)
    np.testing.assert_allclose(hb.wasted_minutes, hs.wasted_minutes,
                               rtol=1e-9, atol=1e-6)


def test_hybrid_chunked_matches_unchunked(int_trace):
    cfg = HybridConfig(use_arima=False)
    whole = run(int_trace, HybridSpec.from_config(cfg))
    chunked = run(int_trace, HybridSpec.from_config(cfg),
                  options=EngineOptions(app_chunk=7))
    np.testing.assert_array_equal(chunked.cold, whole.cold)
    np.testing.assert_allclose(chunked.wasted_minutes, whole.wasted_minutes)


def test_hybrid_pallas_path_matches_scalar():
    """The fused Pallas kernel path (interpret mode here, TPU in prod) must
    agree with the scalar oracle on a small integer-time trace."""
    from repro.core.workload import Trace
    base = uniform_trace(n_apps=48, days=0.5, seed=4, max_events=24)
    padded, counts = base.to_padded()
    # integer minutes (exact in float32), in a fresh trace — to_padded's
    # cached arrays are shared and must not be mutated
    t = Trace(specs=None, times=None, duration_minutes=base.duration_minutes,
              _padded=(np.floor(padded), counts))
    cfg = HybridConfig(use_arima=False)
    hs = simulate_scalar(t, HybridHistogramPolicy(cfg))
    hp = run(t, HybridSpec.from_config(cfg), engine="pallas",
             options=EngineOptions(app_chunk=16))
    np.testing.assert_array_equal(hp.cold, hs.cold)
    np.testing.assert_allclose(hp.wasted_minutes, hs.wasted_minutes,
                               rtol=1e-4, atol=0.5)


def test_uniform_scaling_path():
    t = uniform_trace(5000, days=2.0, seed=9, max_events=48)
    assert t.n_apps == 5000
    padded, counts = t.to_padded()
    assert padded.shape == (5000, 48)
    assert counts.min() >= 1 and counts.max() <= 48
    # rows sorted, padding is +inf, events within the trace window
    for i in (0, 17, 4999):
        ev = t.events(i)
        assert len(ev) == counts[i]
        assert np.all(np.diff(ev) >= 0)
        assert np.all((ev >= 0) & (ev <= t.duration_minutes))
        assert np.all(np.isinf(padded[i, counts[i]:]))
    assert t.app_id(3) == "app-000003"
    # the padded-only trace runs through both engines
    res = run(t, HybridSpec(use_arima=False),
              options=EngineOptions(app_chunk=2048))
    assert res.invocations.sum() == counts.sum()
    assert np.all(res.cold >= 1)


def test_synthesize_shim_removed():
    """``Trace.synthesize`` is gone after its PR 5 deprecation cycle: any
    access — including ``hasattr`` probes — raises an AttributeError that
    spells out the ``WorkloadSpec.uniform`` replacement (same contract as
    the removed ``simulate*`` entry points)."""
    from repro.core.workload import Trace
    with pytest.raises(AttributeError, match="WorkloadSpec.uniform"):
        Trace.synthesize
    with pytest.raises(AttributeError, match="was removed"):
        Trace.synthesize(n_apps=10)
    assert not hasattr(Trace, "synthesize")
    t = uniform_trace(4, days=0.5, seed=0, max_events=4)
    assert not hasattr(t, "synthesize")


def test_uniform_rejects_invalid_args():
    with pytest.raises(ValueError, match="n_apps"):
        WorkloadSpec.uniform(-1).materialize()
    with pytest.raises(ValueError, match="max_events"):
        WorkloadSpec.uniform(4, max_events=0).materialize()
    with pytest.raises(ValueError, match="min_events"):
        WorkloadSpec.uniform(4, min_events=3).materialize()


def test_simulate_rejects_invalid_app_chunk(int_trace):
    cfg = HybridConfig(use_arima=False)
    with pytest.raises(ValueError, match="app_chunk"):
        run(int_trace, HybridSpec.from_config(cfg),
            options=EngineOptions(app_chunk=-3))


def test_uniform_ragged_last_block():
    """App counts that are NOT a multiple of the generation block must
    produce a fully populated trace (generation is block-aligned, with a
    counter RNG per block)."""
    t = uniform_trace(1000, days=1.0, seed=2, max_events=24)
    padded, counts = t.to_padded()
    assert padded.shape[0] == 1000 and padded.shape[1] <= 24
    assert counts.min() >= 1
    # the ragged tail is as well-formed as the rest
    width = padded.shape[1]
    tail = padded[768:]
    assert np.all(np.isfinite(tail[np.arange(width)[None, :] <
                                   counts[768:, None]]))
    for i in (767, 768, 999):
        ev = t.events(i)
        assert len(ev) == counts[i]
        assert np.all(np.diff(ev) >= 0)
        assert np.all(np.isinf(padded[i, counts[i]:]))
    # regeneration is deterministic block by block
    np.testing.assert_array_equal(
        uniform_trace(1000, days=1.0, seed=2, max_events=24).to_padded()[0],
        padded)


def test_hybrid_ragged_chunk_parity():
    """A bucket whose size is not a multiple of app_chunk (ragged last
    chunk) must change nothing — including through the Pallas path, whose
    kernel tiles and pads independently of the chunking."""
    t = uniform_trace(23, days=0.5, seed=6, max_events=12)
    cfg = HybridConfig(use_arima=False)
    whole = run(t, HybridSpec.from_config(cfg))
    ragged = run(t, HybridSpec.from_config(cfg),
                 options=EngineOptions(app_chunk=5))   # 5,5,5,5,3
    np.testing.assert_array_equal(ragged.cold, whole.cold)
    np.testing.assert_array_equal(ragged.wasted_minutes, whole.wasted_minutes)
    pallas_ragged = run(t, HybridSpec.from_config(cfg), engine="pallas",
                        options=EngineOptions(app_chunk=5))
    np.testing.assert_array_equal(pallas_ragged.cold, whole.cold)
    np.testing.assert_allclose(pallas_ragged.wasted_minutes,
                               whole.wasted_minutes, rtol=1e-5, atol=1e-3)


def test_hybrid_parity_power_of_two_bins():
    """Regression: the percentile binary search must cover the full [0,
    n_bins] answer space — with a power-of-two bin count an iteration-short
    search returns the wrong head bin and flips windows vs the oracle."""
    from repro.core.histogram import HistogramConfig
    t = uniform_trace(64, days=1.0, seed=33, max_events=32)
    cfg = HybridConfig(histogram=HistogramConfig(range_minutes=128.0),
                       use_arima=False)
    hs = simulate_scalar(t, HybridHistogramPolicy(cfg))
    hb = run(t, HybridSpec.from_config(cfg))
    np.testing.assert_array_equal(hb.cold, hs.cold)
    np.testing.assert_allclose(hb.wasted_minutes, hs.wasted_minutes,
                               rtol=1e-6, atol=1e-6)


def test_find_first_ge_power_of_two_bins():
    import jax.numpy as jnp
    from repro.core.histogram import find_first_ge
    for n_bins in (2, 4, 8, 64, 128, 240, 256):
        cum = jnp.asarray(np.full((1, n_bins), 5), jnp.int32)
        thr = jnp.asarray([1], jnp.int32)
        assert int(find_first_ge(cum, thr)[0]) == 0, n_bins
        empty = jnp.zeros((1, n_bins), jnp.int32)
        assert int(find_first_ge(empty, thr)[0]) == n_bins, n_bins
        ladder = jnp.asarray(np.arange(1, n_bins + 1)[None, :], jnp.int32)
        for want in (0, n_bins // 2, n_bins - 1):
            got = int(find_first_ge(ladder, jnp.asarray([want + 1]))[0])
            assert got == want, (n_bins, want, got)


def test_uniform_parity_small():
    t = uniform_trace(64, days=1.0, seed=21, max_events=32)
    cfg = HybridConfig(use_arima=False)
    hs = simulate_scalar(t, HybridHistogramPolicy(cfg))
    hb = run(t, HybridSpec.from_config(cfg))
    np.testing.assert_array_equal(hb.cold, hs.cold)
    np.testing.assert_allclose(hb.wasted_minutes, hs.wasted_minutes,
                               rtol=1e-6, atol=1e-6)


def test_zero_event_apps_consistent_across_engines():
    """Regression (the legacy synthesize clamped Poisson counts to >= 1, so
    no engine ever saw a count-0 row): the spec engine's default allows
    zero-event apps, and every engine must agree on them — zero cold
    starts, zero invocations, zero waste, the policy's initial windows, and
    no contribution to always_cold_fraction."""
    # near-zero rates: most apps get no events at all
    t = WorkloadSpec.uniform(96, days=0.02, seed=11, max_events=8).materialize()
    _, counts = t.to_padded()
    zeros = np.where(counts == 0)[0]
    assert len(zeros) > 10, "fixture must actually contain zero-event apps"

    # a list-backed trace with an explicitly empty row exercises the same
    # contract on the eager representation
    lt = __import__("repro.core.workload", fromlist=["Trace"]).Trace(
        specs=None, times=[np.asarray([1.0, 7.0]), np.asarray([])],
        duration_minutes=60.0)

    for trace, zsel in ((t, zeros), (lt, np.asarray([1]))):
        spec = HybridSpec(range_minutes=48.0, use_arima=False)
        results = {eng: run(trace, spec, engine=eng)
                   for eng in ("scalar", "fused", "pallas", "reference")}
        base = results["scalar"]
        assert np.all(base.invocations[zsel] == 0)
        assert np.all(base.cold[zsel] == 0)
        assert np.all(base.wasted_minutes[zsel] == 0.0)
        # never-invoked apps report the policy's initial (standard) windows
        assert np.all(base.final_prewarm[zsel] == 0.0)
        assert np.all(base.final_keep_alive[zsel] == 48.0)
        for eng, res in results.items():
            np.testing.assert_array_equal(res.cold, base.cold, err_msg=eng)
            np.testing.assert_array_equal(res.invocations, base.invocations,
                                          err_msg=eng)
            np.testing.assert_array_equal(res.final_prewarm,
                                          base.final_prewarm, err_msg=eng)
            np.testing.assert_array_equal(res.final_keep_alive,
                                          base.final_keep_alive, err_msg=eng)
            np.testing.assert_allclose(res.wasted_minutes,
                                       base.wasted_minutes, rtol=1e-5,
                                       atol=1e-3, err_msg=eng)
        fx = run(trace, FixedSpec(10.0))
        assert np.all(fx.cold[zsel] == 0)
        assert np.all(fx.final_keep_alive[zsel] == 10.0)
        # count-0 rows must not inflate the always-cold fraction
        invoked = base.invocations > 0
        want = (np.mean(base.cold[invoked] >= base.invocations[invoked])
                if invoked.any() else 0.0)
        assert base.always_cold_fraction == pytest.approx(want)


def test_always_cold_fraction_ignores_zero_invocation_apps():
    from repro.core.simulator import SimResult
    res = SimResult(cold=np.array([1, 0, 0, 2]),
                    invocations=np.array([1, 0, 0, 4]),
                    wasted_minutes=np.zeros(4))
    # only the two invoked apps count; one of them is always-cold
    assert res.always_cold_fraction == pytest.approx(0.5)
    empty = SimResult(cold=np.zeros(3, np.int64),
                      invocations=np.zeros(3, np.int64),
                      wasted_minutes=np.zeros(3))
    assert empty.always_cold_fraction == 0.0


# --- workload generator vs paper anchors -------------------------------------

def test_rate_distribution_anchors():
    specs = sample_apps(4000, seed=11)
    rates = np.array([s.rate_per_day for s in specs])
    assert np.mean(rates <= 24) == pytest.approx(0.45, abs=0.06)
    assert np.mean(rates <= 1440) == pytest.approx(0.81, abs=0.05)
    assert rates.max() / rates.min() > 1e6    # many orders of magnitude


def test_exec_time_distribution():
    specs = sample_apps(4000, seed=12)
    execs = np.array([s.exec_time_s for s in specs])
    assert np.median(execs) < 1.0                      # 50% below 1s
    assert np.mean(execs <= 60.0) > 0.9                # ~96% under 60s


def test_memory_distribution():
    specs = sample_apps(4000, seed=13)
    mem = np.array([s.memory_mb for s in specs])
    assert 90 < np.median(mem) < 250                   # ~170MB median
    assert np.percentile(mem, 90) < 600                # 90% under ~400MB


def test_cv_classes(trace):
    cvs = []
    for i in range(trace.n_apps):
        ia = trace.iats(i)
        if len(ia) >= 5:
            cvs.append(np.std(ia) / max(np.mean(ia), 1e-9))
    cvs = np.array(cvs)
    assert np.mean(cvs < 0.1) > 0.08     # periodic class exists
    assert np.mean(cvs > 1.0) > 0.2      # bursty class exists
