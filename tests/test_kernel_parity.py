"""Parity suite: fused hybrid-step Pallas kernel vs the scalar reference.

The fused kernel (repro.kernels.histogram.fused_hybrid_step_pallas) must
reproduce, per event, exactly what the control-plane scalar path
(AppHistogram + HybridHistogramPolicy decision tree) computes: histogram
contents, OOB counters, and the (prewarm, keep-alive) windows. Property
tests run when hypothesis is installed (see requirements-dev.txt); the
seeded stream tests below always run.
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.histogram import AppHistogram, HistogramConfig
from repro.core.policy import HybridConfig, HybridHistogramPolicy
from repro.kernels import ops

CFG = HistogramConfig(range_minutes=48.0)   # 48 bins: fast in interpret mode
HYB = HybridConfig(histogram=CFG, use_arima=False)
N_LANES = 5                                 # > tile to exercise padding


def _kernel_stream(its, tile_apps=4):
    """Drive the fused kernel one event at a time (N_LANES identical apps).

    Yields (prewarm, keep, total, oob, counts) after every event.
    """
    n_bins = CFG.n_bins
    state = (
        jnp.full((N_LANES,), -jnp.inf, jnp.float32),
        jnp.zeros((N_LANES, n_bins), jnp.int32),
        jnp.zeros((N_LANES,), jnp.int32),
        jnp.zeros((N_LANES,), jnp.float32),
        jnp.zeros((N_LANES,), jnp.float32),
        jnp.zeros((N_LANES,), jnp.float32),
        jnp.full((N_LANES,), jnp.float32(HYB.standard_keep_alive)),
        jnp.zeros((N_LANES,), jnp.int32),
        jnp.zeros((N_LANES,), jnp.float32),
    )
    t = 0.0
    out = []
    for it in its:
        t += it
        state = ops.fused_hybrid_step(
            jnp.full((N_LANES,), t, jnp.float32), *state,
            head_pct=CFG.head_percentile, tail_pct=CFG.tail_percentile,
            margin=CFG.margin, bin_minutes=CFG.bin_minutes,
            range_minutes=CFG.range_minutes, cv_threshold=HYB.cv_threshold,
            min_samples=HYB.min_samples,
            oob_threshold=HYB.oob_fraction_threshold,
            standard_keep=HYB.standard_keep_alive, tile_apps=tile_apps)
        (_, cum, oob, _, _, prewarm, unload_at, _, _) = state
        counts = np.diff(np.concatenate(
            [[0], np.asarray(cum[0], np.int64)]))
        # the carry holds residency bounds; keep-alive is their exact
        # float64 difference (same reconstruction the drivers use)
        out.append((float(prewarm[0]), float(unload_at[0]) - float(prewarm[0]),
                    int(cum[0, -1]), int(oob[0]), counts))
        # all lanes (incl. the padded-tile ones) must agree
        np.testing.assert_array_equal(np.asarray(prewarm),
                                      np.full(N_LANES, prewarm[0]))
        np.testing.assert_array_equal(np.asarray(cum),
                                      np.tile(np.asarray(cum[:1]), (N_LANES, 1)))
    return out


def _scalar_stream(its):
    """Same stream through the scalar control-plane reference."""
    policy = HybridHistogramPolicy(HYB)
    hist = AppHistogram(CFG)
    out = []
    for k, it in enumerate(its):
        w = policy.on_invocation("a", None if k == 0 else float(its[k]))
        if k > 0:
            hist.record(float(its[k]))
        out.append((w.prewarm, w.keep_alive, hist.total, hist.oob,
                    hist.counts.copy()))
    return out


def _check_stream(its):
    """its[0] is the first arrival (not recorded); its[1:] are idle times.

    Times are kept on a dyadic grid well inside float32 range so the kernel
    recovers every idle time exactly from its carried float32 clock.
    """
    got = _kernel_stream(its)
    want = _scalar_stream(its)
    for k, ((gp, gk, gt, go, gc), (wp, wk, wt, wo, wc)) in enumerate(
            zip(got, want)):
        assert gt == wt, f"event {k}: total {gt} != {wt}"
        assert go == wo, f"event {k}: oob {go} != {wo}"
        np.testing.assert_array_equal(gc, wc, err_msg=f"event {k}")
        # single-source float32 decision layer: windows match bit-for-bit
        assert gp == wp, f"event {k}: prewarm {gp} != {wp}"
        assert gk == wk, f"event {k}: keep {gk} != {wk}"


def _quantize(vals):
    # 1/64-minute grid: exact float32 arithmetic for cumulative times < 2^17
    return [max(round(v * 64.0) / 64.0, 0.0) for v in vals]


# --- seeded streams (always run) --------------------------------------------

def test_fused_kernel_parity_in_bounds_stream():
    rng = np.random.default_rng(0)
    its = _quantize(rng.uniform(0.5, 40.0, 60))
    _check_stream(its)


def test_fused_kernel_parity_oob_heavy_stream():
    """Most idle times beyond the histogram range: the representativeness
    check must veto the histogram windows on both paths."""
    rng = np.random.default_rng(1)
    its = _quantize(rng.uniform(CFG.range_minutes + 1.0,
                                3.0 * CFG.range_minutes, 40))
    its[5] = 3.0   # a couple in-bounds so total > 0
    its[11] = 7.0
    _check_stream(its)


def test_fused_kernel_parity_sub_min_samples():
    its = _quantize([4.0, 4.0, 4.0])   # fewer than min_samples ITs
    _check_stream(its)
    # standard keep-alive must be in force after so few samples
    got = _kernel_stream(its)
    assert got[-1][0] == 0.0
    assert got[-1][1] == HYB.standard_keep_alive


def test_fused_kernel_parity_bimodal_prewarm_stream():
    """Concentrated bimodal ITs push CV over threshold: histogram windows
    (prewarm > 0) activate and must match the scalar decision."""
    rng = np.random.default_rng(2)
    its = _quantize([10.0 if i % 2 else 30.0 for i in range(50)])
    _check_stream(its)
    got = _kernel_stream(its)
    assert got[-1][0] > 0.0   # pre-warming active


def test_fused_kernel_parity_mixed_random_streams():
    for seed in range(3, 7):
        rng = np.random.default_rng(seed)
        its = _quantize(np.abs(rng.normal(0.0, CFG.range_minutes, 30)))
        _check_stream(its)


# --- hypothesis property tests (absent hypothesis, only these skip; the
# seeded streams above still run) ---------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - depends on dev environment
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    its_strategy = st.lists(
        st.floats(min_value=0.0, max_value=3.0 * CFG.range_minutes,
                  allow_nan=False),
        min_size=1, max_size=40)

    @settings(max_examples=25, deadline=None)
    @given(its_strategy)
    def test_fused_kernel_parity_property(values):
        _check_stream(_quantize(values))

    @settings(max_examples=10, deadline=None)
    @given(st.lists(st.integers(0, 3 * int(CFG.range_minutes)), min_size=1,
                    max_size=40))
    def test_fused_kernel_parity_property_integer(values):
        _check_stream([float(v) for v in values])
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_fused_kernel_parity_property():
        pass
