"""Sharded-vs-single-device bit-identity for run()/sweep() and the cluster
policy-window scan.

The contract (``repro/distributed/scaleout.py``): partitioning a chunk's
app rows across a 1-D device mesh changes nothing but wall-clock — cold
counts, waste, final windows, and cluster outputs are bit-identical to the
unsharded run, including app counts not divisible by the device count
(masked +inf padding rows), zero-event apps, and the pinned golden traces.

``devices=1`` cases always run (the degenerate mesh exercises the full
shard_map machinery on any host). The 2- and 8-device cases need forced
host devices — the scaleout CI leg runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on an ordinary
one-device host they skip, and the subprocess smoke at the bottom keeps
the real multi-device contract covered everywhere.
"""
import functools
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core.experiment import (EngineOptions, FixedSpec, HybridSpec,
                                   NoUnloadSpec, run, sweep)
from repro.core.workload import Trace
from repro.core.workload_spec import azure_like
from repro.serving.cluster_vector import ClusterSpec

from golden_traces import CFG48, CFG240, GOLDEN_TRACES, coarse_twoweek

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _needs(n):
    return pytest.mark.skipif(
        jax.device_count() < n,
        reason=(f"needs {n} devices — run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count=8"))


DEVICES = [pytest.param(1),
           pytest.param(2, marks=_needs(2)),
           pytest.param(8, marks=_needs(8))]

# Mixed families, two histogram bands — the same shape of grid the
# experiment-API conformance suite uses, so every factored sweep layer
# goes through the sharded path.
GRID = [FixedSpec(10.0), NoUnloadSpec(),
        HybridSpec.from_config(CFG48),
        HybridSpec(range_minutes=48.0, cv_threshold=0.5, use_arima=False),
        HybridSpec.from_config(CFG240)]


@functools.lru_cache(maxsize=None)
def _trace():
    """21 apps — indivisible by 2 and 8 — with a zero-event and a
    single-event app spliced in (padding + masking edge cases)."""
    base = coarse_twoweek(n_apps=21)
    times = [np.asarray(base.events(i), np.float64)
             for i in range(base.n_apps)]
    times[5] = np.asarray([], np.float64)
    times[13] = times[13][:1]
    return Trace(specs=None, times=times,
                 duration_minutes=base.duration_minutes)


@functools.lru_cache(maxsize=None)
def _baseline(engine):
    return sweep(_trace(), GRID, engine=engine,
                 options=EngineOptions(app_chunk=11))


def _assert_rows_equal(base, res):
    np.testing.assert_array_equal(base.cold, res.cold)
    np.testing.assert_array_equal(base.invocations, res.invocations)
    np.testing.assert_array_equal(base.wasted_minutes, res.wasted_minutes)
    np.testing.assert_array_equal(base.final_prewarm, res.final_prewarm)
    np.testing.assert_array_equal(base.final_keep_alive,
                                  res.final_keep_alive)


@pytest.mark.parametrize("devices", DEVICES)
@pytest.mark.parametrize("engine", ["fused", "pallas"])
def test_sweep_sharded_bit_identical(engine, devices):
    res = sweep(_trace(), GRID, engine=engine,
                options=EngineOptions(app_chunk=11, devices=devices))
    _assert_rows_equal(_baseline(engine), res)


@pytest.mark.parametrize("devices", DEVICES)
def test_run_sharded_bit_identical(devices):
    base = run(_trace(), GRID[2])
    res = run(_trace(), GRID[2], options=EngineOptions(devices=devices))
    np.testing.assert_array_equal(base.cold, res.cold)
    np.testing.assert_array_equal(base.wasted_minutes, res.wasted_minutes)
    np.testing.assert_array_equal(base.final_prewarm, res.final_prewarm)
    np.testing.assert_array_equal(base.final_keep_alive,
                                  res.final_keep_alive)


@pytest.mark.parametrize("devices", DEVICES)
@pytest.mark.parametrize("name", sorted(GOLDEN_TRACES))
def test_golden_traces_sharded(name, devices):
    """The sharded sweep reproduces the checked-in float64 oracle records
    on the pinned golden traces — not just self-consistency."""
    make_trace, cfg = GOLDEN_TRACES[name]
    with open(os.path.join(GOLDEN_DIR, f"{name}.json")) as f:
        want = json.load(f)
    res = sweep(make_trace(), [HybridSpec.from_config(cfg)], engine="fused",
                options=EngineOptions(devices=devices))
    np.testing.assert_array_equal(res.cold[0], np.asarray(want["cold"]))
    np.testing.assert_array_equal(res.final_prewarm[0],
                                  np.asarray(want["final_prewarm"]))
    np.testing.assert_array_equal(res.final_keep_alive[0],
                                  np.asarray(want["final_keep_alive"]))
    np.testing.assert_allclose(res.wasted_minutes[0],
                               np.asarray(want["wasted_minutes"]),
                               rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("devices", DEVICES)
def test_cluster_windows_sharded(devices):
    """The fleet simulator's policy-window scan shards the same way: a 91
    app fleet (indivisible by 2 and 8) produces identical placement,
    latency, and waste outputs."""
    wl = azure_like(91, days=1.5, seed=5)
    spec = HybridSpec.from_config(CFG48)
    base = run(wl, spec, cluster=ClusterSpec())
    res = run(wl, spec, cluster=ClusterSpec(),
              options=EngineOptions(devices=devices))
    np.testing.assert_array_equal(base.cold_pct_per_app,
                                  res.cold_pct_per_app)
    np.testing.assert_array_equal(base.latencies_s, res.latencies_s)
    np.testing.assert_array_equal(base.wasted_gb_minutes,
                                  res.wasted_gb_minutes)


_CHILD = r"""
import numpy as np, jax
assert jax.device_count() == 8, jax.device_count()
from repro.core.experiment import EngineOptions, FixedSpec, HybridSpec, sweep
from repro.core.workload import Trace
rng = np.random.default_rng(11)
times = [np.cumsum(rng.integers(1, 64 * 120, 10)) / 64.0 for _ in range(13)]
times[2] = np.asarray([], np.float64)
trace = Trace(specs=None, times=times, duration_minutes=2 * 1440.0)
grid = [FixedSpec(10.0),
        HybridSpec(range_minutes=48.0, cv_threshold=2.0, use_arima=False)]
base = sweep(trace, grid, engine="fused", options=EngineOptions(app_chunk=5))
res = sweep(trace, grid, engine="fused",
            options=EngineOptions(app_chunk=5, devices=8))
for f in ("cold", "wasted_minutes", "final_prewarm", "final_keep_alive"):
    np.testing.assert_array_equal(getattr(base, f), getattr(res, f))
print("SCALEOUT-OK")
"""


def test_subprocess_forced_host_devices():
    """Always-on multi-device coverage: a child process forces 8 host
    devices (XLA_FLAGS must be set before the first jax import, hence the
    subprocess) and asserts devices=8 bit-identity on a tiny sweep."""
    env = dict(os.environ)
    flags = [t for t in env.get("XLA_FLAGS", "").split()
             if not t.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        flags + ["--xla_force_host_platform_device_count=8"])
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO_ROOT, "src"),
         *filter(None, [env.get("PYTHONPATH")])])
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         cwd=REPO_ROOT, capture_output=True, text=True,
                         timeout=600)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SCALEOUT-OK" in out.stdout
