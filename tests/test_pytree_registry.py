"""Pytree-registration audit: no spec field is silently dropped.

The complement of the static ``pytree-completeness`` lint pass: for every
spec dataclass that rides through ``jax.tree_util`` (vmapped policy grids,
``tree_map`` over scenarios) we build an instance with EVERY field
perturbed away from its default, flatten/unflatten it, and require exact
equality. A field registered in neither the children nor the aux_data
comes back as its default and fails here by construction.

Unregistered specs (ClusterSpec, EngineOptions, HedgePolicy) are plain
tree *leaves* today — the same roundtrip documents that status: if someone
registers them later with an incomplete flatten, this test is what breaks.
"""
import dataclasses

import jax
import pytest

from repro.core.experiment import (EngineOptions, FixedSpec, HybridSpec,
                                   NoUnloadSpec)
from repro.core.workload_spec import Cohort, WorkloadSpec
from repro.runtime.straggler import HedgePolicy
from repro.serving.cluster_vector import ClusterSpec

PERTURBED_COHORT = Cohort(
    name="hot", weight=2.5, rate_log10_min=0.5, rate_log10_max=3.5,
    rate_scale=2.0, pattern_probs=(0.25, 0.25, 0.5),
    trigger_probs=(1.0, 0.0))

# Every field explicitly non-default: the roundtrip must preserve all of
# them, so a flatten that forgets one cannot pass.
PERTURBED = [
    FixedSpec(keep_alive=33.0, label="fx"),
    NoUnloadSpec(label="nu"),
    HybridSpec(bin_minutes=2.0, range_minutes=480.0, head_percentile=10.0,
               tail_percentile=95.0, margin=0.2, cv_threshold=1.5,
               min_samples=9, oob_fraction_threshold=0.25,
               arima_min_samples=7, arima_margin=0.3, use_arima=False,
               label="hy"),
    PERTURBED_COHORT,
    WorkloadSpec(n_apps=7, days=2.5, seed=9, cohorts=(PERTURBED_COHORT,),
                 max_events=17, min_events=1, diurnal_amplitude=0.1,
                 weekend_factor=0.5, flash_start=10.0, flash_duration=30.0,
                 flash_factor=2.0, generator="uniform", label="wl"),
    ClusterSpec(n_workers=4, hbm_budget_bytes=1e9, balancing="hash",
                hedge=HedgePolicy(straggler_prob=0.5, straggler_factor=2.0,
                                  hedge_after_factor=3.0, enabled=False),
                checkpoint_at_minute=45.0, label="cl"),
    EngineOptions(include_trailing=False, app_chunk=3, tile_apps=128,
                  interpret=True, devices=2, max_eviction_rounds=2),
]


def _field_items(obj):
    return {f.name: getattr(obj, f.name) for f in dataclasses.fields(obj)}


@pytest.mark.parametrize("spec", PERTURBED,
                         ids=lambda s: type(s).__name__)
def test_roundtrip_preserves_every_field(spec):
    defaults = type(spec)()
    perturbed = _field_items(spec)
    # the fixture itself must perturb everything, or the test proves nothing
    for name, value in _field_items(defaults).items():
        assert perturbed[name] != value, \
            f"fixture leaves {type(spec).__name__}.{name} at its default"
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(restored) is type(spec)
    for name, value in perturbed.items():
        assert getattr(restored, name) == value, \
            f"{type(spec).__name__}.{name} lost in flatten/unflatten"


@pytest.mark.parametrize("spec_cls,meta_fields", [
    (FixedSpec, {"label"}),
    (NoUnloadSpec, {"label"}),
    (HybridSpec, {"use_arima", "label"}),
    (Cohort, {"name", "pattern_probs", "trigger_probs"}),
    (WorkloadSpec, {"generator", "label", "max_events", "min_events",
                    "n_apps", "seed"}),
])
def test_registered_specs_split_children_vs_aux(spec_cls, meta_fields):
    """Registered specs decompose; meta fields survive as aux_data (they
    must NOT appear among the mapped leaves) and data fields are leaves."""
    # flatten the PERTURBED instance: None-valued data fields (e.g. default
    # flash_start) are empty subtrees, not leaves, and would skew the count
    spec = next(s for s in PERTURBED if type(s) is spec_cls)
    leaves, treedef = jax.tree_util.tree_flatten(spec)
    n_data = len(dataclasses.fields(spec_cls)) - len(meta_fields)
    if spec_cls is WorkloadSpec:
        # cohorts is itself a registered pytree: its data fields inline
        cohort_data = len(dataclasses.fields(Cohort)) - 3
        n_data = n_data - 1 + cohort_data
    assert len(leaves) == n_data
    doubled = jax.tree_util.tree_unflatten(treedef, [v * 2 for v in leaves])
    for name in meta_fields:
        assert getattr(doubled, name) == getattr(spec, name), \
            f"meta field {name} should ride aux_data untouched by tree_map"


@pytest.mark.parametrize("leaf_cls", [ClusterSpec, EngineOptions,
                                      HedgePolicy])
def test_unregistered_specs_are_leaves(leaf_cls):
    obj = leaf_cls()
    leaves, _ = jax.tree_util.tree_flatten(obj)
    assert leaves == [obj]
