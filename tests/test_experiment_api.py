"""The experiment front door: PolicySpec pytrees, run()/sweep() parity on
both axes (policy grid AND trace axis), and the removed-shim contract.

The load-bearing guarantee: every row of a ``sweep()`` is bit-identical
(cold counts, invocations, final windows; waste too, engine-for-engine) to
the corresponding single-config ``run()`` on EVERY engine, including the
golden traces — stacking configurations into a traced config axis must
change nothing but wall-clock. The same holds along the trace axis:
``sweep(traces=[...], specs=[...])`` cells equal the single-trace calls.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

import jax.tree_util as tree_util

from repro.core.experiment import (ENGINES, EngineOptions, FixedSpec,
                                   HybridSpec, NoUnloadSpec, as_spec,
                                   as_trace, run, sweep)
from repro.core.histogram import HistogramConfig
from repro.core.policy import (FixedKeepAlivePolicy, HybridConfig,
                               HybridHistogramPolicy, NoUnloadingPolicy)
from repro.core.simulator import simulate_scalar
from repro.core.workload import Trace
from repro.core.workload_spec import WorkloadSpec, azure_like, bursty

from golden_traces import CFG48, GOLDEN_TRACES, coarse_twoweek

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

# A deliberately mixed grid: two families, two histogram bands, and
# window/gate variants that exercise the factored sweep layers.
GRID = [
    FixedSpec(10.0),
    NoUnloadSpec(),
    HybridSpec.from_config(CFG48),
    HybridSpec(range_minutes=48.0, cv_threshold=0.5, use_arima=False),
    HybridSpec(range_minutes=48.0, head_percentile=0.0,
               tail_percentile=100.0, use_arima=False),
    HybridSpec(range_minutes=64.0, use_arima=False),
    FixedSpec(48.0),
]

OPTS = EngineOptions(app_chunk=11)   # ragged chunks on purpose


@pytest.fixture(scope="module")
def trace():
    return coarse_twoweek()


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_sweep_rows_equal_single_config_runs(trace, engine):
    """sweep() row s == run(spec_s) bit-for-bit, per engine — including
    float32 waste, which accumulates in the same order either way."""
    res = sweep(trace, GRID, engine=engine, options=OPTS)
    assert len(res) == len(GRID)
    for s, spec in enumerate(GRID):
        one = run(trace, spec, engine=engine, options=OPTS)
        err = f"engine={engine} row={s} ({spec.name})"
        np.testing.assert_array_equal(res.cold[s], one.cold, err_msg=err)
        np.testing.assert_array_equal(res.invocations, one.invocations,
                                      err_msg=err)
        np.testing.assert_array_equal(res.wasted_minutes[s],
                                      one.wasted_minutes, err_msg=err)
        np.testing.assert_array_equal(res.final_prewarm[s],
                                      one.final_prewarm, err_msg=err)
        np.testing.assert_array_equal(res.final_keep_alive[s],
                                      one.final_keep_alive, err_msg=err)


@pytest.mark.parametrize("engine", ["fused", "pallas", "reference"])
def test_sweep_matches_scalar_oracle(trace, engine):
    """Every sweep row reproduces the float64 scalar oracle exactly on the
    decision-layer outputs (cold counts, windows)."""
    res = sweep(trace, GRID, engine=engine, options=OPTS)
    for s, spec in enumerate(GRID):
        oracle = simulate_scalar(trace, spec.build())
        err = f"engine={engine} row={s} ({spec.name})"
        np.testing.assert_array_equal(res.cold[s], oracle.cold, err_msg=err)
        np.testing.assert_array_equal(res.final_prewarm[s],
                                      oracle.final_prewarm, err_msg=err)
        np.testing.assert_array_equal(res.final_keep_alive[s],
                                      oracle.final_keep_alive, err_msg=err)
        np.testing.assert_allclose(res.wasted_minutes[s],
                                   oracle.wasted_minutes, rtol=1e-5,
                                   atol=1e-3, err_msg=err)


@pytest.mark.parametrize("name", sorted(GOLDEN_TRACES))
def test_sweep_matches_golden_fixtures(name):
    """sweep() over the pinned golden traces reproduces the checked-in
    float64 oracle records row-for-row."""
    make_trace, cfg = GOLDEN_TRACES[name]
    with open(os.path.join(GOLDEN_DIR, f"{name}.json")) as f:
        want = json.load(f)
    t = make_trace()
    # the golden config twice (both rows must match the fixture) plus a
    # decoy variant in between — row order must be preserved
    spec = HybridSpec.from_config(cfg)
    decoy = dataclasses.replace(spec, cv_threshold=spec.cv_threshold + 1.0)
    res = sweep(t, [spec, decoy, spec], engine="fused")
    for s in (0, 2):
        np.testing.assert_array_equal(res.cold[s], np.asarray(want["cold"]))
        np.testing.assert_array_equal(res.final_prewarm[s],
                                      np.asarray(want["final_prewarm"]))
        np.testing.assert_array_equal(res.final_keep_alive[s],
                                      np.asarray(want["final_keep_alive"]))
        np.testing.assert_allclose(res.wasted_minutes[s],
                                   np.asarray(want["wasted_minutes"]),
                                   rtol=0, atol=0)


def test_arima_sweep_rows_match_runs():
    """use_arima specs trigger the per-config scalar post-pass; rows must
    still equal single-config runs and the oracle. Small trace: the ARIMA
    refits per invocation, and this runs the scalar path six times."""
    trace = coarse_twoweek(n_apps=4, seed=13)
    specs = [HybridSpec.from_config(CFG48),
             dataclasses.replace(HybridSpec.from_config(CFG48),
                                 use_arima=True)]
    res = sweep(trace, specs, engine="fused")
    for s, spec in enumerate(specs):
        one = run(trace, spec, engine="fused")
        oracle = simulate_scalar(trace, spec.build())
        np.testing.assert_array_equal(res.cold[s], one.cold)
        np.testing.assert_array_equal(res.cold[s], oracle.cold)
        np.testing.assert_array_equal(res.final_keep_alive[s],
                                      oracle.final_keep_alive)


def test_sweep_points_and_iteration(trace):
    res = sweep(trace, [FixedSpec(10.0), HybridSpec.from_config(CFG48)])
    pts = res.points()
    assert [p.name for p in pts] == ["fixed-10m", "hybrid-48m"]
    rows = list(res)
    assert len(rows) == 2
    assert pts[0].wasted_memory == rows[0].total_wasted


def test_sweep_rejects_bad_inputs(trace):
    with pytest.raises(ValueError, match="at least one"):
        sweep(trace, [])
    with pytest.raises(ValueError, match="unknown engine"):
        sweep(trace, [FixedSpec(10.0)], engine="warp")
    with pytest.raises(TypeError, match="PolicySpec"):
        as_spec(object())
    with pytest.raises(TypeError, match="Trace or WorkloadSpec"):
        as_trace(object())
    with pytest.raises(TypeError, match="exactly one"):
        sweep(trace, [FixedSpec(10.0)], traces=[trace])
    with pytest.raises(TypeError, match="exactly one"):
        sweep(specs=[FixedSpec(10.0)])
    with pytest.raises(ValueError, match="at least one trace"):
        sweep(traces=[], specs=[FixedSpec(10.0)])


# --- the trace axis: sweep(traces=[...], specs=[...]) ------------------------


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_trace_axis_cells_equal_single_trace_runs(trace, engine):
    """Every (t, s) cell of a trace x policy grid is bit-identical to the
    corresponding single-trace run() — the acceptance bar for the axis."""
    spec_b = bursty(24, days=3.0, seed=5, max_events=24, min_events=1)
    traces = [trace, spec_b]
    grid = sweep(traces=traces, specs=GRID, engine=engine, options=OPTS)
    assert grid.shape == (2, len(GRID))
    assert len(list(iter(grid))) == 2
    materialized = [trace, spec_b.materialize()]
    for t, tr in enumerate(materialized):
        for s, spec in enumerate(GRID):
            one = run(tr, spec, engine=engine, options=OPTS)
            err = f"engine={engine} t={t} s={s} ({spec.name})"
            cell = grid.row(t, s)
            np.testing.assert_array_equal(cell.cold, one.cold, err_msg=err)
            np.testing.assert_array_equal(cell.invocations, one.invocations,
                                          err_msg=err)
            np.testing.assert_array_equal(cell.wasted_minutes,
                                          one.wasted_minutes, err_msg=err)
            np.testing.assert_array_equal(cell.final_prewarm,
                                          one.final_prewarm, err_msg=err)
            np.testing.assert_array_equal(cell.final_keep_alive,
                                          one.final_keep_alive, err_msg=err)


def test_workload_specs_accepted_everywhere(trace):
    """run()/sweep() take WorkloadSpec wherever they take Trace, and the
    spec materializes deterministically to the same trace each time."""
    wspec = azure_like(40, days=2.0, seed=3, max_events=16)
    via_spec = run(wspec, FixedSpec(10.0))
    via_trace = run(wspec.materialize(), FixedSpec(10.0))
    np.testing.assert_array_equal(via_spec.cold, via_trace.cold)
    np.testing.assert_array_equal(via_spec.wasted_minutes,
                                  via_trace.wasted_minutes)
    grid = sweep(traces=[wspec, trace], specs=[FixedSpec(10.0)])
    assert grid.trace_name(0) == wspec.name
    assert grid.trace_name(1) == "trace-1"
    np.testing.assert_array_equal(grid.row(0, 0).cold, via_spec.cold)


# --- removed deprecation shims ----------------------------------------------


def test_removed_shims_raise_with_pointer():
    import repro.core
    import repro.core.simulator as sim
    for name in ("simulate", "simulate_fixed_batch", "simulate_hybrid_batch",
                 "simulate_hybrid_batch_reference"):
        for mod in (sim, repro.core):
            with pytest.raises(AttributeError,
                               match="repro.core.experiment"):
                getattr(mod, name)
    with pytest.raises(AttributeError, match="no attribute"):
        sim.definitely_not_a_thing


# --- PolicySpec pytree + build() properties ----------------------------------


def test_specs_roundtrip_and_build_match_legacy():
    spec = HybridSpec(range_minutes=60.0, cv_threshold=1.5, use_arima=True,
                      label="x")
    leaves, treedef = tree_util.tree_flatten(spec)
    assert tree_util.tree_unflatten(treedef, leaves) == spec
    cfg = spec.to_config()
    assert HybridSpec.from_config(cfg, label="x") == spec
    assert spec.build().cfg == cfg

    fx = FixedSpec(25.0)
    leaves, treedef = tree_util.tree_flatten(fx)
    assert tree_util.tree_unflatten(treedef, leaves) == fx
    assert fx.build().keep_alive == 25.0
    assert isinstance(NoUnloadSpec().build(), NoUnloadingPolicy)

    # as_spec round-trips the legacy objects
    assert as_spec(FixedKeepAlivePolicy(30.0)) == FixedSpec(30.0)
    assert as_spec(NoUnloadingPolicy()) == NoUnloadSpec()
    assert as_spec(cfg) == HybridSpec.from_config(cfg)
    assert as_spec(HybridHistogramPolicy(cfg)) == HybridSpec.from_config(cfg)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:

    hybrid_specs = st.builds(
        HybridSpec,
        bin_minutes=st.sampled_from([0.5, 1.0, 2.0]),
        range_minutes=st.sampled_from([24.0, 48.0, 240.0, 480.0]),
        head_percentile=st.sampled_from([0.0, 5.0, 10.0]),
        tail_percentile=st.sampled_from([95.0, 99.0, 100.0]),
        margin=st.floats(0.0, 0.5),
        cv_threshold=st.floats(0.0, 8.0),
        min_samples=st.integers(1, 20),
        oob_fraction_threshold=st.floats(0.05, 0.95),
        use_arima=st.booleans())

    @settings(max_examples=50, deadline=None)
    @given(spec=hybrid_specs)
    def test_hybrid_spec_pytree_roundtrip(spec):
        leaves, treedef = tree_util.tree_flatten(spec)
        assert all(np.isscalar(x) for x in leaves)
        assert tree_util.tree_unflatten(treedef, leaves) == spec

    @settings(max_examples=50, deadline=None)
    @given(spec=hybrid_specs)
    def test_hybrid_spec_build_matches_legacy_constructor(spec):
        cfg = spec.build().cfg
        want = HybridConfig(
            histogram=HistogramConfig(
                bin_minutes=spec.bin_minutes,
                range_minutes=spec.range_minutes,
                head_percentile=spec.head_percentile,
                tail_percentile=spec.tail_percentile,
                margin=spec.margin),
            cv_threshold=spec.cv_threshold, min_samples=spec.min_samples,
            oob_fraction_threshold=spec.oob_fraction_threshold,
            arima_min_samples=spec.arima_min_samples,
            arima_margin=spec.arima_margin, use_arima=spec.use_arima)
        assert cfg == want
        assert HybridSpec.from_config(cfg) == spec

    @settings(max_examples=25, deadline=None)
    @given(keep=st.floats(0.5, 480.0))
    def test_fixed_spec_roundtrip_and_build(keep):
        spec = FixedSpec(keep)
        leaves, treedef = tree_util.tree_flatten(spec)
        assert tree_util.tree_unflatten(treedef, leaves) == spec
        assert spec.build().keep_alive == keep
        assert as_spec(spec.build()) == spec
