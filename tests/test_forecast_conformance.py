"""Conformance suite for the batched forecasting subsystem.

Pins the three contracts ISSUE-10 ships on:

  * the batched grid fit (``fit_arima_grid``) against the
    triangle-constrained scipy CSS oracle (``tests/arima_oracle.py``) —
    AIC within 4.0 of the Nelder-Mead optimum on seeded series;
  * the hybrid engines' ARIMA post-pass (now routed through
    ``repro.forecast.replay``) bit-identical to the scalar per-event
    oracle through ``run()`` — including a ``cv_threshold``-forced trace
    where *every* app takes the ARIMA path — and the cluster engine's
    per-gap window sequences vector == scalar;
  * the SPES predictor family (``SpesSpec``) across every engine:
    scalar oracle, fused, pallas and reference are bit-identical on
    cold counts, final windows AND waste (the float64-compute /
    single-f32-rounding state update makes waste exact, not just
    close), cluster vector == scalar, ``sweep()`` rows == single
    ``run()``s — plus the frontier scenario: long-period timers (period
    beyond the 240-minute histogram range) where SpesSpec strictly
    Pareto-dominates the hybrid on the cold-start/waste frontier.
"""
import numpy as np
import pytest

from repro.core.experiment import (EngineOptions, FixedSpec, HybridSpec,
                                   SpesSpec, run, sweep)
from repro.core.policy import HybridConfig, HybridHistogramPolicy, SpesPolicy
from repro.core.simulator import simulate_scalar
from repro.core.workload import Trace
from repro.core.workload_spec import azure_like, timer_heavy
from repro.forecast import MAX_OBS, ORDER_GRID, fit_arima_grid
from repro.serving.apptable import AppTable
from repro.serving.cluster_vector import ClusterSpec, run_cluster, sweep_cluster

from golden_traces import CFG48, coarse_twoweek


# --------------------------------------------------------------------------
# Batched grid fit vs the scipy CSS oracle
# --------------------------------------------------------------------------


def _oracle_bank():
    rng = np.random.default_rng(17)
    ar1 = [50.0]
    for _ in range(40):
        ar1.append(50.0 + 0.75 * (ar1[-1] - 50.0) + rng.normal(0, 2.0))
    trend = np.arange(30) * 4.0 + 20.0 + rng.normal(0, 0.5, 30)
    periodic = 300.0 + 30.0 * np.sin(np.arange(48) * 0.9) \
        + rng.normal(0, 3.0, 48)
    return {"ar1": np.asarray(ar1), "trend": trend, "periodic": periodic}


def test_batched_fit_tracks_scipy_oracle():
    """Per-order AIC within 4.0 of the constrained Nelder-Mead optimum
    for every order with <= 3 free coefficients; the two 4-coefficient
    orders (2,0,2)/(2,1,2) get a looser 12.0 (their CSS surface has
    boundary optima on the invertibility triangle that fixed-iteration
    LM does not always reach). What the product depends on — the AIC of
    the *selected* (argmin) order — stays within the tight bound."""
    pytest.importorskip("scipy")
    from arima_oracle import fit_css_oracle

    for name, y in _oracle_bank().items():
        row = np.zeros((1, MAX_OBS), np.float32)
        row[0, :len(y)] = y
        fit = fit_arima_grid(row, [len(y)])
        checked = 0
        best_batched = best_oracle = np.inf
        for i, order in enumerate(ORDER_GRID):
            if not bool(fit.valid[0, i]):
                continue
            oracle = fit_css_oracle(y, order)
            if oracle is None:
                continue
            p, _, q = order
            tol = 4.0 if p + q <= 3 else 12.0
            assert float(fit.aic[0, i]) <= oracle[0] + tol, \
                f"{name} order {order}: batched AIC " \
                f"{float(fit.aic[0, i]):.3f} vs oracle {oracle[0]:.3f}"
            best_batched = min(best_batched, float(fit.aic[0, i]))
            best_oracle = min(best_oracle, oracle[0])
            checked += 1
        assert checked >= 10, f"{name}: too few valid fits ({checked})"
        assert best_batched <= best_oracle + 4.0, \
            f"{name}: selected-order AIC {best_batched:.3f} vs oracle " \
            f"best {best_oracle:.3f}"


# --------------------------------------------------------------------------
# Hybrid ARIMA post-pass: engines vs the scalar oracle
# --------------------------------------------------------------------------


def _assert_run_equal(got, oracle, err, waste_exact=True):
    np.testing.assert_array_equal(got.invocations, oracle.invocations,
                                  err_msg=err)
    np.testing.assert_array_equal(got.cold, oracle.cold, err_msg=err)
    np.testing.assert_array_equal(got.final_prewarm, oracle.final_prewarm,
                                  err_msg=err)
    np.testing.assert_array_equal(got.final_keep_alive,
                                  oracle.final_keep_alive, err_msg=err)
    if waste_exact:
        np.testing.assert_array_equal(got.wasted_minutes,
                                      oracle.wasted_minutes, err_msg=err)
    else:
        np.testing.assert_allclose(got.wasted_minutes, oracle.wasted_minutes,
                                   rtol=1e-5, atol=1e-3, err_msg=err)


@pytest.mark.parametrize("seed", [3, 11, 29])
def test_hybrid_arima_replay_matches_scalar_oracle(seed):
    """cv_threshold=1.9 sits just under the bursty traces' CV, forcing a
    healthy mix of histogram- and ARIMA-governed apps; the batched replay
    must reproduce the scalar per-event oracle app-for-app."""
    trace = coarse_twoweek(n_apps=12, seed=seed)
    cfg = HybridConfig(histogram=CFG48.histogram, use_arima=True,
                       cv_threshold=1.9)
    oracle = simulate_scalar(trace, HybridHistogramPolicy(cfg))
    got = run(trace, HybridSpec.from_config(cfg), engine="fused")
    _assert_run_equal(got, oracle, f"hybrid+arima fused seed={seed}")
    chunked = run(trace, HybridSpec.from_config(cfg), engine="fused",
                  options=EngineOptions(app_chunk=5))
    _assert_run_equal(chunked, oracle,
                      f"hybrid+arima fused chunked seed={seed}")


def test_cluster_hybrid_arima_vector_matches_scalar():
    """The cluster engine's per-app ARIMA window loop was replaced by one
    batched ``hybrid_window_sequences`` call; vector == scalar pins it."""
    table = AppTable.from_spec(timer_heavy(90, days=0.5, seed=7))
    spec = HybridSpec(use_arima=True, cv_threshold=1.9)
    cl = ClusterSpec(n_workers=5, hbm_budget_bytes=float("inf"))
    vec = run_cluster(table, spec, cl, engine="vector")
    sca = run_cluster(table, spec, cl, engine="scalar")
    np.testing.assert_array_equal(vec.cold_pct_per_app, sca.cold_pct_per_app)
    np.testing.assert_array_equal(vec.latencies_s, sca.latencies_s)
    np.testing.assert_allclose(vec.wasted_gb_minutes, sca.wasted_gb_minutes,
                               rtol=1e-9)


# --------------------------------------------------------------------------
# SPES predictor family: cross-engine conformance
# --------------------------------------------------------------------------

SPES_SPECS = [SpesSpec(), SpesSpec(alpha=0.2, band_margin=0.05,
                                   band_sigma=4.0)]


@pytest.fixture(scope="module", params=["azure", "timers"])
def spes_case(request):
    if request.param == "azure":
        trace = azure_like(80, days=0.5, seed=3).materialize()
    else:
        trace = timer_heavy(80, days=0.5, seed=11).materialize()
    oracles = {spec: simulate_scalar(trace, SpesPolicy(spec.to_config()))
               for spec in SPES_SPECS}
    return request.param, trace, oracles


@pytest.mark.parametrize("engine,opts", [
    ("fused", {}), ("fused", {"app_chunk": 7}),
    ("pallas", {}), ("reference", {}),
])
def test_spes_engines_match_scalar_oracle(spes_case, engine, opts):
    """Cold counts, final windows AND waste bit-identical for every
    engine: the SPES state update computes in float64 and rounds once to
    float32, so XLA fusion choices cannot perturb the decision state."""
    name, trace, oracles = spes_case
    for spec, oracle in oracles.items():
        got = run(trace, spec, engine=engine,
                  options=EngineOptions(**opts))
        _assert_run_equal(got, oracle,
                          f"{spec.name}/{engine}/{opts} on {name}")


def test_spes_sweep_rows_match_single_runs(spes_case):
    name, trace, oracles = spes_case
    grid = sweep(traces=[trace], specs=list(SPES_SPECS))
    for s, spec in enumerate(SPES_SPECS):
        row = grid.row(0, s)
        _assert_run_equal(row, oracles[spec],
                          f"sweep row {s} ({spec.name}) on {name}")


def test_spes_cluster_vector_matches_scalar():
    table = AppTable.from_spec(azure_like(100, days=0.25, seed=11))
    cl = ClusterSpec(n_workers=5, hbm_budget_bytes=float("inf"))
    grid = sweep_cluster(table, [SpesSpec(), FixedSpec(keep_alive=10.0)],
                         [cl])
    for s, spec in enumerate([SpesSpec(), FixedSpec(keep_alive=10.0)]):
        vec = grid.row(0, s, 0)
        sca = run_cluster(table, spec, cl, engine="scalar")
        err = f"cluster {spec.name}"
        np.testing.assert_array_equal(vec.cold_pct_per_app,
                                      sca.cold_pct_per_app, err_msg=err)
        np.testing.assert_array_equal(vec.latencies_s, sca.latencies_s,
                                      err_msg=err)
        np.testing.assert_allclose(vec.wasted_gb_minutes,
                                   sca.wasted_gb_minutes, rtol=1e-9,
                                   err_msg=err)


# --------------------------------------------------------------------------
# The frontier scenario: SpesSpec Pareto-dominates the hybrid
# --------------------------------------------------------------------------


def _long_period_timers(n_apps=100, days=7, seed=42):
    """Timers with periods past the histogram's 240-minute range: every
    IT lands out of bounds, so the hybrid can only offer its (wide) ARIMA
    or standard-keep-alive windows while the SPES band tracks the period
    directly."""
    rng = np.random.default_rng(seed)
    duration = days * 24 * 60.0
    periods = rng.uniform(280.0, 420.0, n_apps)
    times = []
    for i in range(n_apps):
        phase = rng.uniform(0.0, periods[i])
        t = np.arange(phase, duration, periods[i])
        t = t + rng.normal(0.0, 0.5, t.shape)
        times.append(np.sort(np.clip(t, 0.0, duration - 1e-6)))
    return Trace(specs=None, times=times, duration_minutes=duration)


def test_spes_pareto_dominates_hybrid_on_long_period_timers():
    trace = _long_period_timers()
    hybrid = run(trace, HybridSpec(use_arima=True), engine="fused")
    h_cold = int(hybrid.cold.sum())
    h_waste = float(hybrid.wasted_minutes.sum())
    for spec in (SpesSpec(), SpesSpec(band_margin=0.05, band_sigma=4.0)):
        r = run(trace, spec, engine="fused")
        cold, waste = int(r.cold.sum()), float(r.wasted_minutes.sum())
        assert cold < h_cold and waste < h_waste, \
            f"{spec.name}: ({cold}, {waste:.0f}) does not dominate " \
            f"hybrid ({h_cold}, {h_waste:.0f})"
