"""Unit tests for the batched forecasting subsystem (`repro.forecast`).

Covers the batch-size/padding bit-invariance contract of the grid fit,
the streaming forecaster front-end (including the ``state_dict``
round-trip regression: the legacy class silently dropped the refit
cadence), and the ``repro.core.arima`` deprecation shims.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.forecast import (ArimaForecaster, DEFAULT_REFIT_EVERY, MAX_OBS,
                            ORDER_GRID, fit_arima_grid, fit_window)

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


def _series_bank(n=8, seed=7):
    """Deterministic mix of AR(1), trends, periodic and noisy rows with
    ragged lengths."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        length = int(rng.integers(4, MAX_OBS + 1))
        kind = i % 4
        if kind == 0:
            y = [10.0]
            for _ in range(length - 1):
                y.append(0.7 * y[-1] + 3.0 + rng.normal(0, 0.5))
            y = np.asarray(y)
        elif kind == 1:
            y = np.arange(length) * 2.5 + 5.0 + rng.normal(0, 0.1, length)
        elif kind == 2:
            y = 60.0 + 10.0 * np.sin(np.arange(length) * 0.7) \
                + rng.normal(0, 1.0, length)
        else:
            y = rng.uniform(1.0, 500.0, length)
        out.append(y.astype(np.float32))
    return out


def _pad_rows(series, width=MAX_OBS):
    rows = np.zeros((len(series), width), np.float32)
    lens = np.zeros(len(series), np.int32)
    for i, y in enumerate(series):
        rows[i, :len(y)] = y
        lens[i] = len(y)
    return rows, lens


# --------------------------------------------------------------------------
# Grid fit: batch-size / padding bit-invariance
# --------------------------------------------------------------------------


def test_fit_is_batch_size_invariant():
    """Rows are fit independently: a [8, 64] batch and eight [1, 64]
    batches produce bit-identical results across every GridFit field."""
    series = _series_bank()
    rows, lens = _pad_rows(series)
    full = fit_arima_grid(rows, lens)
    for i in range(len(series)):
        single = fit_arima_grid(rows[i:i + 1], lens[i:i + 1])
        for field in full._fields:
            np.testing.assert_array_equal(
                getattr(full, field)[i], getattr(single, field)[0],
                err_msg=f"row {i} field {field}")


def test_fit_is_padding_invariant():
    """Narrow input rows pad to MAX_OBS internally: passing a [B, 40]
    array equals passing the pre-padded [B, 64] array."""
    series = [y[:40] for y in _series_bank(n=4, seed=11)]
    narrow_rows, lens = _pad_rows(series, width=40)
    wide_rows, _ = _pad_rows(series, width=MAX_OBS)
    a = fit_arima_grid(narrow_rows, lens)
    b = fit_arima_grid(wide_rows, lens)
    for field in a._fields:
        np.testing.assert_array_equal(getattr(a, field), getattr(b, field),
                                      err_msg=field)


def test_fit_input_validation():
    with pytest.raises(ValueError, match="batch, obs"):
        fit_arima_grid(np.zeros(8, np.float32), [8])
    with pytest.raises(ValueError, match="one int per series row"):
        fit_arima_grid(np.zeros((2, 8), np.float32), [8])
    with pytest.raises(ValueError, match="MAX_OBS"):
        fit_arima_grid(np.zeros((1, MAX_OBS + 1), np.float32), [MAX_OBS + 1])


def test_fit_window_truncates_to_trailing_window():
    long = list(np.linspace(1.0, 400.0, MAX_OBS + 20, dtype=np.float32))
    a = fit_window(long)
    b = fit_window(long[-MAX_OBS:])
    np.testing.assert_array_equal(a.aic, b.aic)
    np.testing.assert_array_equal(a.pred, b.pred)


def test_grid_matches_legacy_enumeration():
    assert len(ORDER_GRID) == 17
    assert (0, 0, 0) not in ORDER_GRID
    assert ORDER_GRID[0] == (0, 0, 1)
    assert all(p <= 2 and d <= 1 and q <= 2 for p, d, q in ORDER_GRID)


# --------------------------------------------------------------------------
# Streaming forecaster
# --------------------------------------------------------------------------


def test_forecaster_abstains_below_min_obs():
    f = ArimaForecaster()
    assert f.forecast() is None
    f.observe(100.0)
    f.observe(101.0)
    assert f.forecast() is None


def test_forecaster_constant_series_predicts_the_constant():
    f = ArimaForecaster()
    for _ in range(12):
        f.observe(300.0)
    assert f.forecast() == pytest.approx(300.0, rel=0.01)


def test_forecaster_rolls_obs_window():
    f = ArimaForecaster()
    for i in range(MAX_OBS + 10):
        f.observe(float(i))
    assert f.n_obs == MAX_OBS


def test_state_dict_roundtrip_preserves_cadence():
    """Regression: the legacy state_dict dropped everything but the
    observations, so a restored forecaster re-selected its order on the
    next call regardless of where the refit cadence stood. The restored
    forecaster must now produce the *identical* forecast sequence."""
    rng = np.random.default_rng(3)
    a = ArimaForecaster(refit_every=3)
    preds = []
    for _ in range(7):
        a.observe(float(rng.uniform(100.0, 400.0)))
        preds.append(a.forecast())

    state = a.state_dict()
    assert state["refit_every"] == 3
    assert state["since_auto"] == a._since_auto
    assert state["order"] == a._order

    b = ArimaForecaster()           # default cadence, then restored over
    b.load_state_dict(state)
    assert b._refit_every == 3

    future = [float(rng.uniform(100.0, 400.0)) for _ in range(9)]
    seq_a, seq_b = [], []
    for x in future:
        a.observe(x)
        seq_a.append(a.forecast())
        b.observe(x)
        seq_b.append(b.forecast())
    assert seq_a == seq_b


def test_state_dict_accepts_legacy_obs_only_checkpoints():
    f = ArimaForecaster(refit_every=5)
    f.load_state_dict({"obs": [10.0, 20.0, 30.0, 40.0]})
    assert f.n_obs == 4
    assert f._refit_every == DEFAULT_REFIT_EVERY
    assert f.forecast() is not None


# --------------------------------------------------------------------------
# repro.core.arima deprecation shims
# --------------------------------------------------------------------------


def test_core_arima_names_warn_and_still_work():
    import repro.core.arima as legacy
    with pytest.warns(DeprecationWarning, match="repro.forecast"):
        fit_arima = legacy.fit_arima
    with pytest.warns(DeprecationWarning, match="repro.forecast"):
        auto_arima = legacy.auto_arima
    with pytest.warns(DeprecationWarning, match="repro.forecast"):
        forecaster_cls = legacy.ArimaForecaster
    assert forecaster_cls is ArimaForecaster

    y = np.arange(20, dtype=float) * 2.0 + 5.0
    m = auto_arima(y)
    assert m is not None
    assert m.forecast(y) == pytest.approx(45.0, abs=3.0)
    m1 = fit_arima(y, (1, 0, 0))
    assert m1 is not None and len(m1.ar) == 1
    with pytest.raises(ValueError, match="outside the supported grid"):
        fit_arima(y, (5, 0, 0))
    with pytest.raises(AttributeError, match="no attribute"):
        legacy.not_a_thing


def test_library_import_does_not_pull_scipy():
    """scipy is a dev-only dependency: importing the policy stack, the
    forecast subsystem, and even the deprecation shim module must not
    import it (only the test oracle and the benchmark baseline may)."""
    code = ("import sys; "
            "import repro.forecast, repro.core.policy, repro.core.arima, "
            "repro.core.experiment; "
            "sys.exit(1 if 'scipy' in sys.modules else 0)")
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
