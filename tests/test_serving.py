"""Serving runtime tests: warm pool semantics, cluster sim, engine,
controller fault tolerance, straggler hedging."""
import numpy as np
import pytest

from repro.configs import get, reduced
from repro.core.experiment import FixedSpec, HybridSpec
from repro.core.policy import FixedKeepAlivePolicy
from repro.core.workload import AppSpec, Trace, generate_trace
from repro.runtime.straggler import HedgePolicy
from repro.serving.cluster_sim import ClusterConfig, ClusterSim
from repro.serving.engine import ServeEngine
from repro.serving.registry import ModelEndpoint, Registry
from repro.serving.warmpool import WarmPool

MIN = 60.0


def tiny_registry(n=4, weight_bytes=int(1e9)):
    reg = Registry()
    cfg = reduced(get("smollm-135m"))
    for i in range(n):
        reg.register(ModelEndpoint(app_id=f"app-{i:06d}", cfg=cfg, seed=i,
                                   weight_bytes=weight_bytes))
    return reg


def test_warmpool_fixed_keepalive():
    reg = tiny_registry()
    # legacy stateful Policy objects are still accepted alongside PolicySpec
    pool = WarmPool(reg, FixedKeepAlivePolicy(10.0))
    cold, _ = pool.on_request("app-000000", 0.0)
    assert cold
    pool.on_request_end("app-000000", 1.0)
    # within keep-alive: warm
    cold, lat = pool.on_request("app-000000", 1.0 + 5 * MIN)
    assert not cold and lat == 0.0
    pool.on_request_end("app-000000", 1.0 + 5 * MIN)
    # beyond keep-alive: cold again
    cold, lat = pool.on_request("app-000000", 1.0 + 5 * MIN + 11 * MIN)
    assert cold and lat > 0.0


def test_warmpool_prewarm_hits():
    """Once the histogram learns a 30-min period, arrivals are warm AND the
    image is not resident for the whole gap (memory saved)."""
    reg = tiny_registry()
    pool = WarmPool(reg, HybridSpec(use_arima=False))
    t = 0.0
    colds = []
    for i in range(40):
        cold, _ = pool.on_request("app-000000", t)
        colds.append(cold)
        pool.on_request_end("app-000000", t + 1.0)
        t += 30 * MIN
    # after learning, no cold starts
    assert not any(colds[-10:])
    st = pool.state["app-000000"]
    # image was unloaded between invocations (prewarm scheduled)
    assert st.windows.prewarm > 0
    stats = pool.finalize(t)
    # resident time far below the no-unload bound
    no_unload_bound = t * reg.get("app-000000").weight_bytes
    assert stats.resident_byte_seconds < 0.35 * no_unload_bound


def test_warmpool_budget_eviction():
    reg = tiny_registry(n=4, weight_bytes=int(1e9))
    pool = WarmPool(reg, FixedSpec(240.0), budget_bytes=2.5e9)
    for i, t in [(0, 0.0), (1, 60.0), (2, 120.0)]:
        pool.on_request(f"app-{i:06d}", t)
        pool.on_request_end(f"app-{i:06d}", t + 1)
    # only 2 fit; at least one eviction happened
    loaded = [a for a, s in pool.state.items() if s.loaded]
    assert len(loaded) <= 2
    assert pool.stats.evictions >= 1


def test_warmpool_tick_expires_before_prewarming():
    """Regression: a pre-warm _load inside tick() used to fire before other
    apps' keep-alive expiries were processed (dict order), so _ensure_budget
    evicted an app whose keep-alive had already lapsed — a spurious eviction
    plus mid-iteration mutation of the states being looped over."""
    reg = tiny_registry(n=2, weight_bytes=int(1e9))
    pool = WarmPool(reg, FixedSpec(10.0), budget_bytes=1e9)
    # app 1 first in dict order, with a due pre-warm
    st_b = pool._st("app-000001")
    # app 0 loaded, keep-alive expiring before the tick time
    cold, _ = pool.on_request("app-000000", 0.0)
    assert cold
    pool.on_request_end("app-000000", 0.0)
    pool.state["app-000000"].unload_at = 50.0
    st_b.prewarm_at = 80.0
    pool.tick(100.0)
    # expiry freed the budget: the pre-warm must NOT have evicted app 0
    assert pool.stats.evictions == 0
    assert pool.stats.prewarms == 1
    assert not pool.state["app-000000"].loaded
    assert st_b.loaded
    assert st_b.prewarm_at == float("inf")


def test_warmpool_tick_prewarms_fire_in_time_order():
    """Two due pre-warms, budget for one: the later-scheduled pre-warm is
    processed last, so it wins the single slot (deterministically, not in
    dict insertion order)."""
    reg = tiny_registry(n=2, weight_bytes=int(1e9))
    pool = WarmPool(reg, FixedSpec(10.0), budget_bytes=1e9)
    # insert app 1 first so dict order disagrees with schedule order
    st_b = pool._st("app-000001")
    st_a = pool._st("app-000000")
    st_b.prewarm_at = 20.0
    st_a.prewarm_at = 10.0
    pool.tick(30.0)
    assert pool.stats.prewarms == 2
    assert st_b.loaded              # later schedule processed second, kept
    assert not st_a.loaded          # evicted by the second pre-warm
    assert pool.stats.evictions == 1


def test_warmpool_pinned_app_never_evicted():
    """Regression: ``on_request`` used to pin executing apps only via
    ``unload_at = inf`` — indistinguishable from never-unload apps — so a
    concurrent pre-warm's budget pass could evict an app mid-request. The
    explicit ``pinned`` flag excludes it; with nothing else evictable the
    pool proceeds over budget and counts the overflow instead."""
    reg = tiny_registry(n=2, weight_bytes=int(1e9))
    pool = WarmPool(reg, FixedSpec(10.0), budget_bytes=1.5e9)
    cold, _ = pool.on_request("app-000000", 0.0)   # executing: pinned
    assert cold and pool.state["app-000000"].pinned
    # a pre-warm for app 1 fires while app 0 is still mid-request
    pool._st("app-000001").prewarm_at = 10.0
    pool.tick(20.0)
    st = pool.state["app-000000"]
    assert st.loaded and st.pinned      # NOT evicted mid-request
    assert pool.stats.evictions == 0
    assert pool.stats.budget_overflows == 1
    pool.on_request_end("app-000000", 30.0)
    assert not pool.state["app-000000"].pinned


def test_warmpool_single_image_over_budget_raises():
    reg = tiny_registry(n=2, weight_bytes=int(4e9))
    with pytest.raises(ValueError, match="larger than the budget"):
        WarmPool(reg, FixedSpec(10.0), budget_bytes=2e9)


def test_warmpool_state_roundtrip():
    reg = tiny_registry()
    pool = WarmPool(reg, HybridSpec(use_arima=False))
    t = 0.0
    for _ in range(20):
        pool.on_request("app-000000", t)
        pool.on_request_end("app-000000", t + 1.0)
        t += 15 * MIN
    sd = pool.state_dict()
    pool2 = WarmPool(reg, HybridSpec(use_arima=False))
    pool2.load_state_dict(sd)
    # the learned windows survive the controller restart
    assert pool2.state["app-000000"].windows == pool.state["app-000000"].windows
    c1, _ = pool.on_request("app-000000", t)
    c2, _ = pool2.on_request("app-000000", t)
    assert c1 == c2


def _periodic_trace(n_apps=6, period=20.0, days=0.5):
    times, specs = [], []
    for i in range(n_apps):
        t = np.arange(i * 2.0, days * 1440.0, period)
        times.append(t)
        specs.append(AppSpec(app_id=f"app-{i:06d}", pattern="periodic",
                             rate_per_day=1440.0 / period,
                             period_minutes=period, exec_time_s=0.5,
                             memory_mb=100, n_functions=1, triggers=("timer",)))
    return Trace(specs=specs, times=times, duration_minutes=days * 1440.0)


def test_cluster_sim_hybrid_beats_fixed_on_memory():
    trace = _periodic_trace()
    reg = tiny_registry(n=6)
    fixed = ClusterSim(reg, FixedSpec(10.0),
                       ClusterConfig(n_workers=3)).run(trace)
    hyb = ClusterSim(reg, HybridSpec(use_arima=False),
                     ClusterConfig(n_workers=3)).run(trace)
    assert hyb.cold_pct_p75 <= fixed.cold_pct_p75 + 1e-9
    assert hyb.wasted_gb_minutes < fixed.wasted_gb_minutes


def test_cluster_sim_controller_restart_mid_run():
    trace = _periodic_trace()
    reg = tiny_registry(n=6)
    res = ClusterSim(reg, HybridSpec(use_arima=False),
                     ClusterConfig(n_workers=3,
                                   checkpoint_at_minute=300.0)).run(trace)
    assert res.restored_mid_run
    # restart must not blow up cold starts (windows were persisted)
    assert res.cold_pct_p75 < 30.0


def test_hedging_improves_tail():
    rng = np.random.default_rng(0)
    on = HedgePolicy(straggler_prob=0.05, straggler_factor=10.0, enabled=True)
    off = HedgePolicy(straggler_prob=0.05, straggler_factor=10.0,
                      enabled=False)
    lat_on = [on.effective_latency(1.0, rng) for _ in range(4000)]
    rng = np.random.default_rng(0)
    lat_off = [off.effective_latency(1.0, rng) for _ in range(4000)]
    assert np.percentile(lat_on, 99) < 0.7 * np.percentile(lat_off, 99)


def test_engine_end_to_end_cold_vs_warm():
    """Real JAX executions: a warm request must be much faster than a cold
    one (weight load + compile dominate)."""
    import jax.numpy as jnp
    reg = tiny_registry(n=1)
    eng = ServeEngine(reg)
    app = "app-000000"
    t_load = eng.load(app)
    toks = jnp.zeros((1, 8), jnp.int32)
    _, t_first = eng.generate(app, toks, max_new=4, max_len=16)   # compiles
    _, t_warm = eng.generate(app, toks, max_new=4, max_len=16)
    assert t_warm < t_first            # executable cache hit
    assert eng.is_loaded(app)
    eng.unload(app)
    assert not eng.is_loaded(app)
