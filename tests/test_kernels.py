"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
sweeping shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def rand(*s, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=s), dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (1, 128, 2, 2, 32),      # MHA
    (2, 256, 4, 2, 64),      # GQA
    (1, 512, 8, 1, 64),      # MQA
    (2, 128, 4, 4, 128),     # wide head
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, S, Hq, Hkv, D, dtype):
    q, k, v = rand(B, S, Hq, D, dtype=dtype), rand(B, S, Hkv, D, dtype=dtype), \
        rand(B, S, Hkv, D, dtype=dtype)
    out = ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = jnp.moveaxis(
        ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                          jnp.moveaxis(v, 1, 2), causal=True), 1, 2)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=TOL[dtype],
                               rtol=TOL[dtype])


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_window(window):
    B, S, Hq, Hkv, D = 2, 256, 4, 2, 64
    q, k, v = rand(B, S, Hq, D), rand(B, S, Hkv, D), rand(B, S, Hkv, D)
    out = ops.flash_attention(q, k, v, causal=True, window=window, bq=64, bk=64)
    want = jnp.moveaxis(
        ref.attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                          jnp.moveaxis(v, 1, 2), causal=True, window=window),
        1, 2)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("Skv,kv_len", [(256, 256), (512, 300), (512, 1),
                                        (1024, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(Skv, kv_len, dtype):
    B, Hq, Hkv, D = 2, 4, 2, 64
    group = Hq // Hkv
    q = rand(B, 1, Hq, D, dtype=dtype)
    k = rand(B, Skv, Hkv, D, dtype=dtype)
    v = rand(B, Skv, Hkv, D, dtype=dtype)
    out = ops.decode_attention(q, k, v, jnp.int32(kv_len), bk=128)
    want = ref.decode_attention_ref(
        q[:, 0].reshape(B, Hkv, group, D), jnp.moveaxis(k, 1, 2),
        jnp.moveaxis(v, 1, 2), jnp.int32(kv_len)).reshape(B, 1, Hq, D)
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=TOL[dtype],
                               rtol=TOL[dtype])


@pytest.mark.parametrize("l,chunk", [(64, 16), (128, 32), (256, 64),
                                     (128, 128)])
@pytest.mark.parametrize("n,p", [(8, 16), (16, 32)])
def test_ssd_scan(l, chunk, n, p):
    b, h = 2, 3
    x = rand(b, l, h, p)
    dt = jnp.abs(rand(b, l, h)) * 0.1
    A = -jnp.abs(rand(h))
    Bm, Cm = rand(b, l, n), rand(b, l, n)
    y, s = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, sr = ref.ssd_ref(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(y, yr, atol=5e-5, rtol=5e-4)
    np.testing.assert_allclose(s, sr, atol=5e-5, rtol=5e-4)


def test_ssd_matches_sequential_recurrence():
    """SSD chunked form == naive per-token recurrence (independent oracle)."""
    b, l, h, p, n = 1, 32, 2, 8, 4
    x = rand(b, l, h, p)
    dt = jnp.abs(rand(b, l, h)) * 0.2
    A = -jnp.abs(rand(h))
    Bm, Cm = rand(b, l, n), rand(b, l, n)
    y, _ = ref.ssd_ref(x, dt, A, Bm, Cm, chunk=8)
    S = np.zeros((b, h, n, p))
    want = np.zeros((b, l, h, p))
    for t in range(l):
        a = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])      # [b,h]
        S = S * a[..., None, None] + np.einsum(
            "bn,bh,bhp->bhnp", Bm[:, t], dt[:, t], x[:, t])
        want[:, t] = np.einsum("bn,bhnp->bhp", Cm[:, t], S)
    np.testing.assert_allclose(y, want, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("L,D,bt,bd", [(64, 32, 16, 16), (128, 64, 32, 32),
                                       (256, 128, 64, 128), (128, 64, 128, 64)])
def test_rglru_scan(L, D, bt, bd):
    B = 2
    a = jax.nn.sigmoid(rand(B, L, D)) * 0.98
    bi = rand(B, L, D)
    h, hl = ops.rglru_scan(bi, a, block_t=bt, block_d=bd)
    hr, hlr = ref.rglru_ref(bi, a)
    np.testing.assert_allclose(h, hr, atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(hl, hlr, atol=2e-5, rtol=2e-4)


def test_rglru_matches_sequential():
    B, L, D = 1, 48, 8
    a = jax.nn.sigmoid(rand(B, L, D)) * 0.95
    bi = rand(B, L, D)
    h, _ = ops.rglru_scan(bi, a, block_t=16, block_d=8)
    hs = np.zeros((B, D))
    want = np.zeros((B, L, D))
    an, bn = np.asarray(a), np.asarray(bi)
    for t in range(L):
        hs = an[:, t] * hs + np.sqrt(1 - an[:, t] ** 2) * bn[:, t]
        want[:, t] = hs
    np.testing.assert_allclose(h, want, atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("napps,nbins,tile", [(64, 48, 32), (128, 240, 64),
                                              (32, 16, 32)])
def test_policy_update_kernel(napps, nbins, tile):
    counts = jnp.asarray(RNG.integers(0, 5, (napps, nbins)), jnp.int32)
    oob = jnp.asarray(RNG.integers(0, 3, napps), jnp.int32)
    total = counts.sum(1)
    cvs = total.astype(jnp.float32)
    cvss = jnp.asarray((np.asarray(counts) ** 2).sum(1), jnp.float32)
    bins = jnp.asarray(RNG.integers(0, nbins + 8, napps), jnp.int32)
    active = jnp.asarray(RNG.integers(0, 2, napps), jnp.int32)
    kw = dict(range_minutes=float(nbins))
    outs = ops.policy_update(counts, oob, total, cvs, cvss, bins, active,
                             tile_apps=tile, **kw)
    refs = ref.policy_update_ref(counts, oob, total, cvs, cvss, bins, active,
                                 **kw)
    for o, r in zip(outs, refs):
        np.testing.assert_allclose(np.asarray(o, np.float64),
                                   np.asarray(r, np.float64), atol=1e-5)


def test_policy_kernel_matches_core_scalar():
    """Kernel windows == repro.core.AppHistogram windows on the same stream."""
    from repro.core.histogram import AppHistogram, HistogramConfig
    cfg = HistogramConfig(range_minutes=48.0)
    nbins = cfg.n_bins
    its = RNG.integers(0, 60, 40)  # some OOB
    h = AppHistogram(cfg)
    counts = jnp.zeros((8, nbins), jnp.int32)
    oob = jnp.zeros((8,), jnp.int32)
    total = jnp.zeros((8,), jnp.int32)
    cvs = jnp.zeros((8,), jnp.float32)
    cvss = jnp.zeros((8,), jnp.float32)
    prewarm = keep = None
    for it in its:
        h.record(float(it))
        bins = jnp.full((8,), int(it), jnp.int32)
        active = jnp.ones((8,), jnp.int32)
        (counts, oob, total, cvs, cvss, prewarm, keep, use_hist) = \
            ops.policy_update(counts, oob, total, cvs, cvss, bins, active,
                              range_minutes=cfg.range_minutes, tile_apps=8)
    pw, ka = h.windows()
    seen = h.total + h.oob
    oobf = h.oob_fraction
    expect_hist = (seen >= 5 and h.cv >= 2.0 and h.total > 0 and oobf <= 0.5)
    if expect_hist:
        np.testing.assert_allclose(float(prewarm[0]), pw, atol=1e-4)
        np.testing.assert_allclose(float(keep[0]), ka, atol=1e-4)
    else:
        assert float(prewarm[0]) == 0.0
        np.testing.assert_allclose(float(keep[0]), cfg.range_minutes)
