"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.histogram import AppHistogram, HistogramConfig
from repro.core.policy import (FixedKeepAlivePolicy, HybridConfig,
                               HybridHistogramPolicy, PolicyWindows, is_warm,
                               loaded_idle_time)
from repro.core.workload import AppSpec, Trace
from repro.core.simulator import simulate_scalar

its = st.floats(min_value=0.0, max_value=5000.0, allow_nan=False)


@settings(max_examples=50, deadline=None)
@given(st.lists(its, min_size=1, max_size=300))
def test_histogram_counts_conserved(values):
    cfg = HistogramConfig(range_minutes=240.0)
    h = AppHistogram(cfg)
    for v in values:
        h.record(v)
    assert h.total + h.oob == len(values)
    assert h.counts.sum() == h.total
    assert h.cv >= 0.0


@settings(max_examples=50, deadline=None)
@given(st.lists(its, min_size=1, max_size=200))
def test_histogram_windows_bounds(values):
    cfg = HistogramConfig()
    h = AppHistogram(cfg)
    for v in values:
        h.record(v)
    pw, ka = h.windows()
    assert pw >= 0.0
    assert ka >= 0.0
    # windows never exceed the (margin-inflated) histogram range
    assert pw + ka <= cfg.range_minutes * (1.0 + cfg.margin) + 1e-6


@settings(max_examples=50, deadline=None)
@given(st.floats(0.0, 500.0), st.floats(0.0, 500.0), st.floats(0.0, 1000.0))
def test_warmth_waste_consistency(prewarm, keep, it):
    w = PolicyWindows(prewarm, keep)
    waste = loaded_idle_time(it, w)
    assert 0.0 <= waste <= max(keep, 0.0) + 1e-9
    if is_warm(it, w):
        # a warm hit means the image was resident at arrival; for prewarmed
        # windows the resident span ends exactly at the arrival
        assert waste <= it + 1e-9


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(1.0, 2000.0), min_size=2, max_size=60),
       st.floats(1.0, 240.0))
def test_fixed_policy_cold_count_formula(iats, keep):
    """Scalar sim == direct formula for the fixed policy."""
    times = np.cumsum(np.asarray(iats))
    spec = AppSpec(app_id="app-000000", pattern="poisson", rate_per_day=1.0,
                   period_minutes=1.0, exec_time_s=0.0, memory_mb=1.0,
                   n_functions=1, triggers=("http",))
    trace = Trace(specs=[spec], times=[times],
                  duration_minutes=float(times[-1] + 1))
    res = simulate_scalar(trace, FixedKeepAlivePolicy(keep),
                          include_trailing=False)
    expected_cold = 1 + int(np.sum(np.diff(times) > keep))
    assert res.cold[0] == expected_cold
    expected_waste = float(np.minimum(np.diff(times), keep).sum())
    assert np.isclose(res.wasted_minutes[0], expected_waste, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.floats(1.0, 400.0), min_size=5, max_size=80))
def test_hybrid_never_negative_windows(iats):
    p = HybridHistogramPolicy(HybridConfig(use_arima=False))
    w = p.on_invocation("a", None)
    for it in iats:
        w = p.on_invocation("a", it)
        assert w.prewarm >= 0.0
        assert w.keep_alive >= 0.0


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 64), st.integers(2, 120))
def test_batched_policy_kernel_invariants(napps, nbins):
    """Kernel outputs: counts conserved, windows in range, use_hist sane."""
    import jax.numpy as jnp
    from repro.kernels import ops
    rng = np.random.default_rng(napps * 1000 + nbins)
    counts = jnp.asarray(rng.integers(0, 4, (napps, nbins)), jnp.int32)
    total = counts.sum(1)
    oob = jnp.asarray(rng.integers(0, 2, napps), jnp.int32)
    cvs = total.astype(jnp.float32)
    cvss = jnp.asarray((np.asarray(counts) ** 2).sum(1), jnp.float32)
    bins = jnp.asarray(rng.integers(0, nbins + 4, napps), jnp.int32)
    active = jnp.asarray(rng.integers(0, 2, napps), jnp.int32)
    (nc, no, nt, _, _, pw, ka, uh) = ops.policy_update(
        counts, oob, total, cvs, cvss, bins, active,
        range_minutes=float(nbins), tile_apps=min(napps, 32))
    in_b = (np.asarray(active) != 0) & (np.asarray(bins) < nbins)
    oob_b = (np.asarray(active) != 0) & (np.asarray(bins) >= nbins)
    np.testing.assert_array_equal(np.asarray(nt),
                                  np.asarray(total) + in_b)
    np.testing.assert_array_equal(np.asarray(no), np.asarray(oob) + oob_b)
    assert np.all(np.asarray(nc).sum(1) == np.asarray(nt))
    assert np.all(np.asarray(pw) >= 0)
    assert np.all(np.asarray(ka) >= 0)
    assert np.all(np.asarray(pw) + np.asarray(ka)
                  <= float(nbins) * 1.1 + 1e-4)
