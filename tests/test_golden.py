"""Golden-trace regression tests: every engine vs checked-in oracle results.

The fixtures under ``tests/golden/`` pin per-app cold counts, final policy
windows, and wasted minutes of the float64 scalar oracle on deterministic
seeded traces. Any edit to the hybrid decision math (now single-sourced in
``repro.core.policy_math``) that shifts a verdict fails here loudly;
deliberate formula changes re-record via ``scripts/regen_golden.py``.
"""
import json
import os

import numpy as np
import pytest

from repro.core.experiment import HybridSpec, run

from golden_traces import GOLDEN_TRACES

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

ENGINES = {
    "scalar": lambda t, cfg: run(t, HybridSpec.from_config(cfg),
                                 engine="scalar"),
    "jnp_f64": lambda t, cfg: run(t, HybridSpec.from_config(cfg),
                                  engine="fused"),
    "pallas_f32": lambda t, cfg: run(t, HybridSpec.from_config(cfg),
                                     engine="pallas"),
    "reference_f32": lambda t, cfg: run(t, HybridSpec.from_config(cfg),
                                        engine="reference"),
}


@pytest.fixture(scope="module", params=sorted(GOLDEN_TRACES))
def golden_case(request):
    name = request.param
    path = os.path.join(GOLDEN_DIR, f"{name}.json")
    with open(path) as f:
        want = json.load(f)
    make_trace, cfg = GOLDEN_TRACES[name]
    return name, make_trace(), cfg, want


@pytest.mark.parametrize("engine", sorted(ENGINES))
def test_golden_trace(golden_case, engine):
    name, trace, cfg, want = golden_case
    assert trace.n_apps == want["n_apps"]
    res = ENGINES[engine](trace, cfg)
    err = f"{engine} vs golden {name} (see scripts/regen_golden.py)"
    np.testing.assert_array_equal(res.invocations,
                                  np.asarray(want["invocations"]),
                                  err_msg=err)
    np.testing.assert_array_equal(res.cold, np.asarray(want["cold"]),
                                  err_msg=err)
    np.testing.assert_array_equal(res.final_prewarm,
                                  np.asarray(want["final_prewarm"]),
                                  err_msg=err)
    np.testing.assert_array_equal(res.final_keep_alive,
                                  np.asarray(want["final_keep_alive"]),
                                  err_msg=err)
    # float64 engines reproduce the recorded waste exactly (JSON round-trips
    # float64); float32 engines accumulate their gap terms in float32
    tol = dict(rtol=0, atol=0) if engine in ("scalar", "jnp_f64") \
        else dict(rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(res.wasted_minutes,
                               np.asarray(want["wasted_minutes"]),
                               err_msg=err, **tol)
