"""Cluster-engine conformance: the vectorized columnar engine vs the
per-event scalar oracle, plus the checked-in small-fleet golden.

The exactness contract (see ``repro.serving.cluster_vector``): cold counts,
per-app cold %, latencies and every load/unload/prewarm counter are
bit-identical between engines; resident byte-seconds (and hence wasted
GB-minutes) agree to float64 accumulation-order tolerance. The suite pins
that contract across policy families, both balancing modes, hedging,
controller checkpoint/restore (including the ``checkpoint_at_minute=0.0``
regression) and the HBM eviction refusal.
"""
import json
import os

import numpy as np
import pytest

from repro.core.experiment import (FixedSpec, HybridSpec, NoUnloadSpec,
                                   as_spec, run, sweep)
from repro.core.workload import Trace
from repro.core.workload_spec import WorkloadSpec, azure_like, flash_crowd
from repro.runtime.straggler import HedgePolicy
from repro.serving.apptable import AppTable, fnv1a64, fnv1a64_app_indices
from repro.serving.cluster_sim import ClusterSim
from repro.serving.cluster_vector import (ClusterSpec, run_cluster,
                                          sweep_cluster)

from golden_traces import cluster_small_fleet

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

_COUNTERS = ("cold_starts", "warm_starts", "prewarms", "unloads",
             "evictions", "bytes_moved")


@pytest.fixture(scope="module")
def azure_table():
    return AppTable.from_spec(
        azure_like(220, days=0.25, seed=11, max_events=24))


@pytest.fixture(scope="module")
def flash_table():
    return AppTable.from_spec(
        flash_crowd(160, days=0.25, seed=3, max_events=48))


def _assert_results_equal(vec, sca, err=""):
    np.testing.assert_array_equal(vec.cold_pct_per_app, sca.cold_pct_per_app,
                                  err_msg=err)
    np.testing.assert_array_equal(vec.latencies_s, sca.latencies_s,
                                  err_msg=err)
    np.testing.assert_allclose(vec.wasted_gb_minutes, sca.wasted_gb_minutes,
                               rtol=1e-9, err_msg=err)
    assert len(vec.stats_per_worker) == len(sca.stats_per_worker), err
    for w, (sv, ss) in enumerate(zip(vec.stats_per_worker,
                                     sca.stats_per_worker)):
        for key in _COUNTERS:
            assert sv[key] == ss[key], f"{err}: worker {w} {key}"
        np.testing.assert_allclose(sv["resident_byte_seconds"],
                                   ss["resident_byte_seconds"], rtol=1e-9,
                                   err_msg=f"{err}: worker {w}")
    assert vec.restored_mid_run == sca.restored_mid_run, err


def _conform(table, policy, cluster):
    vec = run_cluster(table, policy, cluster, engine="vector")
    sca = run_cluster(table, policy, cluster, engine="scalar")
    _assert_results_equal(vec, sca,
                          err=f"{type(policy).__name__}/{cluster.name}")
    return vec


# --------------------------------------------------------------------------
# Engine conformance across policy families and balancing modes
# --------------------------------------------------------------------------


@pytest.mark.parametrize("policy,balancing", [
    (HybridSpec(), "affinity"),
    (HybridSpec(), "hash"),
    (FixedSpec(keep_alive=20.0), "affinity"),
    (NoUnloadSpec(), "hash"),
])
def test_conformance_azure(azure_table, policy, balancing):
    _conform(azure_table, policy,
             ClusterSpec(n_workers=7, hbm_budget_bytes=float("inf"),
                         balancing=balancing))


def test_conformance_flash_crowd(flash_table):
    res = _conform(flash_table, HybridSpec(),
                   ClusterSpec(n_workers=5, hbm_budget_bytes=float("inf")))
    assert res.latencies_s.size == flash_table.n_events


def test_hedging_parity(azure_table):
    # Same rank-indexed uniform streams in both engines -> identical
    # stragglers, hence bit-equal latencies even under hedging.
    hedged = ClusterSpec(n_workers=7, hbm_budget_bytes=float("inf"),
                         hedge=HedgePolicy())
    res = _conform(azure_table, FixedSpec(keep_alive=15.0), hedged)
    plain = run_cluster(azure_table, FixedSpec(keep_alive=15.0),
                        ClusterSpec(n_workers=7,
                                    hbm_budget_bytes=float("inf")),
                        engine="vector")
    assert not np.array_equal(res.latencies_s, plain.latencies_s)


# --------------------------------------------------------------------------
# Controller checkpoint/restore
# --------------------------------------------------------------------------


def test_checkpoint_at_zero_regression(azure_table):
    """checkpoint_at_minute=0.0 means "checkpoint at the first event" — a
    falsy check used to silently drop it. Both engines must restore, and the
    save/restore round-trip must not perturb the trajectory."""
    base = dict(n_workers=6, hbm_budget_bytes=float("inf"))
    ck0 = _conform(azure_table, HybridSpec(),
                   ClusterSpec(checkpoint_at_minute=0.0, **base))
    assert ck0.restored_mid_run
    plain = run_cluster(azure_table, HybridSpec(), ClusterSpec(**base),
                        engine="scalar")
    assert not plain.restored_mid_run
    np.testing.assert_array_equal(ck0.cold_pct_per_app,
                                  plain.cold_pct_per_app)
    np.testing.assert_array_equal(ck0.latencies_s, plain.latencies_s)


def test_checkpoint_mid_and_past_end(azure_table):
    base = dict(n_workers=6, hbm_budget_bytes=float("inf"))
    mid = _conform(azure_table, FixedSpec(keep_alive=10.0),
                   ClusterSpec(checkpoint_at_minute=100.0, **base))
    assert mid.restored_mid_run
    never = _conform(azure_table, FixedSpec(keep_alive=10.0),
                     ClusterSpec(checkpoint_at_minute=1e9, **base))
    assert not never.restored_mid_run


# --------------------------------------------------------------------------
# Golden small-fleet fixture (both engines vs checked-in oracle run)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["scalar", "vector"])
def test_golden_small_fleet(engine):
    with open(os.path.join(GOLDEN_DIR, "cluster_small.json")) as f:
        want = json.load(f)
    workload, policy, cluster = cluster_small_fleet()
    assert want["n_apps"] == workload.n_apps
    assert want["n_workers"] == cluster.n_workers
    res = run_cluster(workload, policy, cluster, engine=engine)
    err = f"{engine} vs golden cluster_small (see scripts/regen_golden.py)"
    np.testing.assert_array_equal(
        res.cold_pct_per_app, np.asarray(want["cold_pct_per_app"]),
        err_msg=err)
    for q, v in want["latency_pct"].items():
        assert res.latency_pct(float(q)) == v, f"{err}: p{q}"
    np.testing.assert_allclose(res.wasted_gb_minutes,
                               want["wasted_gb_minutes"], rtol=1e-9,
                               err_msg=err)
    for w, ws in enumerate(want["stats_per_worker"]):
        for key in _COUNTERS:
            assert res.stats_per_worker[w][key] == ws[key], \
                f"{err}: worker {w} {key}"


# --------------------------------------------------------------------------
# Worker placement and hashing
# --------------------------------------------------------------------------


@pytest.mark.parametrize("balancing", ["affinity", "hash"])
def test_worker_assignment_matches_oracle(azure_table, balancing):
    cluster = ClusterSpec(n_workers=5, hbm_budget_bytes=float("inf"),
                          balancing=balancing)
    sim = ClusterSim(azure_table.to_registry(),
                     as_spec(FixedSpec(keep_alive=5.0)), cluster.to_config())
    sim.run(azure_table.to_trace())
    expect = azure_table.worker_assignment(5, balancing)
    for i in range(azure_table.n_apps):
        if azure_table.counts[i] > 0:
            assert sim._assign[azure_table.app_id(i)] == expect[i], i


def test_fnv1a64_vectorized_matches_scalar():
    idx = np.array([0, 5, 17, 999999, 1000000, 10 ** 7 + 3])
    got = fnv1a64_app_indices(idx)
    for i, h in zip(idx, got):
        assert int(h) == fnv1a64(f"app-{int(i):06d}"), i
    with pytest.raises(ValueError, match="non-negative"):
        fnv1a64_app_indices(np.array([-1]))


# --------------------------------------------------------------------------
# HBM eviction gate
# --------------------------------------------------------------------------


def _two_app_trace(times, duration=30.0):
    return Trace(specs=None,
                 times=[np.asarray(t, np.float64) for t in times],
                 duration_minutes=duration)


def test_eviction_pressure_refused():
    # Two 10 GB apps resident together on one 16 GB worker: the scalar
    # oracle evicts; the vector engine proves it cannot and refuses.
    table = AppTable.from_trace(_two_app_trace([[0.0], [1.0]]),
                                exec_s=1.0, memory_mb=512.0,
                                weight_bytes=np.array([10e9, 10e9], np.int64))
    cluster = ClusterSpec(n_workers=1, hbm_budget_bytes=16e9)
    with pytest.raises(ValueError, match="engine='scalar'"):
        run_cluster(table, NoUnloadSpec(), cluster, engine="vector")
    sca = run_cluster(table, NoUnloadSpec(), cluster, engine="scalar")
    assert sum(s["evictions"] for s in sca.stats_per_worker) >= 1


def test_eviction_screen_passes_on_interleaved_residency():
    # Assigned bytes exceed the budget in *sum*, but the first app expires
    # (at the second app's tick) before the third loads — the exact
    # occupancy replay proves the run eviction-free and the engines agree.
    table = AppTable.from_trace(
        _two_app_trace([[0.0], [10.0], [20.0]]),
        exec_s=1.0, memory_mb=512.0,
        weight_bytes=np.array([10e9, 1e9, 10e9], np.int64))
    cluster = ClusterSpec(n_workers=1, hbm_budget_bytes=16e9)
    _conform(table, FixedSpec(keep_alive=0.5), cluster)


# --------------------------------------------------------------------------
# AppTable bridges and workload coercion
# --------------------------------------------------------------------------


def test_apptable_uniform_spec_needs_metadata():
    with pytest.raises(ValueError, match="patterns"):
        AppTable.from_spec(WorkloadSpec.uniform(8))
    tab = AppTable.from_spec(WorkloadSpec.uniform(8, seed=2), exec_s=0.5,
                             memory_mb=256.0)
    assert tab.n_apps == 8
    assert np.all(tab.exec_s == 0.5)


def test_apptable_padded_trace_needs_metadata():
    trace = _two_app_trace([[0.0, 5.0], [1.0]])
    with pytest.raises(ValueError, match="padded-only"):
        AppTable.from_trace(trace)
    tab = AppTable.from_trace(trace, exec_s=[0.1, 0.2], memory_mb=128.0)
    np.testing.assert_array_equal(tab.counts, [2, 1])
    back = tab.to_trace()
    assert back.specs is not None
    np.testing.assert_array_equal(back.events(0), [0.0, 5.0])
    reg = tab.to_registry()
    assert reg.get("app-000000").weight_bytes == 128 * 2 ** 20


def test_run_cluster_rejects_unknown_engine(azure_table):
    with pytest.raises(ValueError, match="unknown cluster engine"):
        run_cluster(azure_table, HybridSpec(), engine="warp")


# --------------------------------------------------------------------------
# Experiment-grid plumbing: trace x policy x cluster
# --------------------------------------------------------------------------


def test_sweep_cells_match_single_runs(azure_table):
    specs = [FixedSpec(keep_alive=10.0), NoUnloadSpec()]
    clusters = [ClusterSpec(n_workers=3, hbm_budget_bytes=float("inf")),
                ClusterSpec(n_workers=3, hbm_budget_bytes=float("inf"),
                            balancing="hash")]
    grid = sweep_cluster(azure_table, specs, clusters)
    assert grid.shape == (1, 2, 2)
    for s, spec in enumerate(specs):
        for c, cl in enumerate(clusters):
            single = run_cluster(azure_table, spec, cl)
            _assert_results_equal(grid.row(0, s, c), single,
                                  err=f"cell ({s},{c})")


def test_experiment_run_and_sweep_cluster_axis(azure_table):
    cl = ClusterSpec(n_workers=4, hbm_budget_bytes=float("inf"))
    single = run_cluster(azure_table, FixedSpec(keep_alive=10.0), cl)
    via_run = run(azure_table, FixedSpec(keep_alive=10.0), cluster=cl)
    _assert_results_equal(via_run, single, err="experiment.run(cluster=)")
    grid = sweep(traces=[azure_table], specs=[FixedSpec(keep_alive=10.0)],
                 clusters=[cl])
    assert grid.shape == (1, 1, 1)
    _assert_results_equal(grid.row(0, 0, 0), single,
                          err="experiment.sweep(clusters=)")
