"""Cluster-engine conformance: the vectorized columnar engine vs the
per-event scalar oracle, plus the checked-in small-fleet golden.

The exactness contract (see ``repro.serving.cluster_vector``): cold counts,
per-app cold %, latencies and every load/unload/prewarm/eviction counter
are bit-identical between engines — on oversubscribed fleets too, where
the vectorized engine replays HBM evictions to a fixed point; resident
byte-seconds (and hence wasted GB-minutes) agree to float64
accumulation-order tolerance. The suite pins that contract across policy
families, both balancing modes, hedging, controller checkpoint/restore
(including the ``checkpoint_at_minute=0.0`` regression and a checkpoint
dropped mid-eviction-storm) and the eviction machinery itself: storm
conformance, the pessimistic screen short-circuit, the
``max_eviction_rounds`` scalar fallback and the single-image-over-budget
construction guard.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core.experiment import (FixedSpec, HybridSpec, NoUnloadSpec,
                                   as_spec, run, sweep)
from repro.core.workload import Trace
from repro.core.workload_spec import WorkloadSpec, azure_like, flash_crowd
from repro.runtime.straggler import HedgePolicy
from repro.serving.apptable import AppTable, fnv1a64, fnv1a64_app_indices
from repro.serving.cluster_sim import ClusterSim
from repro.serving.cluster_vector import (ClusterSpec, run_cluster,
                                          sweep_cluster)

from golden_traces import cluster_oversubscribed_fleet, cluster_small_fleet

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")

_COUNTERS = ("cold_starts", "warm_starts", "prewarms", "unloads",
             "evictions", "budget_overflows", "bytes_moved")


def _oversubscribe(table, factor=40.0, budget=30e9):
    """Inflate model images ~``factor``x so per-worker assigned bytes
    oversubscribe ``budget`` several times over (single images stay under
    it, clearing the construction guard)."""
    wb = np.minimum((table.memory_mb * 2 ** 20 * factor).astype(np.int64),
                    np.int64(0.8 * budget))
    return dataclasses.replace(table, weight_bytes=wb)


@pytest.fixture(scope="module")
def azure_table():
    return AppTable.from_spec(
        azure_like(220, days=0.25, seed=11, max_events=24))


@pytest.fixture(scope="module")
def flash_table():
    return AppTable.from_spec(
        flash_crowd(160, days=0.25, seed=3, max_events=48))


def _assert_results_equal(vec, sca, err=""):
    np.testing.assert_array_equal(vec.cold_pct_per_app, sca.cold_pct_per_app,
                                  err_msg=err)
    np.testing.assert_array_equal(vec.latencies_s, sca.latencies_s,
                                  err_msg=err)
    np.testing.assert_allclose(vec.wasted_gb_minutes, sca.wasted_gb_minutes,
                               rtol=1e-9, err_msg=err)
    assert len(vec.stats_per_worker) == len(sca.stats_per_worker), err
    for w, (sv, ss) in enumerate(zip(vec.stats_per_worker,
                                     sca.stats_per_worker)):
        for key in _COUNTERS:
            assert sv[key] == ss[key], f"{err}: worker {w} {key}"
        np.testing.assert_allclose(sv["resident_byte_seconds"],
                                   ss["resident_byte_seconds"], rtol=1e-9,
                                   err_msg=f"{err}: worker {w}")
    assert vec.restored_mid_run == sca.restored_mid_run, err


def _conform(table, policy, cluster):
    vec = run_cluster(table, policy, cluster, engine="vector")
    sca = run_cluster(table, policy, cluster, engine="scalar")
    _assert_results_equal(vec, sca,
                          err=f"{type(policy).__name__}/{cluster.name}")
    return vec


# --------------------------------------------------------------------------
# Engine conformance across policy families and balancing modes
# --------------------------------------------------------------------------


@pytest.mark.parametrize("policy,balancing", [
    (HybridSpec(), "affinity"),
    (HybridSpec(), "hash"),
    (FixedSpec(keep_alive=20.0), "affinity"),
    (NoUnloadSpec(), "hash"),
])
def test_conformance_azure(azure_table, policy, balancing):
    _conform(azure_table, policy,
             ClusterSpec(n_workers=7, hbm_budget_bytes=float("inf"),
                         balancing=balancing))


def test_conformance_flash_crowd(flash_table):
    res = _conform(flash_table, HybridSpec(),
                   ClusterSpec(n_workers=5, hbm_budget_bytes=float("inf")))
    assert res.latencies_s.size == flash_table.n_events


def test_hedging_parity(azure_table):
    # Same rank-indexed uniform streams in both engines -> identical
    # stragglers, hence bit-equal latencies even under hedging.
    hedged = ClusterSpec(n_workers=7, hbm_budget_bytes=float("inf"),
                         hedge=HedgePolicy())
    res = _conform(azure_table, FixedSpec(keep_alive=15.0), hedged)
    plain = run_cluster(azure_table, FixedSpec(keep_alive=15.0),
                        ClusterSpec(n_workers=7,
                                    hbm_budget_bytes=float("inf")),
                        engine="vector")
    assert not np.array_equal(res.latencies_s, plain.latencies_s)


# --------------------------------------------------------------------------
# Controller checkpoint/restore
# --------------------------------------------------------------------------


def test_checkpoint_at_zero_regression(azure_table):
    """checkpoint_at_minute=0.0 means "checkpoint at the first event" — a
    falsy check used to silently drop it. Both engines must restore, and the
    save/restore round-trip must not perturb the trajectory."""
    base = dict(n_workers=6, hbm_budget_bytes=float("inf"))
    ck0 = _conform(azure_table, HybridSpec(),
                   ClusterSpec(checkpoint_at_minute=0.0, **base))
    assert ck0.restored_mid_run
    plain = run_cluster(azure_table, HybridSpec(), ClusterSpec(**base),
                        engine="scalar")
    assert not plain.restored_mid_run
    np.testing.assert_array_equal(ck0.cold_pct_per_app,
                                  plain.cold_pct_per_app)
    np.testing.assert_array_equal(ck0.latencies_s, plain.latencies_s)


def test_checkpoint_mid_and_past_end(azure_table):
    base = dict(n_workers=6, hbm_budget_bytes=float("inf"))
    mid = _conform(azure_table, FixedSpec(keep_alive=10.0),
                   ClusterSpec(checkpoint_at_minute=100.0, **base))
    assert mid.restored_mid_run
    never = _conform(azure_table, FixedSpec(keep_alive=10.0),
                     ClusterSpec(checkpoint_at_minute=1e9, **base))
    assert not never.restored_mid_run


# --------------------------------------------------------------------------
# Golden fleet fixtures (both engines vs checked-in oracle runs)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["scalar", "vector"])
@pytest.mark.parametrize("fixture,fname", [
    (cluster_small_fleet, "cluster_small.json"),
    (cluster_oversubscribed_fleet, "cluster_oversub.json"),
])
def test_golden_fleet(engine, fixture, fname):
    with open(os.path.join(GOLDEN_DIR, fname)) as f:
        want = json.load(f)
    workload, policy, cluster = fixture()
    assert want["n_apps"] == workload.n_apps
    assert want["n_workers"] == cluster.n_workers
    res = run_cluster(workload, policy, cluster, engine=engine)
    err = f"{engine} vs golden {fname} (see scripts/regen_golden.py)"
    np.testing.assert_array_equal(
        res.cold_pct_per_app, np.asarray(want["cold_pct_per_app"]),
        err_msg=err)
    for q, v in want["latency_pct"].items():
        assert res.latency_pct(float(q)) == v, f"{err}: p{q}"
    np.testing.assert_allclose(res.wasted_gb_minutes,
                               want["wasted_gb_minutes"], rtol=1e-9,
                               err_msg=err)
    for w, ws in enumerate(want["stats_per_worker"]):
        for key in _COUNTERS:
            assert res.stats_per_worker[w][key] == ws[key], \
                f"{err}: worker {w} {key}"


# --------------------------------------------------------------------------
# Worker placement and hashing
# --------------------------------------------------------------------------


@pytest.mark.parametrize("balancing", ["affinity", "hash"])
def test_worker_assignment_matches_oracle(azure_table, balancing):
    cluster = ClusterSpec(n_workers=5, hbm_budget_bytes=float("inf"),
                          balancing=balancing)
    sim = ClusterSim(azure_table.to_registry(),
                     as_spec(FixedSpec(keep_alive=5.0)), cluster.to_config())
    sim.run(azure_table.to_trace())
    expect = azure_table.worker_assignment(5, balancing)
    for i in range(azure_table.n_apps):
        if azure_table.counts[i] > 0:
            assert sim._assign[azure_table.app_id(i)] == expect[i], i


def test_fnv1a64_vectorized_matches_scalar():
    idx = np.array([0, 5, 17, 999999, 1000000, 10 ** 7 + 3])
    got = fnv1a64_app_indices(idx)
    for i, h in zip(idx, got):
        assert int(h) == fnv1a64(f"app-{int(i):06d}"), i
    with pytest.raises(ValueError, match="non-negative"):
        fnv1a64_app_indices(np.array([-1]))


# --------------------------------------------------------------------------
# HBM eviction regime (fixed-point replay vs the oracle)
# --------------------------------------------------------------------------


def _two_app_trace(times, duration=30.0):
    return Trace(specs=None,
                 times=[np.asarray(t, np.float64) for t in times],
                 duration_minutes=duration)


def test_eviction_pressure_conformance():
    # Two 10 GB apps resident together on one 16 GB worker: the regime the
    # PR 6 engine refused. Both engines now evict the same victim at the
    # same tick and every counter matches.
    table = AppTable.from_trace(_two_app_trace([[0.0], [1.0]]),
                                exec_s=1.0, memory_mb=512.0,
                                weight_bytes=np.array([10e9, 10e9], np.int64))
    cluster = ClusterSpec(n_workers=1, hbm_budget_bytes=16e9)
    res = _conform(table, NoUnloadSpec(), cluster)
    assert res.evictions >= 1
    assert res.budget_overflows == 0


@pytest.mark.parametrize("policy,balancing", [
    (HybridSpec(), "affinity"),
    (FixedSpec(keep_alive=20.0), "hash"),
    (NoUnloadSpec(), "affinity"),
])
def test_eviction_storm_conformance(flash_table, policy, balancing):
    # Flash-crowd eviction storm: hundreds of soonest-expiry evictions per
    # worker, bit-identical across engines for every policy family.
    res = _conform(_oversubscribe(flash_table), policy,
                   ClusterSpec(n_workers=3, hbm_budget_bytes=30e9,
                               balancing=balancing))
    assert res.evictions > 100


def test_eviction_storm_with_hedging(flash_table):
    res = _conform(_oversubscribe(flash_table), HybridSpec(),
                   ClusterSpec(n_workers=3, hbm_budget_bytes=30e9,
                               hedge=HedgePolicy()))
    assert res.evictions > 100


def test_checkpoint_mid_eviction_storm(flash_table):
    # Controller checkpoint/restore dropped into the middle of an eviction
    # storm: the save/restore round-trip must not perturb the trajectory.
    res = _conform(_oversubscribe(flash_table), HybridSpec(),
                   ClusterSpec(n_workers=3, hbm_budget_bytes=30e9,
                               checkpoint_at_minute=60.0))
    assert res.restored_mid_run
    assert res.evictions > 100


def test_screen_short_circuits_eviction_free_runs(azure_table, monkeypatch):
    # Workers whose assigned bytes fit at once never enter the fixed-point
    # loop: poison the replay and run eviction-free fleets through it.
    from repro.serving import cluster_vector

    def _boom(*args, **kwargs):
        raise AssertionError(
            "fixed-point eviction replay ran on an eviction-free fleet")

    monkeypatch.setattr(cluster_vector, "_evict_worker", _boom)
    # infinite budget: the screen skips Phase D entirely
    _conform(azure_table, FixedSpec(keep_alive=10.0),
             ClusterSpec(n_workers=5, hbm_budget_bytes=float("inf")))
    # finite but sufficient: every worker passes the assigned-bytes sum test
    run_cluster(azure_table, FixedSpec(keep_alive=10.0),
                ClusterSpec(n_workers=5,
                            hbm_budget_bytes=float(
                                azure_table.weight_bytes.sum())),
                engine="vector")


def test_max_eviction_rounds_falls_back_to_scalar(flash_table):
    table = _oversubscribe(flash_table)
    cluster = ClusterSpec(n_workers=3, hbm_budget_bytes=30e9)
    with pytest.warns(RuntimeWarning, match="engine='scalar'"):
        res = run_cluster(table, FixedSpec(keep_alive=20.0), cluster,
                          engine="vector", max_eviction_rounds=0)
    sca = run_cluster(table, FixedSpec(keep_alive=20.0), cluster,
                      engine="scalar")
    _assert_results_equal(res, sca, err="max_eviction_rounds fallback")
    assert res.evictions >= 1


def test_single_image_over_budget_raises_in_both_engines():
    table = AppTable.from_trace(_two_app_trace([[0.0], [1.0]]),
                                exec_s=1.0, memory_mb=512.0,
                                weight_bytes=np.array([20e9, 1e9], np.int64))
    cluster = ClusterSpec(n_workers=1, hbm_budget_bytes=16e9)
    for engine in ("vector", "scalar"):
        with pytest.raises(ValueError, match="larger than the budget"):
            run_cluster(table, NoUnloadSpec(), cluster, engine=engine)


def test_eviction_screen_passes_on_interleaved_residency():
    # Assigned bytes exceed the budget in *sum*, but the first app expires
    # (at the second app's tick) before the third loads — the exact
    # occupancy replay proves the run eviction-free and the engines agree.
    table = AppTable.from_trace(
        _two_app_trace([[0.0], [10.0], [20.0]]),
        exec_s=1.0, memory_mb=512.0,
        weight_bytes=np.array([10e9, 1e9, 10e9], np.int64))
    cluster = ClusterSpec(n_workers=1, hbm_budget_bytes=16e9)
    _conform(table, FixedSpec(keep_alive=0.5), cluster)


# --------------------------------------------------------------------------
# AppTable bridges and workload coercion
# --------------------------------------------------------------------------


def test_apptable_uniform_spec_needs_metadata():
    with pytest.raises(ValueError, match="patterns"):
        AppTable.from_spec(WorkloadSpec.uniform(8))
    tab = AppTable.from_spec(WorkloadSpec.uniform(8, seed=2), exec_s=0.5,
                             memory_mb=256.0)
    assert tab.n_apps == 8
    assert np.all(tab.exec_s == 0.5)


def test_apptable_padded_trace_needs_metadata():
    trace = _two_app_trace([[0.0, 5.0], [1.0]])
    with pytest.raises(ValueError, match="padded-only"):
        AppTable.from_trace(trace)
    tab = AppTable.from_trace(trace, exec_s=[0.1, 0.2], memory_mb=128.0)
    np.testing.assert_array_equal(tab.counts, [2, 1])
    back = tab.to_trace()
    assert back.specs is not None
    np.testing.assert_array_equal(back.events(0), [0.0, 5.0])
    reg = tab.to_registry()
    assert reg.get("app-000000").weight_bytes == 128 * 2 ** 20


def test_run_cluster_rejects_unknown_engine(azure_table):
    with pytest.raises(ValueError, match="unknown cluster engine"):
        run_cluster(azure_table, HybridSpec(), engine="warp")


# --------------------------------------------------------------------------
# Experiment-grid plumbing: trace x policy x cluster
# --------------------------------------------------------------------------


def test_sweep_cells_match_single_runs(azure_table):
    specs = [FixedSpec(keep_alive=10.0), NoUnloadSpec()]
    clusters = [ClusterSpec(n_workers=3, hbm_budget_bytes=float("inf")),
                ClusterSpec(n_workers=3, hbm_budget_bytes=float("inf"),
                            balancing="hash")]
    grid = sweep_cluster(azure_table, specs, clusters)
    assert grid.shape == (1, 2, 2)
    for s, spec in enumerate(specs):
        for c, cl in enumerate(clusters):
            single = run_cluster(azure_table, spec, cl)
            _assert_results_equal(grid.row(0, s, c), single,
                                  err=f"cell ({s},{c})")


def test_experiment_run_and_sweep_cluster_axis(azure_table):
    cl = ClusterSpec(n_workers=4, hbm_budget_bytes=float("inf"))
    single = run_cluster(azure_table, FixedSpec(keep_alive=10.0), cl)
    via_run = run(azure_table, FixedSpec(keep_alive=10.0), cluster=cl)
    _assert_results_equal(via_run, single, err="experiment.run(cluster=)")
    grid = sweep(traces=[azure_table], specs=[FixedSpec(keep_alive=10.0)],
                 clusters=[cl])
    assert grid.shape == (1, 1, 1)
    _assert_results_equal(grid.row(0, 0, 0), single,
                          err="experiment.sweep(clusters=)")
