"""Tests for the AzurePublicDataset-format exporter and the scheduler."""
import csv
import os
import tempfile

import numpy as np
import pytest

from repro.core.dataset_export import export, load_invocations
from repro.core.experiment import FixedSpec, HybridSpec
from repro.core.workload import generate_trace
from repro.serving.registry import ModelEndpoint, Registry
from repro.serving.scheduler import Scheduler, SchedulerConfig
from repro.serving.warmpool import WarmPool
from repro.configs import get, reduced


def test_export_roundtrip_counts():
    trace = generate_trace(30, days=2.0, seed=9)
    with tempfile.TemporaryDirectory() as d:
        paths = export(trace, d)
        inv_files = [p for p in paths if "invocations" in p]
        assert len(inv_files) == 2      # one per day
        total = 0
        for p in inv_files:
            _, counts = load_invocations(p)
            total += counts.sum()
        expected = sum(len(t) for t in trace.times)
        assert total == expected        # every invocation lands in a bin


def test_export_schema():
    trace = generate_trace(10, days=1.0, seed=3)
    with tempfile.TemporaryDirectory() as d:
        paths = export(trace, d)
        dur = [p for p in paths if "durations" in p][0]
        with open(dur) as f:
            header = next(csv.reader(f))
        assert header[:7] == ["HashOwner", "HashApp", "HashFunction",
                              "Average", "Count", "Minimum", "Maximum"]
        assert "percentile_Average_50" in header
        mem = [p for p in paths if "memory" in p][0]
        with open(mem) as f:
            header = next(csv.reader(f))
        assert "AverageAllocatedMb_pct99" in header


def _mk_pool(policy):
    reg = Registry()
    cfg = reduced(get("smollm-135m"))
    for i in range(3):
        reg.register(ModelEndpoint(app_id=f"app-{i:06d}", cfg=cfg, seed=i,
                                   weight_bytes=int(1e8)))
    return WarmPool(reg, policy)


def test_scheduler_batches_bursts():
    pool = _mk_pool(FixedSpec(10.0))
    sched = Scheduler(pool, SchedulerConfig(max_batch=4))
    # 8 simultaneous requests to one endpoint -> 2 batches
    events = [(1.0, "app-000000", 0.1)] * 8
    done = sched.run(sorted(events))
    assert len(done) == 8
    starts = sorted({round(r.start_s, 4) for r in done})
    assert len(starts) == 2            # two batched executions
    # batched execution span (excl. the one-time cold start) beats 8
    # sequential runs
    span = max(r.finish_s for r in done) - min(r.start_s for r in done)
    assert span < 8 * 0.1


def test_scheduler_warm_after_first_batch():
    pool = _mk_pool(FixedSpec(10.0))
    sched = Scheduler(pool, SchedulerConfig(max_batch=2))
    sched.run([(0.0, "app-000001", 0.05)])
    first = sched.completed[0]
    sched.run([(30.0, "app-000001", 0.05)])
    second = sched.completed[1]
    # second request within keep-alive: no cold-start latency
    assert (second.start_s - second.arrival_s) < \
        (first.start_s - first.arrival_s)
    assert pool.stats.warm_starts >= 1


def test_scheduler_latency_accounting():
    pool = _mk_pool(HybridSpec(use_arima=False))
    sched = Scheduler(pool)
    done = sched.run([(0.0, "app-000002", 0.2), (100.0, "app-000002", 0.2)])
    for r in done:
        assert r.finish_s > r.start_s >= r.arrival_s
        assert r.latency >= r.exec_s
