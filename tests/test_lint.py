"""Tests for the invariant linter (``repro.analysis``).

Three layers:

  * per-rule fixtures — every pass gets at least one known-bad snippet it
    must flag and a known-good twin it must not (the good twin is the
    sanctioned spelling of the same operation);
  * framework semantics — suppressions (inline / standalone / reasonless /
    unknown rule), relkey scoping, ``--json`` schema v1 stability,
    ``--changed`` plumbing;
  * dogfooding — the shipped ``src/`` tree is clean (exit 0), which is
    exactly what the CI lint job asserts.

The fixtures lint in-memory sources against *virtual* paths (e.g.
``src/repro/kernels/bad.py``) — scope rules key off the path's
``repro``-relative tail, so nothing touches disk.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (ALL_RULES, LintConfig, parse_suppressions,
                            render_json, rule_by_name, run_paths, run_source)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

CORE = "src/repro/core/x.py"          # determinism scope, non-kernel
KERNEL = "src/repro/kernels/x.py"     # kernel + determinism scope
OUTSIDE = "src/repro/bench/x.py"      # outside determinism scope


def lint(source, path=CORE, rules=None, config=None):
    findings, _ = run_source(source, path, rules or ALL_RULES, config)
    return findings


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# Pass 1: single-source decision math
# ---------------------------------------------------------------------------


class TestDecisionMath:
    RULE = "single-source-decision-math"

    def test_pct_scale_arithmetic_flagged(self):
        bad = "thr = total * policy_math.PCT_SCALE\n"
        assert rules_of(lint(bad)) == [self.RULE]

    def test_pct_scale_through_dtype_cast_flagged(self):
        # the histogram.py bug shape this PR fixed: the cast does not
        # launder the arithmetic
        bad = "thr = t.astype(jnp.int32) * jnp.int32(policy_math.PCT_SCALE)\n"
        assert rules_of(lint(bad)) == [self.RULE]

    def test_pct_scale_opaque_use_ok(self):
        good = ("from repro.core.policy_math import PCT_SCALE\n"
                "check(width, PCT_SCALE)\n"
                "limit = policy_math.MAX_SCALED_COUNT\n")
        assert lint(good) == []

    def test_policy_math_itself_exempt(self):
        src = "thr = total * PCT_SCALE\n"
        assert lint(src, path="src/repro/core/policy_math.py") == []

    def test_inline_margin_flagged_and_helper_ok(self):
        bad = "lo = it * (1.0 - margin)\n"
        good = "lo, hi = policy_math.margin_factors(margin)\n"
        assert rules_of(lint(bad)) == [self.RULE]
        assert lint(good) == []

    def test_inline_warm_verdict_flagged(self):
        bad = "warm = (it >= load_at) & (it <= unload_at)\n"
        assert rules_of(lint(bad)) == [self.RULE]
        reversed_bad = "warm = load_at <= it and it <= unload_at\n"
        assert rules_of(lint(reversed_bad)) == [self.RULE]

    def test_warm_helper_ok(self):
        good = "warm = policy_math.warm_from_bounds(it, load_at, unload_at)\n"
        assert lint(good) == []


# ---------------------------------------------------------------------------
# Pass 2: x64 discipline
# ---------------------------------------------------------------------------


class TestX64:
    RULE = "x64-discipline"

    def test_f64_in_kernel_flagged(self):
        bad = "acc = jnp.zeros(8, jnp.float64)\n"
        assert rules_of(lint(bad, path=KERNEL)) == [self.RULE]

    def test_f64_string_in_kernel_flagged(self):
        bad = "x = y.astype('float64')\n"
        assert self.RULE in rules_of(lint(bad, path=KERNEL))

    def test_enable_x64_in_kernel_flagged(self):
        bad = "jax.config.update('jax_enable_x64', True)\n"
        assert rules_of(lint(bad, path=KERNEL)) == [self.RULE]

    def test_f64_outside_kernels_ok(self):
        good = "oracle = times.astype(np.float64)\n"
        assert lint(good, path=CORE) == []

    def test_unrebased_time_cast_flagged_everywhere(self):
        bad = "def f(times):\n    return times.astype(np.float32)\n"
        assert rules_of(lint(bad, path=CORE)) == [self.RULE]
        bad2 = "def f(t_abs):\n    return np.asarray(t_abs, np.float32)\n"
        assert rules_of(lint(bad2, path=CORE)) == [self.RULE]

    def test_rebasing_function_exempt(self):
        good = ("def f(times):\n"
                "    t = _rebase_chunk(times)\n"
                "    return t.astype(np.float32)\n")
        assert lint(good, path=CORE) == []

    def test_non_time_cast_ok(self):
        good = "def f(counts):\n    return counts.astype(np.float32)\n"
        assert lint(good, path=CORE) == []


# ---------------------------------------------------------------------------
# Pass 3: tracer leaks
# ---------------------------------------------------------------------------


class TestTracerLeak:
    RULE = "tracer-leak"

    def test_if_on_traced_param_flagged(self):
        bad = ("@jax.jit\n"
               "def f(x):\n"
               "    if x > 0:\n"
               "        return x\n"
               "    return -x\n")
        assert rules_of(lint(bad)) == [self.RULE]

    def test_if_on_static_argnum_ok(self):
        # the repo's _fixed_scan shape: static_argnums resolves positions
        # to names, so branching on the static is standard jit practice
        good = ("@partial(jax.jit, static_argnums=(1,))\n"
                "def f(x, include_trailing):\n"
                "    if include_trailing:\n"
                "        return x + 1\n"
                "    return x\n")
        assert lint(good) == []

    def test_shape_probe_ok(self):
        good = ("@jax.jit\n"
                "def f(x):\n"
                "    if x.ndim == 0:\n"
                "        x = x[None]\n"
                "    return x\n")
        assert lint(good) == []

    def test_scan_body_host_sync_flagged(self):
        bad = ("def body(carry, t):\n"
               "    v = float(t)\n"
               "    return carry + v, np.asarray(carry)\n"
               "out = jax.lax.scan(body, 0.0, ts)\n")
        got = rules_of(lint(bad))
        assert got.count(self.RULE) == 2

    def test_item_in_scan_body_flagged(self):
        bad = ("def body(carry, t):\n"
               "    return carry, t.item()\n"
               "out = jax.lax.scan(body, 0, ts)\n")
        assert rules_of(lint(bad)) == [self.RULE]

    def test_clean_scan_body_ok(self):
        good = ("def body(carry, t):\n"
                "    return carry + t, jnp.where(t > 0, t, 0)\n"
                "out = jax.lax.scan(body, 0.0, ts)\n")
        assert lint(good) == []

    def test_host_code_outside_traced_context_ok(self):
        # while/float on host values is fine — only traced contexts count
        good = ("def host(xs):\n"
                "    while len(xs) > 0:\n"
                "        xs = xs[1:]\n"
                "    return float(np.asarray(xs).sum())\n")
        assert lint(good) == []


# ---------------------------------------------------------------------------
# Pass 4: nondeterminism
# ---------------------------------------------------------------------------


class TestNondeterminism:
    RULE = "nondeterminism"

    def test_global_np_random_flagged(self):
        bad = "noise = np.random.rand(8)\n"
        assert rules_of(lint(bad)) == [self.RULE]

    def test_seeded_generator_ok(self):
        good = ("rng = np.random.default_rng(seed)\n"
                "noise = rng.random(8)\n")
        assert lint(good) == []

    def test_stdlib_random_flagged(self):
        bad = "import random\nx = random.random()\n"
        assert rules_of(lint(bad)) == [self.RULE]

    def test_wall_clock_flagged(self):
        bad = "t = time.time()\n"
        assert rules_of(lint(bad)) == [self.RULE]

    def test_out_of_scope_ok(self):
        assert lint("t = time.time()\n", path=OUTSIDE) == []
        assert lint("x = np.random.rand(3)\n", path=OUTSIDE) == []


# ---------------------------------------------------------------------------
# Pass 5: pytree completeness
# ---------------------------------------------------------------------------

_DATACLASS = ("@dataclasses.dataclass(frozen=True)\n"
              "class FooSpec:\n"
              "    keep_alive: float\n"
              "    label: str\n")


class TestPytree:
    RULE = "pytree-completeness"

    def test_meta_typo_flagged(self):
        bad = _DATACLASS + "_register_pytree(FooSpec, meta=('labell',))\n"
        assert rules_of(lint(bad)) == [self.RULE]

    def test_meta_ok(self):
        good = _DATACLASS + "_register_pytree(FooSpec, meta=('label',))\n"
        assert lint(good) == []

    def test_raw_flatten_dropping_field_flagged(self):
        bad = (_DATACLASS +
               "def _flat(s):\n"
               "    return (s.keep_alive,), None\n"
               "def _unflat(aux, kids):\n"
               "    return FooSpec(kids[0], 'x')\n"
               "jax.tree_util.register_pytree_node(FooSpec, _flat, _unflat)\n")
        got = rules_of(lint(bad))
        assert self.RULE in got
        assert "drops field(s) ['label']" in \
            next(f for f in lint(bad) if f.rule == self.RULE).message

    def test_raw_flatten_complete_ok(self):
        good = (_DATACLASS +
                "def _flat(s):\n"
                "    return (s.keep_alive,), s.label\n"
                "def _unflat(aux, kids):\n"
                "    return FooSpec(kids[0], aux)\n"
                "jax.tree_util.register_pytree_node(FooSpec, _flat, "
                "_unflat)\n")
        assert lint(good) == []

    def test_dataclasses_fields_counts_as_full_coverage(self):
        good = (_DATACLASS +
                "def _flat(s):\n"
                "    vals = [getattr(s, f.name) "
                "for f in dataclasses.fields(s)]\n"
                "    return tuple(vals), None\n"
                "jax.tree_util.register_pytree_node(FooSpec, _flat, None)\n")
        # the lambda/None unflatten is irrelevant; flatten is what's audited
        bad_free = [f for f in lint(good) if f.rule == self.RULE]
        assert bad_free == []


# ---------------------------------------------------------------------------
# Pass 6: deprecation hygiene
# ---------------------------------------------------------------------------


class TestDeprecations:
    RULE = "deprecation-hygiene"

    def test_removed_call_flagged_with_replacement(self):
        bad = "res = simulator.simulate_hybrid_batch(trace, 60)\n"
        found = lint(bad)
        assert rules_of(found) == [self.RULE]
        assert "experiment.run" in found[0].message

    def test_removed_import_flagged(self):
        bad = "from repro.core.simulator import simulate\n"
        assert rules_of(lint(bad)) == [self.RULE]

    def test_synthesize_attr_flagged(self):
        bad = "trace = Trace.synthesize(n_apps=8)\n"
        assert rules_of(lint(bad)) == [self.RULE]

    def test_local_definition_exempt(self):
        good = ("def simulate(trace):\n"
                "    return trace\n"
                "simulate(t)\n")
        assert lint(good) == []

    def test_new_api_ok(self):
        good = "res = experiment.run(trace, FixedSpec(keep_alive=60.0))\n"
        assert lint(good) == []


# ---------------------------------------------------------------------------
# Pass 7: conformance coverage
# ---------------------------------------------------------------------------


class TestConformanceCoverage:
    RULE = "conformance-coverage"
    ENTRY = ("def helper():\n"
             "    pass\n"
             "def launch(trace, spec):\n"
             "    return helper()\n")

    def config(self, tmp_path, names=("launch",)):
        return LintConfig(
            conformance_entry_points=(
                ("repro/core/engine.py", tuple(names)),),
            conformance_test_dir=str(tmp_path))

    def test_uncovered_entry_point_flagged(self, tmp_path):
        (tmp_path / "test_other_conformance.py").write_text(
            "def test_something():\n    helper()\n")
        found = lint(self.ENTRY, path="src/repro/core/engine.py",
                     rules=[rule_by_name(self.RULE)],
                     config=self.config(tmp_path))
        assert rules_of(found) == [self.RULE]
        assert "launch()" in found[0].message
        # anchored at the def, not the module head
        assert found[0].line == 3

    def test_covered_entry_point_ok(self, tmp_path):
        (tmp_path / "test_engine_conformance.py").write_text(
            "def test_launch_matches_oracle():\n"
            "    launch(trace, spec)\n")
        assert lint(self.ENTRY, path="src/repro/core/engine.py",
                    rules=[rule_by_name(self.RULE)],
                    config=self.config(tmp_path)) == []

    def test_mention_outside_conformance_glob_does_not_count(self, tmp_path):
        (tmp_path / "test_engine.py").write_text("launch(trace, spec)\n")
        found = lint(self.ENTRY, path="src/repro/core/engine.py",
                     rules=[rule_by_name(self.RULE)],
                     config=self.config(tmp_path))
        assert rules_of(found) == [self.RULE]

    def test_bare_name_without_call_does_not_count(self, tmp_path):
        (tmp_path / "test_x_conformance.py").write_text(
            "from repro.core.engine import launch\n")
        found = lint(self.ENTRY, path="src/repro/core/engine.py",
                     rules=[rule_by_name(self.RULE)],
                     config=self.config(tmp_path))
        assert rules_of(found) == [self.RULE]

    def test_missing_test_dir_is_its_own_finding(self, tmp_path):
        cfg = LintConfig(
            conformance_entry_points=(
                ("repro/core/engine.py", ("launch",)),),
            conformance_test_dir=str(tmp_path / "nope"))
        found = lint(self.ENTRY, path="src/repro/core/engine.py",
                     rules=[rule_by_name(self.RULE)], config=cfg)
        assert rules_of(found) == [self.RULE]
        assert "cannot verify" in found[0].message

    def test_other_modules_out_of_scope(self, tmp_path):
        assert lint("def launch():\n    pass\n",
                    path="src/repro/core/other.py",
                    rules=[rule_by_name(self.RULE)],
                    config=self.config(tmp_path)) == []

    def test_default_entry_points_resolve_in_repo(self):
        """The shipped defaults point at real files whose conformance
        coverage the dogfood test enforces — catch table rot here."""
        cfg = LintConfig()
        for relkey, names in cfg.conformance_entry_points:
            fp = os.path.join(SRC, *relkey.split("/"))
            assert os.path.isfile(fp), relkey
            src = open(fp, encoding="utf-8").read()
            for name in names:
                assert f"def {name}(" in src, (relkey, name)


# ---------------------------------------------------------------------------
# Framework semantics
# ---------------------------------------------------------------------------


class TestSuppressions:
    def test_inline_suppression_with_reason(self):
        src = ("t = time.time()  "
               "# repro-lint: ignore[nondeterminism] -- wall clock is the "
               "measurement\n")
        findings, suppressed = run_source(src, CORE, ALL_RULES)
        assert findings == []
        assert suppressed == 1

    def test_standalone_suppression_covers_next_code_line(self):
        src = ("# repro-lint: ignore[nondeterminism] -- measurement, with a\n"
               "# continuation line of reasoning\n"
               "t = time.time()\n")
        findings, suppressed = run_source(src, CORE, ALL_RULES)
        assert findings == []
        assert suppressed == 1

    def test_reasonless_suppression_does_not_suppress(self):
        src = "t = time.time()  # repro-lint: ignore[nondeterminism]\n"
        findings, suppressed = run_source(src, CORE, ALL_RULES)
        assert suppressed == 0
        assert sorted(rules_of(findings)) == ["lint-directive",
                                              "nondeterminism"]

    def test_unknown_rule_in_directive_reported(self):
        src = "x = 1  # repro-lint: ignore[not-a-rule] -- because\n"
        findings, _ = run_source(src, CORE, ALL_RULES)
        assert rules_of(findings) == ["lint-directive"]

    def test_wrong_rule_does_not_suppress(self):
        src = ("t = time.time()  "
               "# repro-lint: ignore[tracer-leak] -- wrong rule\n")
        findings, suppressed = run_source(src, CORE, ALL_RULES)
        assert suppressed == 0
        assert "nondeterminism" in rules_of(findings)

    def test_directive_in_docstring_is_not_a_directive(self):
        src = ('"""Docs: write # repro-lint: ignore[rule] -- reason."""\n'
               "x = 1\n")
        assert parse_suppressions(src) == []
        findings, _ = run_source(src, CORE, ALL_RULES)
        assert findings == []


class TestFramework:
    def test_syntax_error_becomes_parse_finding(self):
        findings, _ = run_source("def f(:\n", CORE, ALL_RULES)
        assert rules_of(findings) == ["parse-error"]

    def test_rule_registry(self):
        assert len(ALL_RULES) == 7
        assert {r.name for r in ALL_RULES} == {
            "single-source-decision-math", "x64-discipline", "tracer-leak",
            "nondeterminism", "pytree-completeness", "deprecation-hygiene",
            "conformance-coverage"}
        with pytest.raises(KeyError):
            rule_by_name("nope")

    def test_relkey_scoping_is_root_invariant(self):
        bad = "x = jnp.float64(0)\n"
        for root in ("src/repro/kernels/k.py", "repro/kernels/k.py",
                     "/abs/path/src/repro/kernels/k.py"):
            assert rules_of(lint(bad, path=root)) == ["x64-discipline"]

    def test_config_overrides(self):
        cfg = LintConfig(determinism_scopes=())
        assert lint("t = time.time()\n", config=cfg) == []

    def test_json_schema_v1(self):
        report = run_paths(
            [os.path.join(SRC, "repro", "core", "policy_math.py")],
            ALL_RULES)
        payload = json.loads(render_json(report))
        assert payload["version"] == 1
        assert set(payload.keys()) == {"version", "counts", "findings"}
        assert set(payload["counts"]) == {"files", "findings", "suppressed"}
        for f in payload["findings"]:
            assert set(f) == {"file", "line", "col", "rule", "message"}

    def test_findings_sorted_and_stable(self):
        src = "t = time.time()\nu = time.time()\n"
        findings, _ = run_source(src, CORE, ALL_RULES)
        assert [f.line for f in findings] == sorted(f.line for f in findings)


# ---------------------------------------------------------------------------
# Dogfood: the shipped tree is clean, via the same entry CI uses
# ---------------------------------------------------------------------------


class TestDogfood:
    def test_src_tree_is_clean(self):
        report = run_paths([SRC], ALL_RULES)
        msgs = "\n".join(f.render() for f in report["findings"])
        assert report["counts"]["findings"] == 0, f"lint findings:\n{msgs}"
        assert report["counts"]["files"] >= 40

    def test_cli_exit_codes(self):
        env = dict(os.environ, PYTHONPATH=SRC)
        clean = subprocess.run(
            [sys.executable, "-m", "repro.analysis", SRC],
            capture_output=True, text=True, env=env, cwd=REPO)
        assert clean.returncode == 0, clean.stdout + clean.stderr
        usage = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--select", "nope", SRC],
            capture_output=True, text=True, env=env, cwd=REPO)
        assert usage.returncode == 2

    def test_cli_findings_exit_one(self, tmp_path):
        bad = tmp_path / "repro" / "core" / "bad.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("t = time.time()\n")
        env = dict(os.environ, PYTHONPATH=SRC)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", str(tmp_path)],
            capture_output=True, text=True, env=env, cwd=REPO)
        assert proc.returncode == 1
        assert "nondeterminism" in proc.stdout

    def test_changed_mode(self, tmp_path):
        git = ["git", "-C", str(tmp_path)]
        try:
            subprocess.run(git + ["init", "-q"], check=True,
                           capture_output=True)
        except (OSError, subprocess.CalledProcessError):
            pytest.skip("git unavailable")
        subprocess.run(git + ["config", "user.email", "t@t"], check=True)
        subprocess.run(git + ["config", "user.name", "t"], check=True)
        pkg = tmp_path / "repro" / "core"
        pkg.mkdir(parents=True)
        (pkg / "clean.py").write_text("x = 1\n")
        subprocess.run(git + ["add", "-A"], check=True)
        subprocess.run(git + ["commit", "-qm", "seed"], check=True,
                       capture_output=True)
        (pkg / "bad.py").write_text("t = time.time()\n")  # untracked
        env = dict(os.environ, PYTHONPATH=SRC)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--changed", "repro"],
            capture_output=True, text=True, env=env, cwd=str(tmp_path))
        assert proc.returncode == 1, proc.stdout + proc.stderr
        assert "bad.py" in proc.stdout
        assert "clean.py" not in proc.stdout
