"""Per-architecture smoke tests (reduced same-family configs, one forward /
train step on CPU, shape + finiteness assertions) plus decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get, reduced
from repro.models import build

KEY = jax.random.PRNGKey(0)


def _train_shape(cfg, seq=32, batch=2):
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=seq,
                                global_batch=batch)
    if cfg.frontend == "vision":
        shape = dataclasses.replace(shape, seq_len=seq + cfg.frontend_tokens)
    return shape


@pytest.mark.parametrize("arch_id", list(ARCHS))
def test_smoke_forward_and_loss(arch_id):
    cfg = reduced(get(arch_id))
    model = build(cfg)
    params = model.init(KEY)
    shape = _train_shape(cfg)
    batch = model.make_inputs(shape)
    loss = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # loss should be near ln(vocab) at init
    assert 0.5 * np.log(cfg.vocab) < float(loss) < 2.5 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch_id", list(ARCHS))
def test_smoke_prefill_decode(arch_id):
    cfg = reduced(get(arch_id))
    model = build(cfg)
    params = model.init(KEY)
    shape = dataclasses.replace(_train_shape(cfg), kind="prefill")
    pin = model.make_inputs(shape)
    logits, cache = model.prefill(params, pin.get("tokens"), max_len=64,
                                  embeds=pin.get("embeds"))
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.zeros((2,), jnp.int32)
    for _ in range(3):
        lg, cache = model.decode_step(params, tok, cache)
        assert lg.shape == (2, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(lg)))
        tok = jnp.argmax(lg, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch_id", ["smollm-135m", "mamba2-2.7b",
                                     "recurrentgemma-2b"])
def test_decode_matches_forward(arch_id):
    """Greedy decode logits == full-forward logits at the same positions."""
    cfg = reduced(get(arch_id)).with_(scan_layers=True, remat=False)
    model = build(cfg)
    params = model.init(KEY)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full = model.forward(params, toks)

    prompt = toks[:, :16]
    logits, cache = model.prefill(params, prompt, max_len=S + 8)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, 15]), rtol=2e-2, atol=2e-2)
    # feed the true continuation; decode logits must match teacher forcing
    for t in range(16, 20):
        lg, cache = model.decode_step(params, toks[:, t], cache)
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   rtol=2e-2, atol=2e-2)


def test_train_step_reduces_loss():
    from repro.training import optimizer as opt
    from repro.launch.steps import make_train_step
    from repro.training import data as data_lib

    cfg = reduced(get("smollm-135m"))
    model = build(cfg)
    params = model.init(KEY)
    state = opt.init_state(params)
    shape = _train_shape(cfg, seq=64, batch=4)
    step_fn = jax.jit(make_train_step(model, opt.OptConfig(lr=5e-3,
                                                           warmup_steps=5)))
    losses = []
    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in
                 data_lib.batch_at(step, cfg, shape).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_moe_gather_matches_einsum():
    """The optimized gather dispatch == GShard einsum dispatch."""
    from repro.models import moe
    cfg = reduced(get("olmoe-1b-7b"))
    model = build(cfg)
    params = model.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0, cfg.vocab)
    out_e, aux_e = moe.forward(cfg, params, toks, impl="einsum")
    out_g, aux_g = moe.forward(cfg, params, toks, impl="gather")
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_g),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(float(aux_e), float(aux_g), rtol=1e-5)


def test_mamba_state_continuity():
    """Prefill final state == state after stepwise decode over same tokens."""
    cfg = reduced(get("mamba2-2.7b")).with_(remat=False)
    model = build(cfg)
    params = model.init(KEY)
    B, S = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab)
    _, cache_pre = model.prefill(params, toks, max_len=S)

    # stepwise: drive decode_step token by token from empty state
    from repro.models import mamba2
    import jax.numpy as jnp
    state = mamba2.init_state(cfg, B, jnp.float32)
    cache = {"ssm": state["ssm"], "conv": state["conv"],
             "pos": jnp.zeros((), jnp.int32)}
    for t in range(S):
        _, cache = model.decode_step(params, toks[:, t], cache)
    np.testing.assert_allclose(np.asarray(cache["ssm"]),
                               np.asarray(cache_pre["ssm"]), rtol=2e-2,
                               atol=2e-2)
