"""Deterministic trace constructors shared by the conformance suite, the
golden-trace regression tests, and ``scripts/regen_golden.py``.

Each constructor documents which float32-exactness regime it exercises:
the batched float32 engines (Pallas / reference) rebase every app by its
first event, so they reproduce the float64 oracle bit-for-bit whenever the
*rebased* times are float32-representable — which each constructor
guarantees by keeping times on a dyadic grid with a bounded significand.
"""
import numpy as np

from repro.core.histogram import HistogramConfig
from repro.core.policy import HybridConfig
from repro.core.workload import Trace
from repro.core.workload_spec import WorkloadSpec

MINUTES_14D = 14 * 1440.0

# 48 bins keeps the Pallas interpret path fast while exercising every gate.
CFG48 = HybridConfig(histogram=HistogramConfig(range_minutes=48.0),
                     use_arima=False)
CFG240 = HybridConfig(use_arima=False)

# Sub-millisecond inter-arrival grid: 2**-16 minutes ~ 0.9 ms.
SUBMS = 2.0 ** -16


def _trace(times, duration):
    return Trace(specs=None, times=[np.asarray(t, np.float64) for t in times],
                 duration_minutes=float(duration))


def bursty_subms_multiweek(n_apps: int = 24, seed: int = 5) -> Trace:
    """Two-week trace of apps each active inside its own <=4h neighborhood.

    Absolute timestamps sit deep into the trace (t ~ 2e4 minutes) while the
    inter-arrival structure goes down to sub-millisecond — absolute times
    need ~31 significant bits, far beyond float32, so an un-rebased float32
    engine scrambles the IATs. After per-app rebasing every time is a
    2**-16-minute multiple below 2**8 minutes (24 significant bits): exactly
    float32-representable, hence exact cold-count parity. Pair with CFG48.

    App mix per residue class: dense sub-ms bursts with multi-minute
    inter-burst gaps / OOB-heavy (> 48 min IATs) / sub-``min_samples``
    (1–4 events) / keep-alive-boundary riders (IATs exactly on the standard
    keep-alive and on bin edges +- one sub-ms grid step).
    """
    rng = np.random.default_rng(seed)
    times = []
    for i in range(n_apps):
        # coarse 1/8-minute start anywhere in the first 13 days
        t0 = rng.integers(0, int((MINUTES_14D - 400.0) * 8)) / 8.0
        kind = i % 4
        if kind == 0:
            # bursts of ~8 sub-ms-spaced events, gaps of 1..40 min between
            iats = []
            for _ in range(4):
                iats.extend(rng.integers(1, 64, 7) * SUBMS)   # 15us..1ms-ish
                iats.append(float(rng.integers(64, 2560)) / 64.0)
            iats = np.asarray(iats[:-1])
        elif kind == 1:
            # mostly OOB for the 48-minute histogram range
            iats = rng.integers(49 * 64, 60 * 64, 4) / 64.0
        elif kind == 2:
            n_ev = int(rng.integers(1, 5))
            iats = rng.integers(1, 40 * 64, max(n_ev - 1, 0)) / 64.0
        else:
            # exact boundary riders: standard keep-alive (48.0) and bin
            # edges hit dead-on and missed by one sub-ms grid step
            iats = np.asarray([48.0, 48.0 + SUBMS, 1.0, 1.0 - SUBMS,
                               1.0 + SUBMS, 2.0, 48.0 - SUBMS, 3.0, 3.0,
                               3.0, 3.0])
        offsets = np.concatenate([[0.0], np.cumsum(iats)])
        assert offsets[-1] < 256.0, "span must stay float32-exact on the grid"
        times.append(t0 + offsets)
    return _trace(times, MINUTES_14D)


def coarse_twoweek(n_apps: int = 32, seed: int = 9) -> Trace:
    """Two-week full-span trace on the 1/64-minute grid (21 significant
    bits: float32-exact even before rebasing). Mixes concentrated bimodal
    apps (histogram windows activate), near-uniform apps (low CV -> standard
    keep-alive), OOB-heavy apps, and Poisson-ish apps. Pair with CFG48."""
    rng = np.random.default_rng(seed)
    times = []
    for i in range(n_apps):
        kind = i % 4
        n_ev = int(rng.integers(16, 48))
        if kind == 0:      # bimodal: concentrated -> high CV -> windows
            iats = np.where(rng.uniform(size=n_ev - 1) < 0.5, 10.0, 30.0)
            iats = iats + rng.integers(-8, 8, n_ev - 1) / 64.0
        elif kind == 1:    # spread quasi-uniform -> low CV -> standard
            iats = rng.integers(1 * 64, 47 * 64, n_ev - 1) / 64.0
        elif kind == 2:    # OOB-heavy
            iats = rng.integers(49 * 64, 300 * 64, n_ev - 1) / 64.0
        else:              # short-gap machine traffic
            iats = rng.integers(8, 12 * 64, n_ev - 1) / 64.0
        t = np.concatenate([[rng.integers(0, 64 * 64) / 64.0],
                            np.cumsum(iats)])
        t = t[t < MINUTES_14D - 1.0]
        times.append(np.sort(t))
    return _trace(times, MINUTES_14D)


def synthesized_small(n_apps: int = 64, seed: int = 7) -> Trace:
    """Padded-only ``WorkloadSpec.uniform`` trace (native float32
    timestamps — trivially exact in every engine; ``min_events=1`` keeps the
    legacy every-app-invoked guarantee). Pair with CFG240."""
    return WorkloadSpec.uniform(n_apps, days=3.0, seed=seed, max_events=16,
                                min_events=1).materialize()


GOLDEN_TRACES = {
    # name -> (constructor, config)
    "bursty_subms_multiweek": (bursty_subms_multiweek, CFG48),
    "coarse_twoweek": (coarse_twoweek, CFG48),
    "synthesized_small": (synthesized_small, CFG240),
}


def cluster_small_fleet():
    """The cluster golden: a small azure-like fleet on 6 workers.

    Pins the scalar per-event oracle's per-app cold %, wasted GB-minutes
    and latency percentiles (``tests/golden/cluster_small.json``); the
    conformance suite replays BOTH cluster engines against it. ARIMA stays
    on so the golden covers the forecaster path; the budget is infinite
    because the vectorized engine models the no-eviction regime.
    """
    from repro.core.experiment import HybridSpec
    from repro.core.workload_spec import azure_like
    from repro.serving.cluster_vector import ClusterSpec

    workload = azure_like(120, days=0.25, seed=17, max_events=24)
    policy = HybridSpec()
    cluster = ClusterSpec(n_workers=6, hbm_budget_bytes=float("inf"))
    return workload, policy, cluster


def cluster_oversubscribed_fleet():
    """The eviction-regime cluster golden: a flash-crowd fleet whose model
    images are inflated ~40x, so three 30 GB workers stay oversubscribed
    and the soonest-keep-alive-expiry eviction path runs constantly.

    Pins the scalar per-event oracle's trajectory INCLUDING per-worker
    eviction counters (``tests/golden/cluster_oversub.json``); the
    conformance suite replays BOTH cluster engines against it, so the
    vectorized fixed-point eviction replay is anchored to a checked-in
    oracle run, not just to a live oracle of the same code age.
    """
    import dataclasses

    from repro.core.experiment import HybridSpec
    from repro.core.workload_spec import flash_crowd
    from repro.serving.apptable import AppTable
    from repro.serving.cluster_vector import ClusterSpec

    table = AppTable.from_spec(
        flash_crowd(96, days=0.25, seed=23, max_events=32))
    # ~40x the Azure-like allocated-memory column: single images stay under
    # the 30 GB per-worker budget (construction guard) but each worker's
    # assigned set oversubscribes it several times over.
    wb = np.minimum((table.memory_mb * 2 ** 20 * 40).astype(np.int64),
                    np.int64(24e9))
    table = dataclasses.replace(table, weight_bytes=wb)
    policy = HybridSpec()
    cluster = ClusterSpec(n_workers=3, hbm_budget_bytes=30e9)
    return table, policy, cluster
