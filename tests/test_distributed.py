"""Sharding-rule, optimizer, checkpoint, and fault-tolerance tests.

These run on a small host mesh (real CPU devices); the 256/512-chip meshes
are exercised by the dry-run (launch/dryrun.py), not pytest.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get, reduced
from repro.distributed import sharding as shd
from repro.models import build
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt

KEY = jax.random.PRNGKey(0)


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An abstract mesh over fake devices — fine for spec derivation."""
    devs = np.empty(shape, object)
    it = np.nditer(devs, flags=["multi_index", "refs_ok"])
    class FakeDev:  # minimal stand-in
        def __init__(self, i): self.id = i
    i = 0
    for _ in it:
        devs[it.multi_index] = FakeDev(i)
        i += 1
    return Mesh(devs, axes)


def test_param_specs_dense():
    mesh = fake_mesh()
    cfg = get("qwen2-7b")
    model = build(cfg)
    sds = jax.eval_shape(model.init, KEY)
    specs = shd.param_specs(sds, mesh, cfg)
    # attention q: stacked layers, TP on the head dim
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, None, "model")
    # kv heads (4) don't divide model=16 -> replicated (Megatron KV dup)
    assert specs["layers"]["attn"]["wk"]["w"] == P()
    assert specs["layers"]["mlp"]["wi"]["w"] == P(None, None, "model")
    assert specs["layers"]["mlp"]["wo"]["w"] == P(None, "model", None)
    assert specs["embed"]["table"] == P("model", None)
    assert specs["layers"]["ln1"]["scale"] == P()


def test_param_specs_moe_experts():
    mesh = fake_mesh()
    cfg = get("qwen3-moe-30b-a3b")
    model = build(cfg)
    sds = jax.eval_shape(model.init, KEY)
    specs = shd.param_specs(sds, mesh, cfg)
    # experts [L, E, D, F] sharded over model (EP)
    assert specs["layers"]["moe"]["wi"] == P(None, "model", None, None)
    assert specs["layers"]["moe"]["router"]["w"] == P(None, None, None)


def test_zero_spec_adds_data_axis():
    mesh = fake_mesh()
    spec = shd.zero_spec(P(None, None, "model"), (80, 8192, 1848), mesh)
    assert spec == P("data", None, "model")
    # non-divisible first dims skip to the next
    spec = shd.zero_spec(P(None, None), (5, 4096), mesh)
    assert spec == P(None, "data")


def test_cache_specs_prefer_heads_then_hd():
    mesh = fake_mesh()
    cfg = get("olmoe-1b-7b")      # kv=16 -> heads shardable
    model = build(cfg)
    cs = model.cache_specs(128, 1024)
    specs = shd.cache_specs_tree(cfg, cs, mesh)
    assert specs["k"] == P(None, "data", None, "model", None)

    cfg2 = get("qwen2-72b")       # kv=8 -> fall to head_dim
    model2 = build(cfg2)
    cs2 = model2.cache_specs(128, 1024)
    specs2 = shd.cache_specs_tree(cfg2, cs2, mesh)
    assert specs2["k"] == P(None, "data", None, None, "model")


def test_batch_specs_drop_indivisible():
    mesh = fake_mesh()
    cfg = get("mamba2-2.7b")
    model = build(cfg)
    from repro.configs.base import SHAPES
    sds = model.input_specs(SHAPES["long_500k"])   # batch = 1
    specs = shd.batch_specs(cfg, sds, mesh)
    assert specs["token"] == P(None)   # batch 1 can't shard over 16


def test_optimizer_converges_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init_state(params)
    cfg = opt.OptConfig(lr=0.1, warmup_steps=1, total_steps=200,
                        weight_decay=0.0, grad_clip=10.0)
    for _ in range(150):
        grads = {"w": state.params["w"]}     # d/dw (w^2/2)
        state, _ = opt.apply_updates(state, grads, cfg)
    assert float(jnp.abs(state.params["w"]).max()) < 0.05


def test_checkpoint_roundtrip_and_retention():
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nested": {"b": jnp.ones((4,), jnp.int32)}}
    state = opt.init_state(params)
    with tempfile.TemporaryDirectory() as d:
        for s in (10, 20, 30, 40):
            ckpt.save(d, s, state, keep_last=2)
        assert ckpt.latest_step(d) == 40
        steps = sorted(os.listdir(d))
        assert steps == ["step_00000030", "step_00000040"]
        template = jax.eval_shape(lambda: state)
        restored = ckpt.restore(d, 40, template)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_partial():
    """A .tmp directory (simulated crash mid-save) is never 'latest'."""
    params = {"a": jnp.ones((2,))}
    state = opt.init_state(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state)
        os.makedirs(os.path.join(d, "step_00000002.tmp"))
        assert ckpt.latest_step(d) == 1


def test_train_restart_resumes_deterministically():
    """Crash at step 6, restart, final state == uninterrupted run."""
    from repro.runtime.fault_tolerance import run_with_restarts
    from repro.training.train_loop import LoopConfig
    import dataclasses
    from repro.configs.base import SHAPES

    cfg = reduced(get("smollm-135m"))
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=32, global_batch=2)
    opt_cfg = opt.OptConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    quiet = lambda s: None

    with tempfile.TemporaryDirectory() as d1:
        loop = LoopConfig(steps=10, checkpoint_every=5, checkpoint_dir=d1,
                          log_every=100)
        report = run_with_restarts(cfg, shape, loop, opt_cfg,
                                   fault_at_step=6, log=quiet)
        assert report.attempts == 2
        assert report.result["resumed_from"] == 5
        faulted_loss = report.result["final_loss"]

    with tempfile.TemporaryDirectory() as d2:
        loop = LoopConfig(steps=10, checkpoint_every=5, checkpoint_dir=d2,
                          log_every=100)
        from repro.training import train_loop
        clean = train_loop.train(cfg, shape, loop, opt_cfg, log=quiet)
    assert faulted_loss == pytest.approx(clean["final_loss"], rel=1e-5)


def test_elastic_reshard_roundtrip():
    """Save on mesh A, restore on a differently shaped mesh: same values."""
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.elastic import resharded_restore, verify_roundtrip
    cfg = reduced(get("smollm-135m"))
    model = build(cfg)
    params = model.init(KEY)
    state = opt.init_state(params)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, state)
        template = jax.eval_shape(lambda: state)
        mesh_b = make_host_mesh(model_parallel=1)
        restored = resharded_restore(d, 1, template, mesh_b, cfg)
        assert verify_roundtrip(state, restored)
