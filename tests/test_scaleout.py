"""Unit tests for the app-axis scale-out layer.

Three contracts, each load-bearing for ``EngineOptions(devices=...)``:

  * the ``distributed/compat.py`` shard_map shim translates to BOTH jax
    spellings correctly (``jax.shard_map`` with ``check_vma``/``axis_names``
    and ``jax.experimental.shard_map`` with ``check_rep``) — exercised via
    monkeypatch so a jax upgrade cannot silently break the path not taken
    by the installed version, plus a real execution on whichever the
    installed jax provides;
  * ``scaleout.shard_along_apps`` / ``pad_app_rows`` / ``mesh_for``
    semantics (vmap-style axes, masked +inf padding, the devices knob);
  * ``devices=1`` routes the engines through the full shard_map machinery
    on a single device and stays bit-identical — which is how ordinary
    (one-device) CI covers the sharded code path; multi-device bit-identity
    lives in ``tests/test_scaleout_conformance.py``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.experiment import (EngineOptions, FixedSpec, HybridSpec,
                                   NoUnloadSpec, run, sweep)
from repro.core.workload import Trace
from repro.distributed import compat
from repro.distributed.scaleout import (APP_AXIS, app_sharding, mesh_for,
                                        pad_app_rows, shard_along_apps)
from repro.launch.mesh import make_app_mesh

from golden_traces import CFG48


# --- compat.shard_map: both jax spellings, via monkeypatch -------------------


class _Recorder:
    """Stands in for a jax shard_map entry point and records its kwargs."""

    def __init__(self):
        self.f = None
        self.kwargs = None

    def __call__(self, f, **kwargs):
        self.f = f
        self.kwargs = kwargs
        return lambda *args: ("wrapped", args)


def test_compat_new_api_spelling(monkeypatch):
    """With jax.shard_map present (newer jax), the shim must pass
    check_vma and translate axis_names to a set."""
    rec = _Recorder()
    monkeypatch.setattr(jax, "shard_map", rec, raising=False)
    f = lambda x: x
    wrapped = compat.shard_map(f, "MESH", "IN", "OUT",
                               axis_names=(APP_AXIS,), check=True)
    assert rec.f is f
    assert rec.kwargs == dict(mesh="MESH", in_specs="IN", out_specs="OUT",
                              check_vma=True, axis_names={APP_AXIS})
    assert wrapped(1, 2) == ("wrapped", (1, 2))


def test_compat_new_api_omits_axis_names_when_none(monkeypatch):
    rec = _Recorder()
    monkeypatch.setattr(jax, "shard_map", rec, raising=False)
    compat.shard_map(lambda x: x, "MESH", "IN", "OUT")
    assert rec.kwargs == dict(mesh="MESH", in_specs="IN", out_specs="OUT",
                              check_vma=False)


def test_compat_old_api_spelling(monkeypatch):
    """Without jax.shard_map (jax 0.4.x), the shim must call the
    experimental spelling full-manual: check_rep only, no axis_names/auto
    (partial-manual lowers through an SPMD path that is unimplemented on
    some backends)."""
    import jax.experimental.shard_map as sm_mod
    monkeypatch.delattr(jax, "shard_map", raising=False)
    rec = _Recorder()
    monkeypatch.setattr(sm_mod, "shard_map", rec)
    wrapped = compat.shard_map(lambda x: x, "MESH", "IN", "OUT",
                               axis_names=(APP_AXIS,), check=True)
    assert rec.kwargs == dict(mesh="MESH", in_specs="IN", out_specs="OUT",
                              check_rep=True)
    assert wrapped(3) == ("wrapped", (3,))


def test_compat_executes_on_installed_jax():
    """Whichever spelling the installed jax has, the shim must actually
    partition a computation (any device count, including one)."""
    mesh = make_app_mesh()
    x = np.arange(4 * mesh.devices.size, dtype=np.float32).reshape(-1, 2)
    f = lambda a: a * 2.0 + 1.0
    got = compat.shard_map(f, mesh, (P(APP_AXIS, None),),
                           (P(APP_AXIS, None)))(x)
    np.testing.assert_array_equal(np.asarray(got), f(x))


# --- scaleout primitives -----------------------------------------------------


def test_mesh_for_semantics():
    assert mesh_for(None) is None
    m1 = mesh_for(1)
    assert isinstance(m1, Mesh)
    assert m1.axis_names == (APP_AXIS,) and m1.devices.size == 1
    auto = mesh_for("auto")
    if jax.device_count() == 1:
        assert auto is None          # collapses to the single-device path
    else:
        assert auto.devices.size == jax.device_count()
    with pytest.raises(ValueError, match="'auto'"):
        mesh_for("fast")
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        mesh_for(jax.device_count() + 1)
    with pytest.raises(ValueError, match="at least one device"):
        make_app_mesh(0)


def test_pad_app_rows():
    a = np.arange(12, dtype=np.float64).reshape(3, 4)
    assert pad_app_rows(a, 1) is a
    assert pad_app_rows(a, 3) is a                   # already a multiple
    p = pad_app_rows(a, 8)
    assert p.shape == (8, 4) and p.dtype == a.dtype
    np.testing.assert_array_equal(p[:3], a)
    assert np.all(np.isinf(p[3:])) and np.all(p[3:] > 0)


def test_app_sharding_spec():
    s = app_sharding(mesh_for(1), 2)
    assert isinstance(s, NamedSharding)
    assert s.spec == P(APP_AXIS, None)
    assert app_sharding(mesh_for(1), 1).spec == P(APP_AXIS)


def test_shard_along_apps_axes_and_replication():
    """vmap-style axes: sharded arg rows, replicated knob pytrees, rank-0
    leaves, negative out_axes — outputs equal the direct call."""
    mesh = mesh_for(1)
    times = np.arange(8, dtype=np.float64).reshape(4, 2)
    knobs = (np.float64(2.0), np.arange(3, dtype=np.float64))

    def fn(ts, kn):
        scale, vec = kn
        return dict(scaled=(ts * scale).T,            # apps on axis -1
                    shifted=ts.T + vec.sum())         # apps on axis -1

    got = shard_along_apps(fn, mesh, (0, None), -1)(times, knobs)
    want = fn(times, knobs)
    np.testing.assert_array_equal(np.asarray(got["scaled"]), want["scaled"])
    np.testing.assert_array_equal(np.asarray(got["shifted"]), want["shifted"])

    with pytest.raises(ValueError, match="in_axes"):
        shard_along_apps(fn, mesh, (0,), -1)(times, knobs)


def test_shard_along_apps_matches_unsharded_on_every_device():
    """With >1 devices this is a real partition; with one it is the
    degenerate mesh — either way the assembled output must equal the
    plain call (fixed device order, no collectives)."""
    mesh = mesh_for("auto") or mesh_for(1)
    n = 3 * mesh.devices.size + 1                    # deliberately ragged
    x = np.linspace(0.0, 1.0, n * 4).reshape(n, 4)
    xp = pad_app_rows(x, mesh.devices.size, fill=7.5)
    f = lambda a: jnp.cumsum(a, axis=-1)
    got = np.asarray(shard_along_apps(f, mesh, (0,), 0)(xp))[:n]
    np.testing.assert_array_equal(got, np.asarray(f(x)))


# --- devices=1 through the engines (the always-on sharded-path coverage) -----


def _ragged_trace():
    """9 apps (indivisible by any mesh), one zero-event and one
    single-event app, times on the 1/64-minute grid."""
    rng = np.random.default_rng(7)
    times = [np.cumsum(rng.integers(1, 64 * 90, 12)) / 64.0
             for _ in range(9)]
    times[3] = np.asarray([], np.float64)
    times[6] = times[6][:1]
    return Trace(specs=None, times=times, duration_minutes=4 * 1440.0)


GRID = [FixedSpec(10.0), NoUnloadSpec(),
        HybridSpec.from_config(CFG48),
        HybridSpec(range_minutes=64.0, cv_threshold=0.5, use_arima=False)]


@pytest.mark.parametrize("engine", ["fused", "pallas"])
def test_devices_one_bit_identical(engine):
    trace = _ragged_trace()
    base = sweep(trace, GRID, engine=engine,
                 options=EngineOptions(app_chunk=4))
    res = sweep(trace, GRID, engine=engine,
                options=EngineOptions(app_chunk=4, devices=1))
    np.testing.assert_array_equal(base.cold, res.cold)
    np.testing.assert_array_equal(base.invocations, res.invocations)
    np.testing.assert_array_equal(base.wasted_minutes, res.wasted_minutes)
    np.testing.assert_array_equal(base.final_prewarm, res.final_prewarm)
    np.testing.assert_array_equal(base.final_keep_alive,
                                  res.final_keep_alive)


def test_devices_knob_is_execution_only():
    """devices= must not change what run() computes — same SimResult
    fields, and the scalar engine simply ignores the knob."""
    trace = _ragged_trace()
    spec = GRID[2]
    one = run(trace, spec, options=EngineOptions(devices=1))
    plain = run(trace, spec)
    np.testing.assert_array_equal(one.cold, plain.cold)
    np.testing.assert_array_equal(one.wasted_minutes, plain.wasted_minutes)
    scal = run(trace, spec, engine="scalar",
               options=EngineOptions(devices=1))
    np.testing.assert_array_equal(scal.cold, plain.cold)


def test_engine_options_devices_default():
    assert EngineOptions().devices is None
    assert EngineOptions(devices="auto").devices == "auto"
