"""Batched time-series forecasting subsystem (PR 10).

Layout:

* :mod:`repro.forecast.arima_batched` — the vectorized fixed-order CSS
  ARIMA fit: vmapped Levenberg/Gauss-Newton over (task, order-grid), AIC
  scored in parallel, float32 everywhere.
* :mod:`repro.forecast.forecaster` — the scalar streaming front-end
  (:class:`ArimaForecaster`) plus the shared order-selection/cadence step.
* :mod:`repro.forecast.replay` — vectorized replay of the hybrid policy's
  per-event residency bounds with ARIMA overrides for OOB-heavy apps: the
  batched replacement for the engines' per-app scipy post-pass.
"""
from .arima_batched import (GridFit, MAX_OBS, ORDER_GRID, fit_arima_grid,
                            fit_window)
from .forecaster import (ArimaForecaster, DEFAULT_REFIT_EVERY,
                         select_order_step)

__all__ = [
    "ArimaForecaster", "DEFAULT_REFIT_EVERY", "GridFit", "MAX_OBS",
    "ORDER_GRID", "fit_arima_grid", "fit_window", "select_order_step",
]
