"""Scalar streaming front-end over the batched ARIMA grid fit.

:class:`ArimaForecaster` keeps the legacy public surface (``observe`` /
``forecast`` / ``state_dict``) used by the scalar hybrid policy, but fits
through :mod:`repro.forecast.arima_batched` at batch size 1 — the *same*
compiled per-row program the vectorized replay runs over thousands of apps,
so scalar and batched forecasts agree bit-for-bit.

Order selection and the refit cadence live in :func:`select_order_step`, a
pure function shared verbatim by this class and by
:mod:`repro.forecast.replay` (which replays the cadence per app on the
host after one batched fit of every call window). Keeping it single-sourced
is what makes the hybrid engines' ARIMA overrides bit-identical to the
scalar oracle.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from .arima_batched import MAX_OBS, ORDER_GRID, fit_window

__all__ = ["ArimaForecaster", "SelectionState", "select_order_step",
           "DEFAULT_REFIT_EVERY", "MIN_FORECAST_OBS", "FORECAST_FLOOR"]

DEFAULT_REFIT_EVERY = 8
#: Below this many observations the forecaster abstains entirely (legacy
#: behaviour: too little signal even for the smallest grid order).
MIN_FORECAST_OBS = 3
#: Forecasts are clamped to at least this many minutes — a sub-30s idle
#: prediction would unload instantly and thrash (legacy clamp).
FORECAST_FLOOR = 0.5

#: (selected order index or None, fits since the last auto-selection).
SelectionState = Tuple[Optional[int], int]


def select_order_step(state: SelectionState, aic_row, valid_row, pred_row,
                      refit_every: int) -> Tuple[SelectionState,
                                                 Optional[float]]:
    """One forecaster call: advance the refit cadence and pick a forecast.

    Every ``refit_every`` fits (and on the first fit) the order is
    re-selected as the first-wins AIC argmin over the valid grid entries;
    in between, the stored order is reused (coefficients still come from
    the fresh fit of the current window). Returns the new state and the
    clamped forecast, or ``None`` when no usable fit exists.

    Pure and host-side on purpose: the scalar forecaster and the batched
    replay both call exactly this function, so cadence/selection can never
    diverge between the oracle and the engines.
    """
    order, since = state
    if order is None or since >= refit_every:
        order = _first_wins_argmin(aic_row, valid_row)
        since = 0
    else:
        since += 1
    pred: Optional[float] = None
    if order is not None and bool(valid_row[order]):
        raw = float(pred_row[order])
        if math.isfinite(raw):
            pred = max(raw, FORECAST_FLOOR)
    return (order, since), pred


def _first_wins_argmin(aic_row, valid_row) -> Optional[int]:
    """Earliest grid index attaining the minimal AIC among valid fits
    (matches the legacy strict-improvement loop over ``ORDER_GRID``)."""
    best: Optional[int] = None
    best_aic = math.inf
    for i in range(len(ORDER_GRID)):
        if bool(valid_row[i]) and float(aic_row[i]) < best_aic:
            best = i
            best_aic = float(aic_row[i])
    return best


class ArimaForecaster:
    """Streaming next-idle-time forecaster for one app.

    Keeps a rolling window of the last :data:`MAX_OBS` inter-arrival times;
    ``forecast()`` grid-fits the window through the batched subsystem and
    applies the shared selection/cadence step. The full cadence state —
    ``refit_every``, fits since the last auto-selection, and the selected
    order — round-trips through ``state_dict()`` (the legacy class silently
    dropped everything but the observations).
    """

    def __init__(self, refit_every: int = DEFAULT_REFIT_EVERY) -> None:
        self._obs: List[float] = []
        self._refit_every = int(refit_every)
        self._since_auto = 0
        self._order: Optional[int] = None
        self._dirty = True
        self._cached: Optional[float] = None

    @property
    def n_obs(self) -> int:
        return len(self._obs)

    def observe(self, idle_minutes: float) -> None:
        self._obs.append(float(idle_minutes))
        if len(self._obs) > MAX_OBS:
            self._obs = self._obs[-MAX_OBS:]
        self._dirty = True

    def forecast(self) -> Optional[float]:
        """Predicted next idle time in minutes, or ``None`` if unusable."""
        if len(self._obs) < MIN_FORECAST_OBS:
            return None
        if self._dirty:
            fit = fit_window(self._obs)
            state, pred = select_order_step(
                (self._order, self._since_auto),
                fit.aic[0], fit.valid[0], fit.pred[0], self._refit_every)
            self._order, self._since_auto = state
            self._cached = pred
            self._dirty = False
        return self._cached

    def state_dict(self) -> Dict[str, object]:
        return {
            "obs": list(self._obs),
            "refit_every": self._refit_every,
            "since_auto": self._since_auto,
            "order": None if self._order is None else int(self._order),
        }

    def load_state_dict(self, state: Dict[str, object]) -> None:
        self._obs = [float(x) for x in state["obs"]]
        # Legacy checkpoints carry only the observations; default the
        # cadence fields rather than refusing the restore.
        self._refit_every = int(state.get("refit_every",
                                          DEFAULT_REFIT_EVERY))
        self._since_auto = int(state.get("since_auto", 0))
        order = state.get("order")
        self._order = None if order is None else int(order)
        self._dirty = True
        self._cached = None
