"""Vectorized replay of the hybrid policy for OOB-heavy apps.

The fused hybrid engines cannot run a forecaster inside their ``lax.scan``;
historically any app whose out-of-bounds counter ever crossed the threshold
was re-simulated through the *scalar* policy — a per-app, per-event Python
loop with a scipy ARIMA refit at every step. This module is the batched
replacement:

  1. one chunked ``lax.scan`` of the shared fused hybrid step
     (:func:`repro.core.policy_math.fused_hybrid_step_math`, float64)
     yields every event's residency bounds *and* a per-event flag for
     "the scalar policy would consult the forecaster here";
  2. the flagged (app, event) observation windows are stacked into a single
     batched grid fit (:func:`repro.forecast.arima_batched.fit_arima_grid`);
  3. the forecaster's order-selection cadence is replayed per app on the
     host (:func:`repro.forecast.forecaster.select_order_step` — the same
     function the scalar :class:`~repro.forecast.forecaster.ArimaForecaster`
     steps through), and accepted forecasts override the scanned bounds
     through the same ``policy_math.arima_window`` / ``window_bounds``
     helpers the scalar policy calls;
  4. cold/waste/final-window verdicts are recomputed vectorized in float64
     under the per-event bounds.

Equivalence to the scalar oracle is structural, not numerical luck: at
every event where the scalar policy does *not* take the ARIMA branch, its
windows are exactly the fused step's windows (the PR 2 conformance
contract), and at every event where it does, both sides run the identical
fit + selection + window code. ``tests/test_forecast_conformance.py`` pins
it anyway.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core import policy_math
from ..core.policy import HybridConfig
from .arima_batched import MAX_OBS, fit_arima_grid
from .forecaster import (DEFAULT_REFIT_EVERY, MIN_FORECAST_OBS,
                         select_order_step)

__all__ = ["hybrid_window_sequences", "replay_oob_apps"]


@partial(jax.jit, static_argnums=(1,))
def _branch_scan(times, cfg: policy_math.HybridStepConfig):
    """Scan one chunk's event columns through the fused hybrid step.

    Returns per-event (load, unload) residency bounds plus the per-event
    "forecaster consulted" flag: enough recorded samples AND the OOB
    counter heavy — the exact guard ``HybridHistogramPolicy._decide``
    evaluates after its histogram update.
    """
    n = times.shape[0]
    dt = times.dtype
    init = (
        jnp.full((n,), -jnp.inf, dt),
        jnp.zeros((n, cfg.n_bins), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), dt),
        jnp.zeros((n,), dt),
        jnp.zeros((n,), dt),
        jnp.full((n,), jnp.asarray(cfg.standard_keep, dt)),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), dt),
    )

    def body(carry, t_col):
        out = policy_math.fused_hybrid_step_math(
            t_col, *carry, cfg=cfg, gather=True)
        total = out[1][:, -1].astype(jnp.int32)
        heavy = policy_math.oob_heavy(total, out[2], cfg.oob_threshold)
        seen = (total + out[2]) >= cfg.min_samples
        return out, (out[5], out[6], heavy & seen)

    _, (load_seq, unload_seq, branch_seq) = jax.lax.scan(body, init, times.T)
    return load_seq.T, unload_seq.T, branch_seq.T


def _scan_window_sequences(times2d: np.ndarray, counts: np.ndarray,
                           hybrid: HybridConfig, app_chunk: Optional[int]
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fused-step (load, unload) bounds and branch flags for every event."""
    from ..core.simulator import (DEFAULT_APP_CHUNK, _chunked_buckets,
                                  _step_config_for)
    n, m_ev = times2d.shape
    la = np.zeros((n, m_ev))
    ua = np.full((n, m_ev), float(hybrid.standard_keep_alive))
    branch = np.zeros((n, m_ev), bool)
    cfg = _step_config_for(hybrid)
    chunk = DEFAULT_APP_CHUNK if app_chunk is None else int(app_chunk)
    with enable_x64():
        for sel, sub in _chunked_buckets(times2d, counts, chunk):
            l_seq, u_seq, b_seq = _branch_scan(
                jnp.asarray(sub, jnp.float64), cfg)
            width = sub.shape[1]
            la[sel, :width] = np.asarray(l_seq)
            ua[sel, :width] = np.asarray(u_seq)
            branch[sel, :width] = np.asarray(b_seq)
    return la, ua, branch


def _apply_forecast_overrides(times2d: np.ndarray, counts: np.ndarray,
                              hybrid: HybridConfig, la: np.ndarray,
                              ua: np.ndarray, branch: np.ndarray
                              ) -> np.ndarray:
    """Batched-ARIMA overrides of the scanned bounds, in place.

    Returns ``last_keep`` [n]: the keep-alive of each app's final decided
    window when that decision came from the forecaster, else NaN (final
    keep-alives of non-override windows are the float64 bound difference,
    exactly like every engine).
    """
    n = times2d.shape[0]
    last_keep = np.full(n, np.nan)
    if not hybrid.use_arima or not branch.any():
        return last_keep
    min_fit_obs = max(int(hybrid.arima_min_samples), MIN_FORECAST_OBS)

    # Stage 1: stack every (app, event) forecaster-call window. The scalar
    # forecaster sees the last MAX_OBS inter-arrival times *before* the
    # decision event, i.e. the diffs of t[0..k] trimmed to the window.
    rows: List[int] = []
    events: List[List[int]] = []
    windows: List[np.ndarray] = []
    lens: List[int] = []
    for r in np.nonzero(branch.any(axis=1))[0]:
        m = int(counts[r])
        its = np.diff(times2d[r, :m].astype(np.float64))
        ks = [k for k in range(1, m)
              if branch[r, k] and min(k, MAX_OBS) >= min_fit_obs]
        if not ks:
            continue
        rows.append(int(r))
        events.append(ks)
        for k in ks:
            w = its[max(0, k - MAX_OBS):k]
            lens.append(len(w))
            windows.append(w)
    if not windows:
        return last_keep

    stacked = np.zeros((len(windows), MAX_OBS), np.float32)
    for i, w in enumerate(windows):
        stacked[i, :len(w)] = w
    fit = fit_arima_grid(stacked, lens)

    # Stage 2: replay each app's selection cadence over its call sequence
    # (host-side and cheap — the device work happened once, above).
    task = 0
    for r, ks in zip(rows, events):
        state = (None, 0)
        last_event = int(counts[r]) - 1
        for k in ks:
            state, pred = select_order_step(
                state, fit.aic[task], fit.valid[task], fit.pred[task],
                DEFAULT_REFIT_EVERY)
            task += 1
            if pred is None or not (math.isfinite(pred) and pred > 0):
                continue  # scanned standard bounds already in place
            pw, ka = policy_math.arima_window(pred, hybrid.arima_margin)
            lo, hi = policy_math.window_bounds(pw, ka)
            la[r, k] = lo
            ua[r, k] = hi
            if k == last_event:
                last_keep[r] = ka
    return last_keep


def hybrid_window_sequences(times2d: np.ndarray, counts: np.ndarray,
                            hybrid: HybridConfig, *,
                            app_chunk: Optional[int] = None
                            ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-event (load_at, unload_at) bounds for the given apps, float64.

    ``times2d`` is a padded [n, M] event-time matrix (+inf padding, like
    ``Trace.to_padded``); row k's bounds are the windows decided *at* event
    k (they govern the following gap). This is the batched equivalent of
    stepping ``HybridHistogramPolicy.on_invocation`` through every event —
    forecaster path included — and is what the cluster engine's window
    phase consumes for its OOB-heavy rows.
    """
    la, ua, branch = _scan_window_sequences(times2d, counts, hybrid,
                                            app_chunk)
    _apply_forecast_overrides(times2d, counts, hybrid, la, ua, branch)
    return la, ua


def replay_oob_apps(times2d: np.ndarray, counts: np.ndarray,
                    duration: float, hybrid: HybridConfig,
                    app_indices: np.ndarray, include_trailing: bool, *,
                    app_chunk: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Re-simulate the flagged apps under the full (forecaster-capable)
    hybrid policy, vectorized — the batched replacement for the engines'
    per-app ``simulate_scalar`` ARIMA post-pass.

    Returns per-app arrays aligned with ``app_indices``: cold counts,
    wasted minutes, final prewarm, final keep-alive — bit-identical to
    ``simulate_scalar(trace, HybridHistogramPolicy(hybrid), ...)`` on those
    apps.
    """
    aidx = np.asarray(app_indices)
    sub_t = times2d[aidx]
    sub_c = counts[aidx].astype(np.int64)
    la, ua, branch = _scan_window_sequences(sub_t, sub_c, hybrid, app_chunk)
    last_keep = _apply_forecast_overrides(sub_t, sub_c, hybrid, la, ua,
                                          branch)

    k, m_ev = sub_t.shape
    t64 = sub_t.astype(np.float64)
    col = np.arange(m_ev)[None, :]
    valid = col < sub_c[:, None]
    has_events = sub_c > 0

    # Verdict for the gap closing at event j uses the bounds decided at
    # event j-1 (float64 throughout — identical IEEE ops to the scalar
    # loop's python floats).
    gap_valid = valid[:, 1:]
    with np.errstate(invalid="ignore"):   # inf - inf on padding columns
        it = t64[:, 1:] - t64[:, :-1]
    it = np.where(gap_valid, it, 0.0)
    prev_la, prev_ua = la[:, :-1], ua[:, :-1]
    warm = policy_math.warm_from_bounds(it, prev_la, prev_ua)
    cold = has_events.astype(np.int64) + np.sum(gap_valid & ~warm, axis=1)
    contrib = np.where(gap_valid,
                       policy_math.idle_from_bounds(it, prev_la, prev_ua),
                       0.0)
    # Accumulate in event order (a column loop, apps vectorized): float64
    # addition is order-sensitive at the last ulp and the scalar oracle
    # sums per event.
    waste = np.zeros(k)
    for j in range(contrib.shape[1]):
        waste += contrib[:, j]

    last = np.maximum(sub_c - 1, 0)
    rows = np.arange(k)
    final_la = np.where(has_events, la[rows, last], 0.0)
    final_ua = np.where(has_events, ua[rows, last],
                        float(hybrid.standard_keep_alive))
    if include_trailing:
        t_last = np.where(has_events, t64[rows, last], np.inf)
        tail = duration - t_last
        waste = waste + np.where(
            has_events & (tail > 0),
            policy_math.idle_from_bounds(np.where(np.isfinite(tail), tail,
                                                  0.0),
                                         final_la, final_ua),
            0.0)
    # Final windows: prewarm == load bound (all window families emit
    # non-negative prewarm); keep-alive is the float64 bound difference,
    # except when the last decision was a forecast — the scalar policy
    # reports that keep-alive directly, and (pw + ka) - pw need not round
    # back to ka.
    final_keep = np.where(np.isnan(last_keep), final_ua - final_la,
                          last_keep)
    return dict(cold=cold, wasted_minutes=waste, final_prewarm=final_la,
                final_keep_alive=final_keep)
