"""Batched fixed-order CSS ARIMA fitting (the vectorized forecast engine).

The paper's hybrid policy falls back to an ARIMA forecast of the next idle
time for apps whose ITs are mostly out of histogram bounds. The legacy
implementation (``repro.core.arima``, now a deprecation shim) fit one app at
a time with scipy's Nelder-Mead — the single remaining per-app Python loop
in the pipeline. This module replaces it with a batched fit:

  * every (series, order) pair is fit **independently and in parallel**: a
    damped Gauss-Newton (Levenberg-Marquardt) minimization of the
    conditional-sum-of-squares objective, ``vmap``-ed over a static
    (p, d, q) order grid and again over the task axis;
  * the residual recursion is a ``lax.scan`` over a fixed ``MAX_OBS``-wide
    window with masked lag updates, so ragged series lengths ride one
    compiled program;
  * orders are scored by AIC in the same pass; order *selection* (and the
    refit cadence) happens on the host — see
    :func:`repro.forecast.forecaster.select_order_step` — so the scalar
    oracle and the batched replay share one selection routine.

Everything is computed in float32 regardless of the x64 regime: forecasts
are *decisions*, and float32 keeps them bit-identical between the float64
scalar oracle and the float32-capable engines (the same contract as
``repro.core.policy_math``). The scalar path fits a [1, MAX_OBS] batch and
the replay fits [chunk, MAX_OBS] batches through the same per-row program
(``vmap`` adds a batch axis without changing per-row math);
``tests/test_forecast.py`` pins the batch-size invariance and
``tests/test_forecast_conformance.py`` pins the fit against the scipy
test oracle.

Stationarity/invertibility: after every Gauss-Newton step the AR and MA
coefficient pairs are projected into the (slightly shrunken) stationary /
invertible triangle ``{|c2| < 1, |c1| < 1 - c2}`` — unlike the legacy
soft ``|coef| <= 1.5`` guard, fitted AR roots are guaranteed stable
(property-tested in ``tests/test_forecast_property.py``).
"""
from __future__ import annotations

import itertools
from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MAX_OBS", "ORDER_GRID", "GridFit", "fit_arima_grid", "fit_window",
]

#: Rolling observation window — ARIMA apps see invocations hours apart, so a
#: small window tracks regime changes (same value as the legacy forecaster).
MAX_OBS = 64

#: The static order grid, in the legacy ``auto_arima`` enumeration order
#: (AIC ties resolve to the earliest grid entry, like the old first-wins
#: strict-improvement loop).
ORDER_GRID: Tuple[Tuple[int, int, int], ...] = tuple(
    (p, d, q)
    for p, d, q in itertools.product(range(3), range(2), range(3))
    if (p, d, q) != (0, 0, 0))

_N_ORDERS = len(ORDER_GRID)
_GN_ITERS = 24          # Levenberg-Marquardt iterations (fixed, branchless)
_COEF_BOUND = 0.98      # stationarity/invertibility triangle shrink factor
_SSE_FLOOR = 1e-12      # matches the legacy sigma2 floor

# Host-side order-grid columns, reused by every jitted fit.
_ORD_P = np.asarray([o[0] for o in ORDER_GRID], np.int32)
_ORD_D = np.asarray([o[1] for o in ORDER_GRID], np.int32)
_ORD_Q = np.asarray([o[2] for o in ORDER_GRID], np.int32)

#: Batch rows are padded up to the smallest of these shapes so the expensive
#: fit program compiles a handful of times, not once per ragged batch.
_BATCH_BUCKETS = (1, 32, 256, 2048)
_FIT_CHUNK = _BATCH_BUCKETS[-1]


class GridFit(NamedTuple):
    """Per-(task, order) fit results, host numpy.

    ``aic``/``pred`` are float32 [B, n_orders]; ``valid`` marks fits that
    are usable (long enough series, finite inputs, non-degenerate variance,
    finite forecast). Invalid entries carry ``aic = +inf``. ``coef`` is
    float32 [B, n_orders, 4] holding the projected ``(ar1, ar2, ma1, ma2)``
    vector (inactive lags are exactly 0) and ``mu`` [B, n_orders] the mean
    of the differenced series — together they reconstruct the fitted model
    (the deprecation shim and the stationarity property tests read them).
    """
    aic: np.ndarray
    pred: np.ndarray
    valid: np.ndarray
    coef: np.ndarray
    mu: np.ndarray


def _project_triangle(c1, c2):
    """Project a (lag-1, lag-2) coefficient pair into the stationary (AR) /
    invertible (MA) region ``{|c2| < 1, c2 + c1 < 1, c2 - c1 < 1}``,
    shrunk by ``_COEF_BOUND`` so roots stay strictly outside the unit
    circle."""
    b = jnp.float32(_COEF_BOUND)
    c2 = jnp.clip(c2, -b, b)
    lim = b * (jnp.float32(1.0) - c2)
    return jnp.clip(c1, -lim, lim), c2


def _css_scan(wc, mask, theta):
    """CSS residuals of an ARMA(<=2, <=2) on the centered series ``wc``.

    Zero pre-sample convention (exactly the legacy recursion): lag values
    before the first observation are 0. ``mask`` gates both the residual
    and the lag shift, so after the scan the carry holds the *last valid*
    (w, e) lags — the state the one-step forecast reads.
    Returns (residuals [L], (w1, w2, e1, e2)).
    """
    ar1, ar2, ma1, ma2 = theta[0], theta[1], theta[2], theta[3]

    def step(carry, x):
        w1, w2, e1, e2 = carry
        wct, mt = x
        fit = ar1 * w1 + ar2 * w2 + ma1 * e1 + ma2 * e2
        e = jnp.where(mt, wct - fit, jnp.float32(0.0))
        new = (jnp.where(mt, wct, w1), jnp.where(mt, w1, w2),
               jnp.where(mt, e, e1), jnp.where(mt, e1, e2))
        return new, e

    zero = jnp.float32(0.0)
    carry, es = jax.lax.scan(step, (zero, zero, zero, zero), (wc, mask))
    return es, carry


def _fit_one(y, n, p, d, q):
    """Fit one (series, order) pair; returns (aic, pred, valid) scalars.

    ``y`` is [MAX_OBS] float32 (observations left-aligned, garbage beyond
    ``n``); ``p``/``d``/``q`` are traced int32 scalars from the order grid,
    expressed as coefficient masks so one program serves every order.
    """
    L = y.shape[0]
    idx = jnp.arange(L, dtype=jnp.int32)
    obs_mask = idx < n
    y = jnp.where(obs_mask, y, jnp.float32(0.0))
    finite_in = jnp.all(jnp.where(obs_mask, jnp.isfinite(y), True))

    # Difference (d <= 1): w_t = y_{t+1} - y_t, valid length m = n - d.
    use_diff = d == 1
    w = jnp.where(use_diff, jnp.roll(y, -1) - y, y)
    m = n - d
    mask = idx < m
    w = jnp.where(mask, w, jnp.float32(0.0))
    mf = jnp.maximum(m.astype(jnp.float32), jnp.float32(1.0))
    mu = jnp.sum(w) / mf
    wc = jnp.where(mask, w - mu, jnp.float32(0.0))
    sse0 = jnp.sum(wc * wc)

    # Active-coefficient mask: theta = (ar1, ar2, ma1, ma2).
    pmask = jnp.stack([p >= 1, p >= 2, q >= 1, q >= 2]).astype(jnp.float32)

    def residuals(theta):
        th = theta * pmask
        a1, a2 = _project_triangle(th[0], th[1])
        b1, b2 = _project_triangle(th[2], th[3])
        return _css_scan(wc, mask, (a1, a2, b1, b2))

    def sse_of(theta):
        es, _ = residuals(theta)
        return jnp.sum(es * es)

    def lm_step(_, state):
        theta, best_sse, lam = state
        es, _ = residuals(theta)
        jac = jax.jacfwd(lambda th: residuals(th)[0])(theta) * pmask[None, :]
        g = jac.T @ es
        h = jac.T @ jac
        damp = lam * (jnp.diag(h) + jnp.float32(1e-6))
        # Inactive coefficients get identity rows: delta stays 0 there.
        a = h + jnp.diag(damp) + jnp.diag(jnp.float32(1.0) - pmask)
        delta = jnp.linalg.solve(a, g)
        cand = theta - delta
        new_sse = sse_of(cand)
        better = new_sse < best_sse
        theta = jnp.where(better, cand, theta)
        best_sse = jnp.where(better, new_sse, best_sse)
        lam = jnp.where(better, lam * jnp.float32(0.3),
                        lam * jnp.float32(4.0))
        return theta, best_sse, jnp.clip(lam, 1e-8, 1e8)

    # Two deterministic starts: zeros, and the lag-1 autocorrelation of the
    # centered series (the standard moment init — CSS in the MA direction
    # is flat around zero, so a zero start alone stalls on MA-heavy
    # orders). Best SSE wins; both run branchlessly in one program.
    r1_num = jnp.sum(wc * jnp.roll(wc, 1) * mask * jnp.roll(mask, 1))
    r1 = jnp.clip(r1_num / jnp.maximum(sse0, jnp.float32(_SSE_FLOOR)),
                  -0.9, 0.9)
    zero = jnp.float32(0.0)
    half = jnp.float32(0.5)
    theta, sse = jnp.zeros(4, jnp.float32), sse0
    for start in (jnp.zeros(4, jnp.float32),
                  jnp.stack([r1, zero, r1, zero]),
                  # Opposed-sign AR/MA pairs: mixed ARMA objectives have a
                  # near-cancellation valley along ar ~ -ma that a single
                  # start cannot cross.
                  jnp.stack([half, zero, -half, zero]),
                  jnp.stack([-half, zero, half, zero])):
        th_s, sse_s, _ = jax.lax.fori_loop(
            0, _GN_ITERS, lm_step, (start, sse_of(start),
                                    jnp.float32(1e-2)))
        take = sse_s < sse
        theta = jnp.where(take, th_s, theta)
        sse = jnp.where(take, sse_s, sse)

    es, (w1, w2, e1, e2) = residuals(theta)
    th = theta * pmask
    a1, a2 = _project_triangle(th[0], th[1])
    b1, b2 = _project_triangle(th[2], th[3])
    coef = jnp.stack([a1, a2, b1, b2]) * pmask
    pred_w = mu + a1 * w1 + a2 * w2 + b1 * e1 + b2 * e2
    # Un-difference: the d=1 forecast predicts y_{n} = y_{n-1} + pred_w.
    last = jnp.take(y, jnp.maximum(n - 1, 0))
    pred = jnp.where(use_diff, last + pred_w, pred_w)

    sse = jnp.maximum(sse, jnp.float32(_SSE_FLOOR))
    k = (p + q + 1).astype(jnp.float32)
    aic = mf * jnp.log(sse / mf) + jnp.float32(2.0) * k

    long_enough = (n >= d + jnp.maximum(p, q) + 2) & (m >= p + q + 1)
    # Zero variance (a constant series — the perfectly-periodic timer
    # case) is not a failure: the SSE floor keeps the AIC finite and the
    # forecast collapses to the window mean, exactly the legacy contract.
    # Only too-short or non-finite inputs fall back to the standard
    # keep-alive verdict.
    valid = (long_enough & finite_in
             & jnp.isfinite(pred) & jnp.isfinite(aic))
    aic = jnp.where(valid, aic, jnp.float32(jnp.inf))
    return aic, pred, valid, coef, mu


@partial(jax.jit, static_argnums=())
def _fit_grid(series, lengths):
    """[B, MAX_OBS] x order grid -> (aic, pred, valid, coef, mu), batched
    as [B, n_orders(, 4)]."""
    over_orders = jax.vmap(_fit_one, in_axes=(None, None, 0, 0, 0))
    over_tasks = jax.vmap(over_orders, in_axes=(0, 0, None, None, None))
    return over_tasks(series, lengths,
                      jnp.asarray(_ORD_P), jnp.asarray(_ORD_D),
                      jnp.asarray(_ORD_Q))


def _bucket(b: int) -> int:
    for size in _BATCH_BUCKETS:
        if b <= size:
            return size
    return _FIT_CHUNK


def _as_rows(series, lengths) -> Tuple[np.ndarray, np.ndarray]:
    rows = np.asarray(series, np.float32)
    if rows.ndim != 2:
        raise ValueError(f"series must be [batch, obs], got shape "
                         f"{rows.shape}")
    lens = np.asarray(lengths, np.int32)
    if lens.shape != (rows.shape[0],):
        raise ValueError("lengths must be one int per series row")
    if rows.shape[1] > MAX_OBS:
        raise ValueError(f"series wider than MAX_OBS={MAX_OBS}; pass the "
                         f"trailing window")
    if rows.shape[1] < MAX_OBS:
        rows = np.pad(rows, ((0, 0), (0, MAX_OBS - rows.shape[1])))
    return rows, np.minimum(lens, rows.shape[1])


def fit_arima_grid(series, lengths) -> GridFit:
    """Fit every series against the whole order grid, batched on device.

    ``series`` is [B, <=MAX_OBS] float-like (rows left-aligned, anything
    past ``lengths[b]`` ignored); returns a :class:`GridFit`. Batches are
    chunked to ``_FIT_CHUNK`` rows and padded to a small set of bucket
    shapes, so arbitrary batch sizes reuse a handful of compilations; rows
    are computed independently, so results are bit-identical regardless of
    batch size or padding.
    """
    rows, lens = _as_rows(series, lengths)
    B = rows.shape[0]
    aic = np.empty((B, _N_ORDERS), np.float32)
    pred = np.empty((B, _N_ORDERS), np.float32)
    valid = np.empty((B, _N_ORDERS), bool)
    coef = np.empty((B, _N_ORDERS, 4), np.float32)
    mu = np.empty((B, _N_ORDERS), np.float32)
    for lo in range(0, B, _FIT_CHUNK):
        chunk_rows = rows[lo:lo + _FIT_CHUNK]
        chunk_lens = lens[lo:lo + _FIT_CHUNK]
        bc = chunk_rows.shape[0]
        pad = _bucket(bc) - bc
        if pad:
            chunk_rows = np.pad(chunk_rows, ((0, pad), (0, 0)))
            chunk_lens = np.pad(chunk_lens, (0, pad))
        a, p, v, c, m = _fit_grid(jnp.asarray(chunk_rows),
                                  jnp.asarray(chunk_lens))
        aic[lo:lo + bc] = np.asarray(a)[:bc]
        pred[lo:lo + bc] = np.asarray(p)[:bc]
        valid[lo:lo + bc] = np.asarray(v)[:bc]
        coef[lo:lo + bc] = np.asarray(c)[:bc]
        mu[lo:lo + bc] = np.asarray(m)[:bc]
    return GridFit(aic=aic, pred=pred, valid=valid, coef=coef, mu=mu)


def fit_window(obs: Sequence[float]) -> GridFit:
    """Grid-fit one observation window (the scalar forecaster's call path —
    the same program the batched replay runs, at batch size 1)."""
    window = list(obs)[-MAX_OBS:]
    row = np.zeros((1, MAX_OBS), np.float32)
    row[0, :len(window)] = window
    return fit_arima_grid(row, [len(window)])
