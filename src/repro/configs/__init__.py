"""Architecture registry: ``--arch <id>`` resolves through here."""
from .base import ModelConfig, ShapeConfig, SHAPES, reduced
from . import (smollm_135m, qwen2_72b, qwen2_7b, deepseek_67b, mamba2_2p7b,
               qwen3_moe_30b_a3b, olmoe_1b_7b, recurrentgemma_2b,
               llava_next_34b, seamless_m4t_medium)

ARCHS = {m.CONFIG.arch_id: m.CONFIG for m in (
    smollm_135m, qwen2_72b, qwen2_7b, deepseek_67b, mamba2_2p7b,
    qwen3_moe_30b_a3b, olmoe_1b_7b, recurrentgemma_2b, llava_next_34b,
    seamless_m4t_medium,
)}

# Sub-quadratic archs run the long_500k shape; pure full-attention archs skip
# it (documented in DESIGN.md §Architectures).
SUBQUADRATIC = {"mamba2-2.7b", "recurrentgemma-2b"}


def get(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def cells(include_skipped: bool = False):
    """Yield every (arch_id, shape_name) dry-run cell."""
    for arch_id in ARCHS:
        for shape in SHAPES.values():
            if shape.name == "long_500k" and arch_id not in SUBQUADRATIC:
                if include_skipped:
                    yield arch_id, shape.name, "skip:full-attention"
                continue
            if include_skipped:
                yield arch_id, shape.name, "run"
            else:
                yield arch_id, shape.name


__all__ = ["ARCHS", "SHAPES", "SUBQUADRATIC", "get", "cells", "ModelConfig",
           "ShapeConfig", "reduced"]
