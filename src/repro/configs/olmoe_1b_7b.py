"""OLMoE-1B-7B [arXiv:2409.02060] — MoE, 64 experts top-8, MHA."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50_304, head_dim=128,
    n_experts=64, top_k=8, d_expert=1024,
)
