"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — MoE, 128 experts top-8."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=768,
    vocab=151_936, head_dim=128, rope_theta=1e6,
    n_experts=128, top_k=8, d_expert=768,
)
