"""Qwen2-72B [arXiv:2407.10671] — dense GQA with QKV bias."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-72b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29_568,
    vocab=152_064, head_dim=128, qkv_bias=True, rope_theta=1e6,
)
