"""Mamba2-2.7B [arXiv:2405.21060] — SSD (state-space duality), attention-free."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50_280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=256, conv_width=4,
)
