"""LLaVA-NeXT-34B [hf:llava-hf/llava-v1.6; backbone only] — VLM.

The anyres vision tower is a STUB: input_specs() provides precomputed patch
embeddings (anyres tiling of a 672x672 image -> 2880 patch tokens) that the
backbone consumes alongside text tokens.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20_480,
    vocab=64_000, head_dim=128, rope_theta=5e6,
    frontend="vision", frontend_tokens=2880,
)
