"""RecurrentGemma-2B [arXiv:2402.19427] — RG-LRU + local attention, 1:2."""
from .base import ModelConfig

# 26 layers, repeating (recurrent, recurrent, local-attention); MQA (kv=1),
# local window 2048, head_dim 256, d_rnn = lru_width 2560.
CONFIG = ModelConfig(
    arch_id="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256_000, head_dim=256, attn_window=2048,
    block_pattern=("rec", "rec", "attn"), rglru_d_rnn=2560,
)
