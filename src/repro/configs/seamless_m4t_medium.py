"""SeamlessM4T-medium [arXiv:2308.11596; backbone only] — enc-dec, audio.

The speech frontend (fbank + w2v-BERT feature extractor) is a STUB:
input_specs() provides precomputed frame embeddings for the encoder.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium", family="encdec",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256_206, head_dim=64, n_encoder_layers=12, cross_attention=True,
    frontend="audio", frontend_tokens=1024,
)
