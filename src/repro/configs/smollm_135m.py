"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small dense LM."""
from .base import ModelConfig

CONFIG = ModelConfig(
    arch_id="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab=49_152, head_dim=64, tie_embeddings=True,
)
