"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a :class:`ModelConfig`; the four
assigned input shapes are :class:`ShapeConfig` instances. ``reduced()``
produces the small same-family config used by CPU smoke tests (the full
configs are exercised only through the dry-run's ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0             # per-expert FFN width
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 512     # GShard dispatch group size (tokens)
    moe_impl: str = "einsum"      # einsum (GShard baseline) | gather (opt)
    router_aux_weight: float = 0.01
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- hybrid (RecurrentGemma) ---
    attn_window: int = 0          # local attention window (0 = full/global)
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    rglru_d_rnn: int = 0          # recurrence width (0 -> d_model)
    # --- encoder-decoder ---
    n_encoder_layers: int = 0
    cross_attention: bool = False
    # --- modality frontend stubs ---
    frontend: str = "none"        # none | vision | audio
    frontend_tokens: int = 0      # embeddings provided by the stub per sample
    # --- pipeline parallelism (optional; pod axis = stages) ---
    pipeline_stages: int = 0      # 0/1 = off
    pipeline_microbatches: int = 8
    # --- loss ---
    chunked_xent: bool = False    # never materialize [B,S,V] logits
    # --- numerics / structure ---
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    use_kernels: bool = False     # Pallas path (TPU target; interpret on CPU)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab=512,
        dtype="float32",
        remat=False,
        scan_layers=cfg.scan_layers,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, top_k=2, d_expert=64, moe_group_size=64)
    if cfg.family == "ssm":
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16, d_model=64,
                  n_heads=2, n_kv_heads=2, head_dim=32)
    if cfg.family == "hybrid":
        kw.update(attn_window=16, block_pattern=("rec", "rec", "attn"),
                  n_layers=3, rglru_d_rnn=0)
    if cfg.family == "encdec":
        kw.update(n_encoder_layers=2)
    if cfg.frontend != "none":
        kw.update(frontend_tokens=8)
    return cfg.with_(**kw)
