"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core.histogram import HistogramConfig
from ..models.layers import _sdpa
from ..models.mamba2 import ssd_reference
from ..models.rglru import rglru_scan_ref


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: [B,Hq,S,D] (BHSD layout, like the kernel); k,v: [B,Hkv,S,D]."""
    qs = jnp.moveaxis(q, 1, 2)     # -> [B,S,H,D]
    ks = jnp.moveaxis(k, 1, 2)
    vs = jnp.moveaxis(v, 1, 2)
    out = _sdpa(qs, ks, vs, causal=causal, window=window, q_offset=0)
    return jnp.moveaxis(out, 2, 1)


def decode_attention_ref(q, k, v, kv_len):
    """q: [B,Hkv,group,D]; k,v: [B,Hkv,Skv,D]."""
    B, Hkv, group, D = q.shape
    Skv = k.shape[2]
    qf = q.astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32))
    mask = jnp.arange(Skv)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, chunk):
    return ssd_reference(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                         B.astype(jnp.float32), C.astype(jnp.float32), chunk)


def rglru_ref(b_in, a):
    return rglru_scan_ref(b_in, a)


def policy_update_ref(counts, oob, total, cv_sum, cv_sum_sq, bins, active,
                      *, head_pct=5.0, tail_pct=99.0, margin=0.10,
                      bin_minutes=1.0, range_minutes=240.0, cv_threshold=2.0,
                      min_samples=5, oob_threshold=0.5):
    """Vectorized jnp oracle mirroring repro.core semantics exactly."""
    n_apps, n_bins = counts.shape
    active = active != 0
    in_b = active & (bins >= 0) & (bins < n_bins)
    oob_hit = active & (bins >= n_bins)
    safe = jnp.clip(bins, 0, n_bins - 1)
    onehot = jax.nn.one_hot(safe, n_bins, dtype=jnp.int32) * in_b[:, None]
    old = jnp.take_along_axis(counts, safe[:, None], axis=1)[:, 0]
    new_counts = counts + onehot
    total = total + in_b.astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    inb_f = in_b.astype(jnp.float32)
    cv_sum = cv_sum + inb_f
    cv_sum_sq = cv_sum_sq + inb_f * (2.0 * old.astype(jnp.float32) + 1.0)

    mean = cv_sum / n_bins
    var = jnp.maximum(cv_sum_sq / n_bins - mean * mean, 0.0)
    cv = jnp.where(mean > 0, jnp.sqrt(var) / jnp.maximum(mean, 1e-9), 0.0)

    cum = jnp.cumsum(new_counts, axis=1)
    tot_f = jnp.maximum(total, 1).astype(jnp.float32)
    head_thr = jnp.maximum(jnp.ceil(tot_f * head_pct / 100.0), 1.0)
    tail_thr = jnp.maximum(jnp.ceil(tot_f * tail_pct / 100.0), 1.0)
    head_bin = jnp.argmax(cum.astype(jnp.float32) >= head_thr[:, None], axis=1)
    tail_bin = jnp.argmax(cum.astype(jnp.float32) >= tail_thr[:, None], axis=1) + 1

    prewarm = head_bin.astype(jnp.float32) * bin_minutes * (1.0 - margin)
    tail = jnp.minimum(tail_bin.astype(jnp.float32) * bin_minutes,
                       range_minutes) * (1.0 + margin)
    keep = jnp.maximum(tail - prewarm, 0.0)
    seen = total + oob
    use_hist = ((seen >= min_samples) & (cv >= cv_threshold) & (total > 0)
                & ~(oob.astype(jnp.float32) > oob_threshold
                    * jnp.maximum(seen, 1).astype(jnp.float32)))
    prewarm = jnp.where(use_hist, prewarm, 0.0)
    keep = jnp.where(use_hist, keep, range_minutes)
    return (new_counts, oob, total, cv_sum, cv_sum_sq, prewarm, keep,
            use_hist.astype(jnp.int32))
