"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..models.layers import _sdpa
from ..models.mamba2 import ssd_reference
from ..models.rglru import rglru_scan_ref


def attention_ref(q, k, v, *, causal=True, window=0):
    """q: [B,Hq,S,D] (BHSD layout, like the kernel); k,v: [B,Hkv,S,D]."""
    qs = jnp.moveaxis(q, 1, 2)     # -> [B,S,H,D]
    ks = jnp.moveaxis(k, 1, 2)
    vs = jnp.moveaxis(v, 1, 2)
    out = _sdpa(qs, ks, vs, causal=causal, window=window, q_offset=0)
    return jnp.moveaxis(out, 2, 1)


def decode_attention_ref(q, k, v, kv_len):
    """q: [B,Hkv,group,D]; k,v: [B,Hkv,Skv,D]."""
    B, Hkv, group, D = q.shape
    Skv = k.shape[2]
    qf = q.astype(jnp.float32) / math.sqrt(D)
    s = jnp.einsum("bhgd,bhkd->bhgk", qf, k.astype(jnp.float32))
    mask = jnp.arange(Skv)[None, None, None, :] < kv_len
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgk,bhkd->bhgd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, B, C, chunk):
    return ssd_reference(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                         B.astype(jnp.float32), C.astype(jnp.float32), chunk)


def rglru_ref(b_in, a):
    return rglru_scan_ref(b_in, a)


def policy_update_ref(counts, oob, total, cv_sum, cv_sum_sq, bins, active,
                      *, head_pct=5.0, tail_pct=99.0, margin=0.10,
                      bin_minutes=1.0, range_minutes=240.0, cv_threshold=2.0,
                      min_samples=5, oob_threshold=0.5):
    """Vectorized jnp oracle: same single-source policy math as the kernel,
    but through the XLA-friendly gather forms."""
    from ..core import policy_math

    n_apps, n_bins = counts.shape
    active = active != 0
    in_b = active & (bins >= 0) & (bins < n_bins)
    oob_hit = active & (bins >= n_bins)
    safe = jnp.clip(bins, 0, n_bins - 1)
    onehot = jax.nn.one_hot(safe, n_bins, dtype=jnp.int32) * in_b[:, None]
    old = jnp.take_along_axis(counts, safe[:, None], axis=1)[:, 0]
    new_counts = counts + onehot
    total = total + in_b.astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    cv_sum, cv_sum_sq = policy_math.welford_update(cv_sum, cv_sum_sq, in_b,
                                                   old)

    cum = jnp.cumsum(new_counts, axis=1)
    head_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(total, head_pct),
        gather=True)
    tail_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(total, tail_pct),
        gather=True) + 1
    load_at, unload_at = policy_math.window_values(
        head_bin, tail_bin, bin_minutes, range_minutes, margin)
    use_hist = policy_math.use_histogram_gate(
        total, oob, cv_sum, cv_sum_sq, n_bins, min_samples, cv_threshold,
        oob_threshold)
    std_load, std_unload = policy_math.standard_window_bounds(range_minutes)
    prewarm = jnp.where(use_hist, load_at, std_load)
    keep = jnp.where(use_hist, unload_at, std_unload) - prewarm
    return (new_counts, oob, total, cv_sum, cv_sum_sq, prewarm, keep,
            use_hist.astype(jnp.int32))
