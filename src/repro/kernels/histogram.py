"""Fleet-scale hybrid-histogram policy update — Pallas TPU kernel.

This is the paper's own hot loop, adapted TPU-natively (DESIGN.md §2). The
paper's challenges #4/#5 demand O(µs) policy updates per invocation; at
provider scale (millions of app endpoints) the control plane batches the
idle-time observations of one scheduling tick and updates *all* app
histograms plus their policy windows in a single vectorized pass:

  for each app a in tile:                      (one VMEM tile = TA apps)
    counts[a, bin(it_a)] += 1                  (or OOB counter)
    cv[a]     <- Welford O(1) update
    head/tail <- weighted 5th/99th percentile over bins
    prewarm/keepalive <- margins + representativeness fallback

Everything is rank-2 [TA, n_bins] arithmetic — ideal VPU work. The decision
formulas are NOT written here: kernel bodies call the single-source helpers
in :mod:`repro.core.policy_math` with ``gather=False`` (masked-reduction
forms — compare-against-iota instead of row gathers), which trace inside
Pallas identically to the ``lax.scan`` engines.

Grid: (n_apps / TA,) — fully parallel over app tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import policy_math
from . import compat


def _policy_kernel(counts_ref, oob_ref, total_ref, cvs_ref, cvss_ref,
                   bins_ref, active_ref,
                   ncounts_ref, noob_ref, ntotal_ref, ncvs_ref, ncvss_ref,
                   prewarm_ref, keep_ref, use_hist_ref, *,
                   n_bins: int, head_pct: float, tail_pct: float,
                   margin: float, bin_minutes: float, range_minutes: float,
                   cv_threshold: float, min_samples: int, oob_threshold: float):
    counts = counts_ref[...]                       # [TA, n_bins] i32
    bins = bins_ref[...]                           # [TA] i32 (bin idx; >=n_bins -> OOB)
    active = active_ref[...] != 0                  # [TA]
    TA = counts.shape[0]

    in_b = active & (bins >= 0) & (bins < n_bins)
    oob_hit = active & (bins >= n_bins)
    safe = jnp.clip(bins, 0, n_bins - 1)

    iota = jax.lax.broadcasted_iota(jnp.int32, (TA, n_bins), 1)
    onehot = (iota == safe[:, None]) & in_b[:, None]
    old = jnp.sum(jnp.where(onehot, counts, 0), axis=1)          # [TA]
    new_counts = counts + onehot.astype(jnp.int32)

    total = total_ref[...] + in_b.astype(jnp.int32)
    oob = oob_ref[...] + oob_hit.astype(jnp.int32)
    cvs, cvss = policy_math.welford_update(cvs_ref[...], cvss_ref[...],
                                           in_b, old)

    cum = jnp.cumsum(new_counts, axis=1)                          # [TA, n_bins]
    head_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(total, head_pct),
        gather=False)
    tail_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(total, tail_pct),
        gather=False) + 1
    load_at, unload_at = policy_math.window_values(
        head_bin, tail_bin, bin_minutes, range_minutes, margin)
    use_hist = policy_math.use_histogram_gate(
        total, oob, cvs, cvss, n_bins, min_samples, cv_threshold,
        oob_threshold)
    std_load, std_unload = policy_math.standard_window_bounds(range_minutes)
    prewarm = jnp.where(use_hist, load_at, std_load)
    keep = jnp.where(use_hist, unload_at, std_unload) - prewarm

    ncounts_ref[...] = new_counts
    noob_ref[...] = oob
    ntotal_ref[...] = total
    ncvs_ref[...] = cvs
    ncvss_ref[...] = cvss
    prewarm_ref[...] = prewarm
    keep_ref[...] = keep
    use_hist_ref[...] = use_hist.astype(jnp.int32)


def policy_update_pallas(counts, oob, total, cv_sum, cv_sum_sq, bins, active,
                         *, head_pct=5.0, tail_pct=99.0, margin=0.10,
                         bin_minutes=1.0, range_minutes=240.0,
                         cv_threshold=2.0, min_samples=5, oob_threshold=0.5,
                         tile_apps: int = 512, interpret: bool = True):
    """Batched histogram+policy update for the whole fleet.

    counts: [n_apps, n_bins] i32; oob/total: [n_apps] i32;
    cv_sum/cv_sum_sq: [n_apps] f32; bins: [n_apps] i32 (this tick's IT bin,
    >= n_bins means OOB); active: [n_apps] i32 (0/1).
    Returns (new_counts, new_oob, new_total, new_cv_sum, new_cv_sum_sq,
             prewarm, keep_alive, use_hist).
    """
    n_apps, n_bins = counts.shape
    TA = min(tile_apps, n_apps)
    pad = (-n_apps) % TA
    if pad:
        # pad with inactive rows so the app tiling covers every app
        pv = lambda x, fill=0: jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        counts, oob, total = pv(counts), pv(oob), pv(total)
        cv_sum, cv_sum_sq = pv(cv_sum), pv(cv_sum_sq)
        bins, active = pv(bins), pv(active)
        n_apps += pad
    grid = (n_apps // TA,)
    kernel = functools.partial(
        _policy_kernel, n_bins=n_bins, head_pct=head_pct, tail_pct=tail_pct,
        margin=margin, bin_minutes=bin_minutes, range_minutes=range_minutes,
        cv_threshold=cv_threshold, min_samples=min_samples,
        oob_threshold=oob_threshold)

    vec = lambda dt: pl.BlockSpec((TA,), lambda i: (i,))
    mat = pl.BlockSpec((TA, n_bins), lambda i: (i, 0))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[mat, vec(None), vec(None), vec(None), vec(None), vec(None),
                  vec(None)],
        out_specs=[mat, vec(None), vec(None), vec(None), vec(None), vec(None),
                   vec(None), vec(None)],
        out_shape=[
            jax.ShapeDtypeStruct((n_apps, n_bins), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(counts, oob, total, cv_sum, cv_sum_sq, bins, active)
    if pad:
        outs = tuple(o[:-pad] for o in outs)
    return outs


# ---------------------------------------------------------------------------
# Fused simulator step: bin-update + CV + percentile decision + warm/cold
# accounting, one pass per scan step over the whole fleet.
# ---------------------------------------------------------------------------


def _fused_step_kernel(t_ref, prev_ref, cum_ref, oob_ref, cvs_ref, cvss_ref,
                       pre_ref, unload_ref, cold_ref, waste_ref,
                       nprev_ref, ncum_ref, noob_ref, ncvs_ref, ncvss_ref,
                       npre_ref, nunload_ref, ncold_ref, nwaste_ref, **params):
    """One hybrid-policy scan step for a tile of TA apps.

    Carries *cumulative* bin counts (``cum``) and the residency bounds
    (prewarm, unload_at). The body is exactly the single-source step in
    ``policy_math.fused_hybrid_step_math`` with the Pallas-lowerable
    ``gather=False`` lookup strategy.
    """
    out = policy_math.fused_hybrid_step_math(
        t_ref[...], prev_ref[...], cum_ref[...], oob_ref[...], cvs_ref[...],
        cvss_ref[...], pre_ref[...], unload_ref[...], cold_ref[...],
        waste_ref[...], gather=False, **params)
    (nprev_ref[...], ncum_ref[...], noob_ref[...], ncvs_ref[...],
     ncvss_ref[...], npre_ref[...], nunload_ref[...], ncold_ref[...],
     nwaste_ref[...]) = out


def fused_hybrid_step_pallas(t_now, prev_t, cum, oob, cv_sum, cv_sum_sq,
                             prewarm, unload_at, cold, waste, *,
                             head_pct=5.0, tail_pct=99.0, margin=0.10,
                             bin_minutes=1.0, range_minutes=240.0,
                             cv_threshold=2.0, min_samples=5,
                             oob_threshold=0.5, standard_keep=240.0,
                             tile_apps: int = 512, interpret: bool = True):
    """One fused hybrid-simulator scan step for the whole fleet.

    All vectors are [n_apps]; ``cum`` is [n_apps, n_bins] i32 *cumulative*
    in-bounds counts; (``prewarm``, ``unload_at``) are the residency bounds
    decided after each app's previous event. Returns the updated
    (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, unload_at, cold, waste).
    Designed to sit inside ``jax.lax.scan`` over padded event columns.
    """
    n_apps, n_bins = cum.shape
    if n_apps == 0:
        return (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
                cold, waste)
    TA = min(tile_apps, n_apps)
    pad = (-n_apps) % TA
    if pad:
        pv = lambda x, fill=0: jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        t_now = pv(t_now, jnp.inf)        # padded rows are never active
        prev_t, cum, oob = pv(prev_t), pv(cum), pv(oob)
        cv_sum, cv_sum_sq = pv(cv_sum), pv(cv_sum_sq)
        prewarm, unload_at = pv(prewarm), pv(unload_at)
        cold, waste = pv(cold), pv(waste)
        n_apps += pad
    grid = (n_apps // TA,)
    kernel = functools.partial(
        _fused_step_kernel, n_bins=n_bins, head_pct=head_pct,
        tail_pct=tail_pct, margin=margin, bin_minutes=bin_minutes,
        range_minutes=range_minutes, cv_threshold=cv_threshold,
        min_samples=min_samples, oob_threshold=oob_threshold,
        standard_keep=standard_keep)

    vec = pl.BlockSpec((TA,), lambda i: (i,))
    mat = pl.BlockSpec((TA, n_bins), lambda i: (i, 0))
    f32v = jax.ShapeDtypeStruct((n_apps,), jnp.float32)
    i32v = jax.ShapeDtypeStruct((n_apps,), jnp.int32)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec, vec, mat, vec, vec, vec, vec, vec, vec, vec],
        out_specs=[vec, mat, vec, vec, vec, vec, vec, vec, vec],
        out_shape=[
            f32v,
            jax.ShapeDtypeStruct((n_apps, n_bins), jnp.int32),
            i32v, f32v, f32v, f32v, f32v, i32v, f32v,
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(t_now, prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, unload_at, cold,
      waste)
    if pad:
        outs = tuple(o[:-pad] for o in outs)
    return outs
