"""Fleet-scale hybrid-histogram policy update — Pallas TPU kernel.

This is the paper's own hot loop, adapted TPU-natively (DESIGN.md §2). The
paper's challenges #4/#5 demand O(µs) policy updates per invocation; at
provider scale (millions of app endpoints) the control plane batches the
idle-time observations of one scheduling tick and updates *all* app
histograms plus their policy windows in a single vectorized pass:

  for each app a in tile:                      (one VMEM tile = TA apps)
    counts[a, bin(it_a)] += 1                  (or OOB counter)
    cv[a]     <- Welford O(1) update
    head/tail <- weighted 5th/99th percentile over bins
    prewarm/keepalive <- margins + representativeness fallback

Everything is rank-2 [TA, n_bins] arithmetic — ideal VPU work. The decision
formulas are NOT written here: kernel bodies call the single-source helpers
in :mod:`repro.core.policy_math` with ``gather=False`` (masked-reduction
forms — compare-against-iota instead of row gathers), which trace inside
Pallas identically to the ``lax.scan`` engines.

Two kernels:

  * :func:`policy_update_pallas` — one scheduling tick of the control
    plane (grid (n_apps / TA,), fully parallel over app tiles);
  * :func:`fused_hybrid_sweep_step_pallas` — one simulator scan step for S
    stacked policy configurations (grid (S, n_apps / TA)); the per-config
    knobs arrive as a scalar-prefetched SMEM config block, so a new grid
    point is a new SMEM row, not a recompile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core import policy_math
from . import compat


def _policy_kernel(counts_ref, oob_ref, total_ref, cvs_ref, cvss_ref,
                   bins_ref, active_ref,
                   ncounts_ref, noob_ref, ntotal_ref, ncvs_ref, ncvss_ref,
                   prewarm_ref, keep_ref, use_hist_ref, *,
                   n_bins: int, head_pct: float, tail_pct: float,
                   margin: float, bin_minutes: float, range_minutes: float,
                   cv_threshold: float, min_samples: int, oob_threshold: float):
    counts = counts_ref[...]                       # [TA, n_bins] i32
    bins = bins_ref[...]                           # [TA] i32 (bin idx; >=n_bins -> OOB)
    active = active_ref[...] != 0                  # [TA]
    TA = counts.shape[0]

    in_b = active & (bins >= 0) & (bins < n_bins)
    oob_hit = active & (bins >= n_bins)
    safe = jnp.clip(bins, 0, n_bins - 1)

    iota = jax.lax.broadcasted_iota(jnp.int32, (TA, n_bins), 1)
    onehot = (iota == safe[:, None]) & in_b[:, None]
    old = jnp.sum(jnp.where(onehot, counts, 0), axis=1)          # [TA]
    new_counts = counts + onehot.astype(jnp.int32)

    total = total_ref[...] + in_b.astype(jnp.int32)
    oob = oob_ref[...] + oob_hit.astype(jnp.int32)
    cvs, cvss = policy_math.welford_update(cvs_ref[...], cvss_ref[...],
                                           in_b, old)

    cum = jnp.cumsum(new_counts, axis=1)                          # [TA, n_bins]
    head_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(total, head_pct),
        gather=False)
    tail_bin = policy_math.first_bin_ge_scaled(
        cum, policy_math.percentile_threshold_scaled(total, tail_pct),
        gather=False) + 1
    load_at, unload_at = policy_math.window_values(
        head_bin, tail_bin, bin_minutes, range_minutes, margin)
    use_hist = policy_math.use_histogram_gate(
        total, oob, cvs, cvss, n_bins, min_samples, cv_threshold,
        oob_threshold)
    std_load, std_unload = policy_math.standard_window_bounds(range_minutes)
    prewarm = jnp.where(use_hist, load_at, std_load)
    keep = jnp.where(use_hist, unload_at, std_unload) - prewarm

    ncounts_ref[...] = new_counts
    noob_ref[...] = oob
    ntotal_ref[...] = total
    ncvs_ref[...] = cvs
    ncvss_ref[...] = cvss
    prewarm_ref[...] = prewarm
    keep_ref[...] = keep
    use_hist_ref[...] = use_hist.astype(jnp.int32)


def policy_update_pallas(counts, oob, total, cv_sum, cv_sum_sq, bins, active,
                         *, head_pct=5.0, tail_pct=99.0, margin=0.10,
                         bin_minutes=1.0, range_minutes=240.0,
                         cv_threshold=2.0, min_samples=5, oob_threshold=0.5,
                         tile_apps: int = 512, interpret: bool = True):
    """Batched histogram+policy update for the whole fleet.

    counts: [n_apps, n_bins] i32; oob/total: [n_apps] i32;
    cv_sum/cv_sum_sq: [n_apps] f32; bins: [n_apps] i32 (this tick's IT bin,
    >= n_bins means OOB); active: [n_apps] i32 (0/1).
    Returns (new_counts, new_oob, new_total, new_cv_sum, new_cv_sum_sq,
             prewarm, keep_alive, use_hist).
    """
    n_apps, n_bins = counts.shape
    TA = min(tile_apps, n_apps)
    pad = (-n_apps) % TA
    if pad:
        # pad with inactive rows so the app tiling covers every app
        pv = lambda x, fill=0: jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        counts, oob, total = pv(counts), pv(oob), pv(total)
        cv_sum, cv_sum_sq = pv(cv_sum), pv(cv_sum_sq)
        bins, active = pv(bins), pv(active)
        n_apps += pad
    grid = (n_apps // TA,)
    kernel = functools.partial(
        _policy_kernel, n_bins=n_bins, head_pct=head_pct, tail_pct=tail_pct,
        margin=margin, bin_minutes=bin_minutes, range_minutes=range_minutes,
        cv_threshold=cv_threshold, min_samples=min_samples,
        oob_threshold=oob_threshold)

    vec = lambda dt: pl.BlockSpec((TA,), lambda i: (i,))
    mat = pl.BlockSpec((TA, n_bins), lambda i: (i, 0))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[mat, vec(None), vec(None), vec(None), vec(None), vec(None),
                  vec(None)],
        out_specs=[mat, vec(None), vec(None), vec(None), vec(None), vec(None),
                   vec(None), vec(None)],
        out_shape=[
            jax.ShapeDtypeStruct((n_apps, n_bins), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(counts, oob, total, cv_sum, cv_sum_sq, bins, active)
    if pad:
        outs = tuple(o[:-pad] for o in outs)
    return outs


# ---------------------------------------------------------------------------
# Fused simulator sweep step: bin-update + CV + percentile decision +
# warm/cold accounting for S stacked policy configurations, one pass per
# scan step over the whole fleet. The per-config knobs ride in SMEM as a
# scalar-prefetched config block — adding a grid point changes data, not
# the kernel.
# ---------------------------------------------------------------------------

# Column layout of the scalar-prefetched config blocks (see
# ``repro.core.simulator._build_pallas_cfg``).
CFG_I32_COLS = ("n_bins", "head_numer", "tail_numer", "min_samples")
CFG_F32_COLS = ("margin_lo", "margin_hi", "bin_minutes", "range_f32",
                "cv_threshold", "oob_threshold", "standard_keep")


def _sweep_step_kernel(cfg_i_ref, cfg_f_ref, t_ref, prev_ref, cum_ref,
                       oob_ref, cvs_ref, cvss_ref, pre_ref, unload_ref,
                       cold_ref, waste_ref,
                       nprev_ref, ncum_ref, noob_ref, ncvs_ref, ncvss_ref,
                       npre_ref, nunload_ref, ncold_ref, nwaste_ref):
    """One hybrid-policy scan step for (config s, tile of TA apps).

    ``cfg_i_ref``/``cfg_f_ref`` are the scalar-prefetched [S, k] config
    blocks living in SMEM; program_id(0) selects this instance's row. The
    body is exactly the single-source step in
    ``policy_math.fused_hybrid_step_math`` with the Pallas-lowerable
    ``gather=False`` lookup strategy and *traced* config scalars.
    """
    s = pl.program_id(0)
    cfg = policy_math.HybridStepConfig(
        n_bins=cfg_i_ref[s, 0], head_numer=cfg_i_ref[s, 1],
        tail_numer=cfg_i_ref[s, 2], min_samples=cfg_i_ref[s, 3],
        margin_lo=cfg_f_ref[s, 0], margin_hi=cfg_f_ref[s, 1],
        bin_minutes=cfg_f_ref[s, 2], bin_f32=cfg_f_ref[s, 2],
        range_f32=cfg_f_ref[s, 3], cv_threshold=cfg_f_ref[s, 4],
        oob_threshold=cfg_f_ref[s, 5], standard_keep=cfg_f_ref[s, 6])
    out = policy_math.fused_hybrid_step_math(
        t_ref[...], prev_ref[0], cum_ref[0], oob_ref[0], cvs_ref[0],
        cvss_ref[0], pre_ref[0], unload_ref[0], cold_ref[0], waste_ref[0],
        cfg=cfg, gather=False)
    (nprev_ref[0], ncum_ref[0], noob_ref[0], ncvs_ref[0], ncvss_ref[0],
     npre_ref[0], nunload_ref[0], ncold_ref[0], nwaste_ref[0]) = out


def fused_hybrid_sweep_step_pallas(t_now, prev_t, cum, oob, cv_sum,
                                   cv_sum_sq, prewarm, unload_at, cold,
                                   waste, cfg_i32, cfg_f32, *,
                                   tile_apps: int = 512,
                                   interpret: bool = True):
    """One fused hybrid-simulator scan step for S configs x the whole fleet.

    ``t_now`` is [n_apps] (the trace column, shared by every config);
    per-config state is stacked [S, n_apps] (``cum`` is [S, n_apps, n_bins]
    i32 *cumulative* in-bounds counts; (``prewarm``, ``unload_at``) are the
    residency bounds decided after each app's previous event). ``cfg_i32``
    [S, 4] / ``cfg_f32`` [S, 7] are the per-config knob blocks (column
    layout ``CFG_I32_COLS``/``CFG_F32_COLS``), delivered to SMEM via scalar
    prefetch. Grid: (S, n_apps / TA) — fully parallel. Returns the updated
    (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, unload_at, cold, waste).
    Designed to sit inside ``jax.lax.scan`` over padded event columns.
    """
    S, n_apps, n_bins = cum.shape
    if n_apps == 0 or S == 0:
        return (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
                cold, waste)
    TA = min(tile_apps, n_apps)
    pad = (-n_apps) % TA
    if pad:
        pv = lambda x, fill=0: jnp.concatenate(
            [x, jnp.full(x.shape[:-1] + (pad,), fill, x.dtype)], axis=-1)
        t_now = pv(t_now, jnp.inf)        # padded rows are never active
        prev_t = pv(prev_t)
        cum = jnp.concatenate(
            [cum, jnp.zeros((S, pad, n_bins), cum.dtype)], axis=1)
        oob, cv_sum, cv_sum_sq = pv(oob), pv(cv_sum), pv(cv_sum_sq)
        prewarm, unload_at = pv(prewarm), pv(unload_at)
        cold, waste = pv(cold), pv(waste)
        n_apps += pad
    grid = (S, n_apps // TA)

    tvec = pl.BlockSpec((TA,), lambda s, i, *refs: (i,))
    vec = pl.BlockSpec((1, TA), lambda s, i, *refs: (s, i))
    mat = pl.BlockSpec((1, TA, n_bins), lambda s, i, *refs: (s, i, 0))
    f32v = jax.ShapeDtypeStruct((S, n_apps), jnp.float32)
    i32v = jax.ShapeDtypeStruct((S, n_apps), jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[tvec, vec, mat, vec, vec, vec, vec, vec, vec, vec],
        out_specs=[vec, mat, vec, vec, vec, vec, vec, vec, vec],
    )
    outs = pl.pallas_call(
        _sweep_step_kernel,
        grid_spec=grid_spec,
        out_shape=[
            f32v,
            jax.ShapeDtypeStruct((S, n_apps, n_bins), jnp.int32),
            i32v, f32v, f32v, f32v, f32v, i32v, f32v,
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(cfg_i32, cfg_f32, t_now, prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm,
      unload_at, cold, waste)
    if pad:
        outs = tuple(o[:, :-pad] if o.ndim == 2 else o[:, :-pad, :]
                     for o in outs)
    return outs


def fused_hybrid_step_pallas(t_now, prev_t, cum, oob, cv_sum, cv_sum_sq,
                             prewarm, unload_at, cold, waste, *,
                             head_pct=5.0, tail_pct=99.0, margin=0.10,
                             bin_minutes=1.0, range_minutes=240.0,
                             cv_threshold=2.0, min_samples=5,
                             oob_threshold=0.5, standard_keep=240.0,
                             tile_apps: int = 512, interpret: bool = True):
    """Single-config fused scan step: the S=1 slice of the sweep kernel.

    Kept as the scalar-parity/benchmark surface (``ops.fused_hybrid_step``);
    the knobs are packed into a one-row SMEM config block exactly as the
    sweep driver would (``HybridStepConfig.from_host`` owns the rounding).
    """
    n_apps, n_bins = cum.shape
    if n_apps == 0:
        return (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
                cold, waste)
    c = policy_math.HybridStepConfig.from_host(
        n_bins=n_bins, head_pct=head_pct, tail_pct=tail_pct, margin=margin,
        bin_minutes=bin_minutes, range_minutes=range_minutes,
        cv_threshold=cv_threshold, min_samples=min_samples,
        oob_threshold=oob_threshold, standard_keep=standard_keep)
    cfg_i32 = jnp.asarray(
        [[c.n_bins, c.head_numer, c.tail_numer, c.min_samples]], jnp.int32)
    cfg_f32 = jnp.asarray(
        [[c.margin_lo, c.margin_hi, c.bin_f32, c.range_f32, c.cv_threshold,
          c.oob_threshold, c.standard_keep]], jnp.float32)
    outs = fused_hybrid_sweep_step_pallas(
        t_now, prev_t[None], cum[None], oob[None], cv_sum[None],
        cv_sum_sq[None], prewarm[None], unload_at[None], cold[None],
        waste[None], cfg_i32, cfg_f32, tile_apps=tile_apps,
        interpret=interpret)
    return tuple(o[0] for o in outs)
