"""Fleet-scale hybrid-histogram policy update — Pallas TPU kernel.

This is the paper's own hot loop, adapted TPU-natively (DESIGN.md §2). The
paper's challenges #4/#5 demand O(µs) policy updates per invocation; at
provider scale (millions of app endpoints) the control plane batches the
idle-time observations of one scheduling tick and updates *all* app
histograms plus their policy windows in a single vectorized pass:

  for each app a in tile:                      (one VMEM tile = TA apps)
    counts[a, bin(it_a)] += 1                  (or OOB counter)
    cv[a]     <- Welford O(1) update
    head/tail <- weighted 5th/99th percentile over bins (one cumsum sweep)
    prewarm/keepalive <- margins + representativeness fallback

Everything is rank-2 [TA, n_bins] arithmetic — ideal VPU work; the bin
update is a one-hot add (compare-against-iota), the percentile extraction a
cumsum + masked min over the bin iota.

Grid: (n_apps / TA,) — fully parallel over app tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

BIG = 10 ** 9


def _policy_kernel(counts_ref, oob_ref, total_ref, cvs_ref, cvss_ref,
                   bins_ref, active_ref,
                   ncounts_ref, noob_ref, ntotal_ref, ncvs_ref, ncvss_ref,
                   prewarm_ref, keep_ref, use_hist_ref, *,
                   n_bins: int, head_pct: float, tail_pct: float,
                   margin: float, bin_minutes: float, range_minutes: float,
                   cv_threshold: float, min_samples: int, oob_threshold: float):
    counts = counts_ref[...]                       # [TA, n_bins] i32
    bins = bins_ref[...]                           # [TA] i32 (bin idx; >=n_bins -> OOB)
    active = active_ref[...] != 0                  # [TA]
    TA = counts.shape[0]

    in_b = active & (bins >= 0) & (bins < n_bins)
    oob_hit = active & (bins >= n_bins)
    safe = jnp.clip(bins, 0, n_bins - 1)

    iota = jax.lax.broadcasted_iota(jnp.int32, (TA, n_bins), 1)
    onehot = (iota == safe[:, None]) & in_b[:, None]
    old = jnp.sum(jnp.where(onehot, counts, 0), axis=1)          # [TA]
    new_counts = counts + onehot.astype(jnp.int32)

    total = total_ref[...] + in_b.astype(jnp.int32)
    oob = oob_ref[...] + oob_hit.astype(jnp.int32)
    inb_f = in_b.astype(jnp.float32)
    cvs = cvs_ref[...] + inb_f                                    # Welford sums
    cvss = cvss_ref[...] + inb_f * (2.0 * old.astype(jnp.float32) + 1.0)

    # CV of bin counts (representativeness check)
    mean = cvs / n_bins
    var = jnp.maximum(cvss / n_bins - mean * mean, 0.0)
    cv = jnp.where(mean > 0, jnp.sqrt(var) / jnp.maximum(mean, 1e-9), 0.0)

    # weighted percentiles: one cumsum over bins, masked min over iota
    cum = jnp.cumsum(new_counts, axis=1)                          # [TA, n_bins]
    tot_f = jnp.maximum(total, 1).astype(jnp.float32)
    head_thr = jnp.maximum(jnp.ceil(tot_f * (head_pct / 100.0)), 1.0)
    tail_thr = jnp.maximum(jnp.ceil(tot_f * (tail_pct / 100.0)), 1.0)
    cum_f = cum.astype(jnp.float32)
    head_bin = jnp.min(jnp.where(cum_f >= head_thr[:, None], iota, BIG), axis=1)
    tail_bin = jnp.min(jnp.where(cum_f >= tail_thr[:, None], iota, BIG), axis=1) + 1
    head_bin = jnp.where(head_bin == BIG, 0, head_bin)
    tail_bin = jnp.where(tail_bin == BIG + 1, n_bins, tail_bin)

    prewarm = head_bin.astype(jnp.float32) * bin_minutes * (1.0 - margin)
    tail = jnp.minimum(tail_bin.astype(jnp.float32) * bin_minutes,
                       range_minutes) * (1.0 + margin)
    keep = jnp.maximum(tail - prewarm, 0.0)

    seen = total + oob
    use_hist = ((seen >= min_samples) & (cv >= cv_threshold) & (total > 0)
                & ~(oob.astype(jnp.float32) > oob_threshold
                    * jnp.maximum(seen, 1).astype(jnp.float32)))
    prewarm = jnp.where(use_hist, prewarm, 0.0)
    keep = jnp.where(use_hist, keep, range_minutes)

    ncounts_ref[...] = new_counts
    noob_ref[...] = oob
    ntotal_ref[...] = total
    ncvs_ref[...] = cvs
    ncvss_ref[...] = cvss
    prewarm_ref[...] = prewarm
    keep_ref[...] = keep
    use_hist_ref[...] = use_hist.astype(jnp.int32)


def policy_update_pallas(counts, oob, total, cv_sum, cv_sum_sq, bins, active,
                         *, head_pct=5.0, tail_pct=99.0, margin=0.10,
                         bin_minutes=1.0, range_minutes=240.0,
                         cv_threshold=2.0, min_samples=5, oob_threshold=0.5,
                         tile_apps: int = 512, interpret: bool = True):
    """Batched histogram+policy update for the whole fleet.

    counts: [n_apps, n_bins] i32; oob/total: [n_apps] i32;
    cv_sum/cv_sum_sq: [n_apps] f32; bins: [n_apps] i32 (this tick's IT bin,
    >= n_bins means OOB); active: [n_apps] i32 (0/1).
    Returns (new_counts, new_oob, new_total, new_cv_sum, new_cv_sum_sq,
             prewarm, keep_alive, use_hist).
    """
    n_apps, n_bins = counts.shape
    TA = min(tile_apps, n_apps)
    pad = (-n_apps) % TA
    if pad:
        # pad with inactive rows so the app tiling covers every app
        pv = lambda x, fill=0: jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        counts, oob, total = pv(counts), pv(oob), pv(total)
        cv_sum, cv_sum_sq = pv(cv_sum), pv(cv_sum_sq)
        bins, active = pv(bins), pv(active)
        n_apps += pad
    grid = (n_apps // TA,)
    kernel = functools.partial(
        _policy_kernel, n_bins=n_bins, head_pct=head_pct, tail_pct=tail_pct,
        margin=margin, bin_minutes=bin_minutes, range_minutes=range_minutes,
        cv_threshold=cv_threshold, min_samples=min_samples,
        oob_threshold=oob_threshold)

    vec = lambda dt: pl.BlockSpec((TA,), lambda i: (i,))
    mat = pl.BlockSpec((TA, n_bins), lambda i: (i, 0))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[mat, vec(None), vec(None), vec(None), vec(None), vec(None),
                  vec(None)],
        out_specs=[mat, vec(None), vec(None), vec(None), vec(None), vec(None),
                   vec(None), vec(None)],
        out_shape=[
            jax.ShapeDtypeStruct((n_apps, n_bins), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(counts, oob, total, cv_sum, cv_sum_sq, bins, active)
    if pad:
        outs = tuple(o[:-pad] for o in outs)
    return outs


# ---------------------------------------------------------------------------
# Fused simulator step: bin-update + CV + percentile decision + warm/cold
# accounting, one pass per scan step over the whole fleet.
# ---------------------------------------------------------------------------


def _fused_step_kernel(t_ref, prev_ref, cum_ref, oob_ref, cvs_ref, cvss_ref,
                       pre_ref, keep_ref, cold_ref, waste_ref,
                       nprev_ref, ncum_ref, noob_ref, ncvs_ref, ncvss_ref,
                       npre_ref, nkeep_ref, ncold_ref, nwaste_ref, *,
                       n_bins: int, head_pct: float, tail_pct: float,
                       margin: float, bin_minutes: float, range_minutes: float,
                       cv_threshold: float, min_samples: int,
                       oob_threshold: float, standard_keep: float):
    """One hybrid-policy scan step for a tile of TA apps.

    Carries *cumulative* bin counts (``cum``) instead of raw counts: the
    per-event update is a suffix add, so no per-step cumsum recompute is
    needed for the percentile windows — the event-dependent work replaces
    the fleet-wide O(n_bins) prefix scan of the legacy engine.
    """
    t_now = t_ref[...]
    prev_t = prev_ref[...]
    cum = cum_ref[...]                              # [TA, n_bins] i32
    prewarm = pre_ref[...]
    keep = keep_ref[...]
    TA = cum.shape[0]

    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    # Warm/cold + waste under the windows decided after the previous event.
    warm = jnp.where(prewarm <= 0.0, it <= keep,
                     (it >= prewarm) & (it <= prewarm + keep))
    is_cold = valid & (first | ~warm)
    gap_w_nopre = jnp.minimum(it, keep)
    gap_w_pre = jnp.where(it < prewarm, 0.0,
                          jnp.minimum(it, prewarm + keep) - prewarm)
    gap_waste = jnp.where(valid & ~first,
                          jnp.where(prewarm <= 0.0, gap_w_nopre, gap_w_pre),
                          0.0)

    # Histogram bin update on the cumulative representation.
    rec = valid & ~first
    bin_idx = jnp.floor(it / bin_minutes).astype(jnp.int32)
    in_b = rec & (bin_idx >= 0) & (bin_idx < n_bins)
    oob_hit = rec & (bin_idx >= n_bins)
    safe = jnp.clip(bin_idx, 0, n_bins - 1)

    iota = jax.lax.broadcasted_iota(jnp.int32, (TA, n_bins), 1)
    at_mask = iota == safe[:, None]
    cum_at = jnp.sum(jnp.where(at_mask, cum, 0), axis=1)
    cum_below = jnp.sum(jnp.where(iota == (safe - 1)[:, None], cum, 0), axis=1)
    old = cum_at - cum_below                        # pre-update count at bin
    new_cum = cum + ((iota >= safe[:, None]) & in_b[:, None]).astype(jnp.int32)

    total = jnp.max(new_cum, axis=1)                # == new_cum[:, -1]
    oob = oob_ref[...] + oob_hit.astype(jnp.int32)
    inb_f = in_b.astype(jnp.float32)
    cvs = cvs_ref[...] + inb_f
    cvss = cvss_ref[...] + inb_f * (2.0 * old.astype(jnp.float32) + 1.0)

    # Representativeness (CV of bin counts).
    mean = cvs / n_bins
    var = jnp.maximum(cvss / n_bins - mean * mean, 0.0)
    cv = jnp.where(mean > 0, jnp.sqrt(var) / jnp.maximum(mean, 1e-9), 0.0)

    # Head/tail percentile windows straight off the maintained cumulative
    # counts: masked min over the bin iota, no cumsum.
    tot_f = total.astype(jnp.float32)
    head_thr = jnp.maximum(jnp.ceil(tot_f * (head_pct / 100.0)), 1.0)
    tail_thr = jnp.maximum(jnp.ceil(tot_f * (tail_pct / 100.0)), 1.0)
    cum_f = new_cum.astype(jnp.float32)
    head_bin = jnp.min(jnp.where(cum_f >= head_thr[:, None], iota, BIG), axis=1)
    tail_bin = jnp.min(jnp.where(cum_f >= tail_thr[:, None], iota, BIG), axis=1) + 1
    head_bin = jnp.where(head_bin == BIG, 0, head_bin)
    tail_bin = jnp.where(tail_bin == BIG + 1, n_bins, tail_bin)

    new_pre = head_bin.astype(jnp.float32) * bin_minutes * (1.0 - margin)
    tail = jnp.minimum(tail_bin.astype(jnp.float32) * bin_minutes,
                       range_minutes) * (1.0 + margin)
    new_keep = jnp.maximum(tail - new_pre, 0.0)

    seen = total + oob
    use_hist = ((seen >= min_samples) & (cv >= cv_threshold) & (total > 0)
                & ~(oob.astype(jnp.float32) > oob_threshold
                    * jnp.maximum(seen, 1).astype(jnp.float32)))
    new_pre = jnp.where(use_hist, new_pre, 0.0)
    new_keep = jnp.where(use_hist, new_keep, standard_keep)

    nprev_ref[...] = jnp.where(valid, t_now, prev_t)
    ncum_ref[...] = new_cum
    noob_ref[...] = oob
    ncvs_ref[...] = cvs
    ncvss_ref[...] = cvss
    npre_ref[...] = jnp.where(valid, new_pre, prewarm)
    nkeep_ref[...] = jnp.where(valid, new_keep, keep)
    ncold_ref[...] = cold_ref[...] + is_cold.astype(jnp.int32)
    nwaste_ref[...] = waste_ref[...] + gap_waste


def fused_hybrid_step_pallas(t_now, prev_t, cum, oob, cv_sum, cv_sum_sq,
                             prewarm, keep, cold, waste, *,
                             head_pct=5.0, tail_pct=99.0, margin=0.10,
                             bin_minutes=1.0, range_minutes=240.0,
                             cv_threshold=2.0, min_samples=5,
                             oob_threshold=0.5, standard_keep=240.0,
                             tile_apps: int = 512, interpret: bool = True):
    """One fused hybrid-simulator scan step for the whole fleet.

    All vectors are [n_apps]; ``cum`` is [n_apps, n_bins] i32 *cumulative*
    in-bounds counts. Returns the updated
    (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, keep, cold, waste).
    Designed to sit inside ``jax.lax.scan`` over padded event columns.
    """
    n_apps, n_bins = cum.shape
    if n_apps == 0:
        return (prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, keep, cold,
                waste)
    TA = min(tile_apps, n_apps)
    pad = (-n_apps) % TA
    if pad:
        pv = lambda x, fill=0: jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        t_now = pv(t_now, jnp.inf)        # padded rows are never active
        prev_t, cum, oob = pv(prev_t), pv(cum), pv(oob)
        cv_sum, cv_sum_sq = pv(cv_sum), pv(cv_sum_sq)
        prewarm, keep = pv(prewarm), pv(keep)
        cold, waste = pv(cold), pv(waste)
        n_apps += pad
    grid = (n_apps // TA,)
    kernel = functools.partial(
        _fused_step_kernel, n_bins=n_bins, head_pct=head_pct,
        tail_pct=tail_pct, margin=margin, bin_minutes=bin_minutes,
        range_minutes=range_minutes, cv_threshold=cv_threshold,
        min_samples=min_samples, oob_threshold=oob_threshold,
        standard_keep=standard_keep)

    vec = pl.BlockSpec((TA,), lambda i: (i,))
    mat = pl.BlockSpec((TA, n_bins), lambda i: (i, 0))
    f32v = jax.ShapeDtypeStruct((n_apps,), jnp.float32)
    i32v = jax.ShapeDtypeStruct((n_apps,), jnp.int32)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[vec, vec, mat, vec, vec, vec, vec, vec, vec, vec],
        out_specs=[vec, mat, vec, vec, vec, vec, vec, vec, vec],
        out_shape=[
            f32v,
            jax.ShapeDtypeStruct((n_apps, n_bins), jnp.int32),
            i32v, f32v, f32v, f32v, f32v, i32v, f32v,
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(t_now, prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm, keep, cold, waste)
    if pad:
        outs = tuple(o[:-pad] for o in outs)
    return outs
