"""Fleet-scale hybrid-histogram policy update — Pallas TPU kernel.

This is the paper's own hot loop, adapted TPU-natively (DESIGN.md §2). The
paper's challenges #4/#5 demand O(µs) policy updates per invocation; at
provider scale (millions of app endpoints) the control plane batches the
idle-time observations of one scheduling tick and updates *all* app
histograms plus their policy windows in a single vectorized pass:

  for each app a in tile:                      (one VMEM tile = TA apps)
    counts[a, bin(it_a)] += 1                  (or OOB counter)
    cv[a]     <- Welford O(1) update
    head/tail <- weighted 5th/99th percentile over bins (one cumsum sweep)
    prewarm/keepalive <- margins + representativeness fallback

Everything is rank-2 [TA, n_bins] arithmetic — ideal VPU work; the bin
update is a one-hot add (compare-against-iota), the percentile extraction a
cumsum + masked min over the bin iota.

Grid: (n_apps / TA,) — fully parallel over app tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BIG = 10 ** 9


def _policy_kernel(counts_ref, oob_ref, total_ref, cvs_ref, cvss_ref,
                   bins_ref, active_ref,
                   ncounts_ref, noob_ref, ntotal_ref, ncvs_ref, ncvss_ref,
                   prewarm_ref, keep_ref, use_hist_ref, *,
                   n_bins: int, head_pct: float, tail_pct: float,
                   margin: float, bin_minutes: float, range_minutes: float,
                   cv_threshold: float, min_samples: int, oob_threshold: float):
    counts = counts_ref[...]                       # [TA, n_bins] i32
    bins = bins_ref[...]                           # [TA] i32 (bin idx; >=n_bins -> OOB)
    active = active_ref[...] != 0                  # [TA]
    TA = counts.shape[0]

    in_b = active & (bins >= 0) & (bins < n_bins)
    oob_hit = active & (bins >= n_bins)
    safe = jnp.clip(bins, 0, n_bins - 1)

    iota = jax.lax.broadcasted_iota(jnp.int32, (TA, n_bins), 1)
    onehot = (iota == safe[:, None]) & in_b[:, None]
    old = jnp.sum(jnp.where(onehot, counts, 0), axis=1)          # [TA]
    new_counts = counts + onehot.astype(jnp.int32)

    total = total_ref[...] + in_b.astype(jnp.int32)
    oob = oob_ref[...] + oob_hit.astype(jnp.int32)
    inb_f = in_b.astype(jnp.float32)
    cvs = cvs_ref[...] + inb_f                                    # Welford sums
    cvss = cvss_ref[...] + inb_f * (2.0 * old.astype(jnp.float32) + 1.0)

    # CV of bin counts (representativeness check)
    mean = cvs / n_bins
    var = jnp.maximum(cvss / n_bins - mean * mean, 0.0)
    cv = jnp.where(mean > 0, jnp.sqrt(var) / jnp.maximum(mean, 1e-9), 0.0)

    # weighted percentiles: one cumsum over bins, masked min over iota
    cum = jnp.cumsum(new_counts, axis=1)                          # [TA, n_bins]
    tot_f = jnp.maximum(total, 1).astype(jnp.float32)
    head_thr = jnp.maximum(jnp.ceil(tot_f * (head_pct / 100.0)), 1.0)
    tail_thr = jnp.maximum(jnp.ceil(tot_f * (tail_pct / 100.0)), 1.0)
    cum_f = cum.astype(jnp.float32)
    head_bin = jnp.min(jnp.where(cum_f >= head_thr[:, None], iota, BIG), axis=1)
    tail_bin = jnp.min(jnp.where(cum_f >= tail_thr[:, None], iota, BIG), axis=1) + 1
    head_bin = jnp.where(head_bin == BIG, 0, head_bin)
    tail_bin = jnp.where(tail_bin == BIG + 1, n_bins, tail_bin)

    prewarm = head_bin.astype(jnp.float32) * bin_minutes * (1.0 - margin)
    tail = jnp.minimum(tail_bin.astype(jnp.float32) * bin_minutes,
                       range_minutes) * (1.0 + margin)
    keep = jnp.maximum(tail - prewarm, 0.0)

    seen = total + oob
    use_hist = ((seen >= min_samples) & (cv >= cv_threshold) & (total > 0)
                & ~(oob.astype(jnp.float32) > oob_threshold
                    * jnp.maximum(seen, 1).astype(jnp.float32)))
    prewarm = jnp.where(use_hist, prewarm, 0.0)
    keep = jnp.where(use_hist, keep, range_minutes)

    ncounts_ref[...] = new_counts
    noob_ref[...] = oob
    ntotal_ref[...] = total
    ncvs_ref[...] = cvs
    ncvss_ref[...] = cvss
    prewarm_ref[...] = prewarm
    keep_ref[...] = keep
    use_hist_ref[...] = use_hist.astype(jnp.int32)


def policy_update_pallas(counts, oob, total, cv_sum, cv_sum_sq, bins, active,
                         *, head_pct=5.0, tail_pct=99.0, margin=0.10,
                         bin_minutes=1.0, range_minutes=240.0,
                         cv_threshold=2.0, min_samples=5, oob_threshold=0.5,
                         tile_apps: int = 512, interpret: bool = True):
    """Batched histogram+policy update for the whole fleet.

    counts: [n_apps, n_bins] i32; oob/total: [n_apps] i32;
    cv_sum/cv_sum_sq: [n_apps] f32; bins: [n_apps] i32 (this tick's IT bin,
    >= n_bins means OOB); active: [n_apps] i32 (0/1).
    Returns (new_counts, new_oob, new_total, new_cv_sum, new_cv_sum_sq,
             prewarm, keep_alive, use_hist).
    """
    n_apps, n_bins = counts.shape
    TA = min(tile_apps, n_apps)
    pad = (-n_apps) % TA
    if pad:
        # pad with inactive rows so the app tiling covers every app
        pv = lambda x, fill=0: jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        counts, oob, total = pv(counts), pv(oob), pv(total)
        cv_sum, cv_sum_sq = pv(cv_sum), pv(cv_sum_sq)
        bins, active = pv(bins), pv(active)
        n_apps += pad
    grid = (n_apps // TA,)
    kernel = functools.partial(
        _policy_kernel, n_bins=n_bins, head_pct=head_pct, tail_pct=tail_pct,
        margin=margin, bin_minutes=bin_minutes, range_minutes=range_minutes,
        cv_threshold=cv_threshold, min_samples=min_samples,
        oob_threshold=oob_threshold)

    vec = lambda dt: pl.BlockSpec((TA,), lambda i: (i,))
    mat = pl.BlockSpec((TA, n_bins), lambda i: (i, 0))
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[mat, vec(None), vec(None), vec(None), vec(None), vec(None),
                  vec(None)],
        out_specs=[mat, vec(None), vec(None), vec(None), vec(None), vec(None),
                   vec(None), vec(None)],
        out_shape=[
            jax.ShapeDtypeStruct((n_apps, n_bins), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.float32),
            jax.ShapeDtypeStruct((n_apps,), jnp.int32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(counts, oob, total, cv_sum, cv_sum_sq, bins, active)
    if pad:
        outs = tuple(o[:-pad] for o in outs)
    return outs
