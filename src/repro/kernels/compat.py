"""Version compatibility helpers for the Pallas TPU kernels.

``pltpu.CompilerParams`` was called ``pltpu.TPUCompilerParams`` in older JAX
releases (<= 0.4.x). Every kernel goes through :func:`compiler_params` so the
package imports and runs on both spellings.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None)
if _COMPILER_PARAMS_CLS is None:  # pragma: no cover - depends on jax version
    _COMPILER_PARAMS_CLS = getattr(pltpu, "TPUCompilerParams")


def compiler_params(**kwargs):
    """Build TPU compiler params under either pltpu spelling."""
    return _COMPILER_PARAMS_CLS(**kwargs)
