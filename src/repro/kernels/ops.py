"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in ``interpret=True`` mode; on a
real TPU set ``repro.kernels.ops.INTERPRET = False`` (the launcher does this
automatically based on the backend).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .decode_attention import flash_decode
from .flash_attention import flash_attention_bhsd
from .histogram import fused_hybrid_step_pallas, policy_update_pallas
from .rglru_scan import rglru_scan_pallas
from .ssd_scan import ssd_scan_pallas

INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 512):
    """q: [B,S,Hq,D]; k,v: [B,S,Hkv,D] (model layout) -> [B,S,Hq,D]."""
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=INTERPRET)
    return jnp.moveaxis(out, 1, 2)


@partial(jax.jit, static_argnames=("bk",))
def decode_attention(q, k, v, kv_len, *, bk: int = 512):
    """q: [B,1,Hq,D]; k,v caches: [B,Skv,Hkv,D]; kv_len scalar.

    Returns [B,1,Hq,D].
    """
    B, _, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    qg = q[:, 0].reshape(B, Hkv, group, D)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    out = flash_decode(qg, kt, vt, kv_len, bk=bk, interpret=INTERPRET)
    return out.reshape(B, 1, Hq, D)


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 256):
    return ssd_scan_pallas(x, dt, A, B, C, chunk=chunk, interpret=INTERPRET)


@partial(jax.jit, static_argnames=("block_t", "block_d"))
def rglru_scan(b_in, a, *, block_t: int = 256, block_d: int = 512):
    return rglru_scan_pallas(b_in, a, block_t=block_t, block_d=block_d,
                             interpret=INTERPRET)


@partial(jax.jit, static_argnames=("head_pct", "tail_pct", "margin",
                                   "bin_minutes", "range_minutes",
                                   "cv_threshold", "min_samples",
                                   "oob_threshold", "tile_apps"))
def policy_update(counts, oob, total, cv_sum, cv_sum_sq, bins, active, **kw):
    return policy_update_pallas(counts, oob, total, cv_sum, cv_sum_sq, bins,
                                active, interpret=INTERPRET, **kw)


@partial(jax.jit, static_argnames=("head_pct", "tail_pct", "margin",
                                   "bin_minutes", "range_minutes",
                                   "cv_threshold", "min_samples",
                                   "oob_threshold", "standard_keep",
                                   "tile_apps"))
def fused_hybrid_step(t_now, prev_t, cum, oob, cv_sum, cv_sum_sq, prewarm,
                      unload_at, cold, waste, **kw):
    """Fused simulator step (see kernels.histogram.fused_hybrid_step_pallas)."""
    return fused_hybrid_step_pallas(t_now, prev_t, cum, oob, cv_sum,
                                    cv_sum_sq, prewarm, unload_at, cold,
                                    waste, interpret=INTERPRET, **kw)
