"""RG-LRU linear-recurrence Pallas TPU kernel.

Computes ``h_t = a_t * h_{t-1} + b_t`` over the sequence. Within a VMEM
block of T timesteps the inclusive scan is evaluated with a Hillis–Steele
log-step doubling over the (a, b) semigroup — log2(T) fully vectorized VPU
sweeps instead of a T-step serial loop; the block-boundary state is carried
in scratch across the sequential grid dimension.

Grid: (B, nd, nt) — nt (time blocks) innermost/sequential; nd tiles the
feature dimension so wide recurrences (d_rnn = 2560) stay VMEM-resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat


def _scan_block(a, b):
    """Inclusive scan of the recurrence semigroup over axis 0. a,b: [T, D]."""
    T = a.shape[0]
    s = 1
    while s < T:
        a_sh = jnp.concatenate([jnp.ones_like(a[:s]), a[:-s]], axis=0)
        b_sh = jnp.concatenate([jnp.zeros_like(b[:s]), b[:-s]], axis=0)
        live = (jax.lax.broadcasted_iota(jnp.int32, a.shape, 0) >= s)
        b = jnp.where(live, a * b_sh + b, b)
        a = jnp.where(live, a * a_sh, a)
        s *= 2
    return a, b


def _rglru_kernel(b_ref, a_ref, h_ref, hlast_ref, carry_ref, *, T: int,
                  nt: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0].astype(jnp.float32)                   # [T, D]
    b = b_ref[0].astype(jnp.float32)                   # [T, D] gated input
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * b    # RG-LRU normalization
    h0 = carry_ref[...]                                # [1, D]
    b = b.at[0:1].add(a[0:1] * h0)
    acum, h = _scan_block(a, b)
    carry_ref[...] = h[T - 1: T]
    h_ref[0] = h.astype(h_ref.dtype)

    @pl.when(ti == nt - 1)
    def _emit():
        hlast_ref[0] = h[T - 1: T].astype(hlast_ref.dtype)


def rglru_scan_pallas(b_in, a, *, block_t: int = 256, block_d: int = 512,
                      interpret: bool = True):
    """b_in (gated input term), a (decay): [B, L, D] fp32.

    Returns (h [B, L, D], h_last [B, D]).
    """
    B, L, D = a.shape
    T = min(block_t, L)
    bd = min(block_d, D)
    nt = L // T
    nd = D // bd

    kernel = functools.partial(_rglru_kernel, T=T, nt=nt)
    h, hlast = pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, T, bd), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, T, bd), lambda bi, di, ti: (bi, ti, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, T, bd), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, 1, bd), lambda bi, di, ti: (bi, 0, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, D), b_in.dtype),
            jax.ShapeDtypeStruct((B, 1, D), b_in.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((1, bd), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(b_in, a)
    return h, hlast[:, 0, :]
