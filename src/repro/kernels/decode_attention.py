"""GQA flash-decode Pallas TPU kernel.

One query token per sequence against a long KV cache. The q heads that share
a KV head are processed together as a ``[group, D]`` tile (so the matmul has
an MXU-utilizable M dimension even though there is a single token), and the
KV cache is streamed through VMEM in ``bk``-sized blocks with the online
softmax carried in scratch. Positions at or beyond ``kv_len`` are masked, so
the same compiled kernel serves every cache fill level.

Grid: (B, Hkv, nk) with nk sequential. Per-step VMEM: k/v blocks
(2*bk*D) + acc (group*D) + logits (group*bk) in fp32 — ~1.1 MB at bk=512,
D=128, group=8.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

NEG_INF = -1e30


def _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, bk: int, scale: float, nk: int, group: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = kvlen_ref[0]
    k_start = ki * bk

    @pl.when(k_start < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # [group, D]
        k = k_ref[0, 0].astype(jnp.float32)                   # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [g,bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (group, bk), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode(q, k, v, kv_len, *, bk: int = 512, interpret: bool = True):
    """q: [B,Hkv,group,D]; k,v: [B,Hkv,Skv,D]; kv_len: scalar int32.

    Returns [B, Hkv, group, D].
    """
    B, Hkv, group, D = q.shape
    Skv = k.shape[2]
    bk = min(bk, Skv)
    nk = Skv // bk
    scale = 1.0 / math.sqrt(D)
    kv_len_arr = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (1,))

    kernel = functools.partial(_decode_kernel, bk=bk, scale=scale, nk=nk,
                               group=group)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, j, kvl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, kvl: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, j, kvl: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, j, kvl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(kv_len_arr, q, k, v)
