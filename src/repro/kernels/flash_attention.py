"""Flash attention (training / prefill) Pallas TPU kernel.

Block-wise online-softmax attention with GQA and optional local windows.
Layout is [B, H, S, D] (transposed in ops.py). The grid is
``(B, Hq, nq, nk)`` with the KV dimension innermost and *sequential*
(``arbitrary``): the running max / denominator / accumulator live in VMEM
scratch across the nk iterations. Causality and the local window are
enforced two ways:

  * whole out-of-range KV blocks are skipped via ``pl.when`` (this is what
    makes windowed attention on a 32k sequence block-sparse rather than
    quadratic);
  * the diagonal (and window-edge) blocks apply an elementwise mask.

Block sizes default to (128, 512) and are clamped to the sequence; VMEM
footprint per step is q(bq*D) + k/v(bk*D each) + acc(bq*D) + logits(bq*bk)
in fp32 — about 2.6 MB at bq=128, bk=512, D=128, comfortably inside the
~16 MB/core VMEM budget while keeping the MXU fed with 128-aligned matmuls.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 bq: int, bk: int, causal: bool, window: int, scale: float,
                 nk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # Whole-block skip conditions (block-sparsity).
    live = True
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window > 0:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)                   # [bk, D]
        v = v_ref[0, 0].astype(jnp.float32)                   # [bk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                   # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                       # [bq, 1]
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = 128, bk: int = 512,
                         interpret: bool = True):
    """q: [B,Hq,S,D]; k,v: [B,Hkv,S,D] -> [B,Hq,S,D]."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    nq = S // bq
    nk = S // bk
    scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_attn_kernel, bq=bq, bk=bk, causal=causal,
                               window=window, scale=scale, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
