"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Implements the state-space-duality block decomposition (the TPU-native
replacement for the CUDA selective scan): for each chunk of Q tokens the
intra-chunk output is a pair of dense matmuls (MXU work), and a small
``[N, P]`` state is carried across chunks through VMEM scratch with the
chunk grid dimension sequential.

Grid: (B, H, nc) — nc (chunks) innermost/sequential.
Blocks: x (1,Q,1,P), dt (1,Q,1), B/C (1,Q,N), y like x; state scratch [N,P].
VMEM per step at Q=256, N=128, P=64: x/y 64 KB, B/C 128 KB, M(QxQ) 256 KB,
state 32 KB — ~0.6 MB in fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import compat


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, s_ref,
                *, Q: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # [Q]
    a = a_ref[0]                                       # scalar A_h (negative)
    Bm = b_ref[0].astype(jnp.float32)                  # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)                  # [Q, N]

    dA = dt * a                                        # [Q] negative
    cum = jnp.cumsum(dA)                               # [Q]
    # intra-chunk: M[i,j] = (C_i . B_j) exp(cum_i - cum_j) dt_j , j <= i
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # [Q,Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    seg = cum[:, None] - cum[None, :]
    decay = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    M = CB * decay * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # [Q,P]

    # inter-chunk: y_i += exp(cum_i) * C_i @ S_prev
    S_prev = s_ref[...]                                # [N, P]
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, S_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # state update: S = exp(cum_last) * S_prev + sum_j exp(cum_last-cum_j) B_j dt_j x_j
    last = cum[Q - 1]
    w = jnp.exp(last - cum) * dt                       # [Q]
    S_loc = jax.lax.dot_general(Bm * w[:, None], x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [N,P]
    s_ref[...] = jnp.exp(last) * S_prev + S_loc

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _emit_state():
        sfin_ref[0, 0] = s_ref[...].astype(sfin_ref.dtype)


def ssd_scan_pallas(x, dt, A, B, C, *, chunk: int = 256,
                    interpret: bool = True):
    """x: [b,l,h,p]; dt: [b,l,h]; A: [h]; B,C: [b,l,n].

    Returns (y [b,l,h,p], final_state [b,h,n,p]).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l)
    nc = l // Q

    kernel = functools.partial(_ssd_kernel, Q=Q, nc=nc)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, Q, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, Q, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, Q, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C)
    return y, sfin
