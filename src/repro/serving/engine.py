"""Serve engine: executes real JAX model steps for loaded endpoints.

Mirrors a production inference engine in miniature: an executable cache
(arch-config-keyed jitted prefill/decode), per-endpoint weight store, and
greedy batched decode. The cluster simulator uses the *cost model* for
scale; the end-to-end example (`examples/serve_serverless.py`) drives THIS
engine so cold/warm latency differences are actually measured on real model
executions.
"""
from __future__ import annotations

import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import Model, build
from .registry import ModelEndpoint, Registry


class ServeEngine:
    def __init__(self, registry: Registry):
        self.registry = registry
        self._models: Dict[str, Model] = {}          # arch key -> Model
        self._exec_cache: Dict[str, Tuple] = {}      # arch key -> jitted fns
        self._weights: Dict[str, Dict] = {}          # app id -> params (host)
        self._loaded: Dict[str, Dict] = {}           # app id -> params (device)

    @staticmethod
    def _arch_key(cfg: ModelConfig) -> str:
        return f"{cfg.arch_id}/{cfg.n_layers}x{cfg.d_model}x{cfg.vocab}"

    def _model(self, cfg: ModelConfig) -> Model:
        k = self._arch_key(cfg)
        if k not in self._models:
            self._models[k] = build(cfg)
        return self._models[k]

    def _executables(self, cfg: ModelConfig, max_len: int):
        k = (self._arch_key(cfg), max_len)
        if k not in self._exec_cache:
            model = self._model(cfg)
            # enc-dec needs encoder frames; VLM backbones serve text-only here
            needs_embeds = cfg.family == "encdec"

            @jax.jit
            def prefill(params, tokens):
                embeds = None
                if needs_embeds:
                    # modality frontend STUB: synthetic frame/patch embeddings
                    embeds = jnp.zeros(
                        (tokens.shape[0], max(cfg.frontend_tokens, 1),
                         cfg.d_model), jnp.float32)
                logits, cache = model.prefill(params, tokens, max_len,
                                              embeds=embeds)
                return jnp.argmax(logits, -1).astype(jnp.int32), cache

            @jax.jit
            def decode(params, token, cache):
                logits, cache = model.decode_step(params, token, cache)
                return jnp.argmax(logits, -1).astype(jnp.int32), cache

            self._exec_cache[k] = (prefill, decode)
        return self._exec_cache[k]

    # -- lifecycle (called by the warm pool / example driver) -----------------

    def load(self, app_id: str) -> float:
        """Materialize weights on device; returns wall seconds taken."""
        # repro-lint: ignore[nondeterminism] -- load() *measures* wall-clock
        # cold-start latency; the measurement is the deliverable, no
        # simulated state depends on it
        t0 = time.perf_counter()
        ep = self.registry.get(app_id)
        if app_id not in self._weights:
            model = self._model(ep.cfg)
            self._weights[app_id] = jax.device_get(
                model.init(jax.random.PRNGKey(ep.seed)))
        self._loaded[app_id] = jax.device_put(self._weights[app_id])
        jax.block_until_ready(jax.tree.leaves(self._loaded[app_id])[0])
        # repro-lint: ignore[nondeterminism] -- end of the latency measurement
        return time.perf_counter() - t0

    def unload(self, app_id: str) -> None:
        self._loaded.pop(app_id, None)

    def is_loaded(self, app_id: str) -> bool:
        return app_id in self._loaded

    # -- inference -------------------------------------------------------------

    def generate(self, app_id: str, tokens: jnp.ndarray, max_new: int = 8,
                 max_len: int = 128) -> Tuple[jnp.ndarray, float]:
        """Greedy generation; returns (tokens [B, max_new], wall seconds).

        Requires the app to be loaded (the warm pool guarantees that)."""
        # repro-lint: ignore[nondeterminism] -- generate() reports measured
        # serving latency alongside the (deterministic) tokens
        t0 = time.perf_counter()
        ep = self.registry.get(app_id)
        params = self._loaded[app_id]
        prefill, decode = self._executables(ep.cfg, max_len)
        tok, cache = prefill(params, tokens)
        outs = [tok[:, 0] if tok.ndim > 1 else tok]
        for _ in range(max_new - 1):
            nxt, cache = decode(params, outs[-1], cache)
            outs.append(nxt)
        result = jnp.stack(outs, axis=1)
        jax.block_until_ready(result)
        # repro-lint: ignore[nondeterminism] -- end of the latency measurement
        return result, time.perf_counter() - t0
