"""Columnar application table for the fleet-scale cluster simulator.

The per-event cluster oracle (:mod:`repro.serving.cluster_sim`) consumes an
eager trace: a Python list of ``AppSpec`` objects next to a Python list of
time arrays — fine at 10^4 apps, prohibitive at 10^6. ``AppTable`` is the
columnar replacement: app-id hashes, exec times, memory sizes and image
weights as flat arrays next to the padded ``[n_apps, max_ev]`` time frame,
built straight from a ``WorkloadSpec`` (no ``materialize(eager=True)`` and
no per-app Python objects) or from any existing ``Trace``.

Population columns come from
:func:`repro.core.workload_spec.population_columns`, which replays only the
per-block population draw of the generator — bit-identical to the values an
eager materialization writes into ``AppSpec`` objects, at array speed.

Worker placement is a column too: ``worker_assignment`` reproduces the
oracle's affinity balancer exactly (least-loaded-at-first-sight over a fleet
of initially empty workers is round-robin in order of first arrival) and
offers FNV-1a hash placement as the stateless alternative the paper's
controller discussion gestures at.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from ..core.workload import MINUTES_PER_DAY, AppSpec, Trace
from ..core.workload_spec import WorkloadSpec, population_columns
from .registry import ModelEndpoint, Registry

# Default image weight: the app's allocated memory, 1 MB = 2**20 bytes.
_BYTES_PER_MB = 2 ** 20

_FNV_OFFSET = 0xcbf29ce484222325
_FNV_PRIME = 0x100000001b3
_U64_MASK = 0xFFFFFFFFFFFFFFFF


def fnv1a64(s: str) -> int:
    """FNV-1a 64-bit hash of a string (the scalar reference)."""
    h = _FNV_OFFSET
    for b in s.encode():
        h = ((h ^ b) * _FNV_PRIME) & _U64_MASK
    return h


_APP_PREFIX_HASH = fnv1a64("app-")
_POW10 = 10 ** np.arange(1, 19, dtype=np.int64)


def fnv1a64_app_indices(idx: np.ndarray) -> np.ndarray:
    """Vectorized :func:`fnv1a64` of the canonical ``app-%06d`` id pattern.

    Folds the shared ``"app-"`` prefix once, then the decimal digits
    column-wise per id width (``%06d`` pads to 6 digits; wider fleets grow
    naturally). Bit-identical to ``fnv1a64(f"app-{i:06d}")`` per element.
    """
    idx = np.asarray(idx, np.int64)
    if np.any(idx < 0):
        raise ValueError("app indices must be non-negative")
    out = np.empty(idx.shape, np.uint64)
    width = np.maximum(np.searchsorted(_POW10, idx, side="right") + 1, 6)
    prime = np.uint64(_FNV_PRIME)
    with np.errstate(over="ignore"):
        for w in np.unique(width):
            m = width == w
            v = idx[m]
            h = np.full(v.shape, np.uint64(_APP_PREFIX_HASH))
            for p in range(int(w) - 1, -1, -1):
                digit = ((v // 10 ** p) % 10 + ord("0")).astype(np.uint64)
                h = (h ^ digit) * prime
            out[m] = h
    return out


def _column(value, n: int, name: str, dtype) -> np.ndarray:
    arr = np.asarray(value, dtype)
    if arr.ndim == 0:
        return np.full(n, arr, dtype)
    if arr.shape != (n,):
        raise ValueError(f"{name} must be scalar or shape ({n},), "
                         f"got {arr.shape}")
    return np.ascontiguousarray(arr)


@dataclasses.dataclass(frozen=True)
class AppTable:
    """Columnar per-app fleet state: the cluster engine's input format.

    ``times`` is the padded ``[n_apps, max_ev]`` invocation frame in minutes
    (+inf padded, sorted per row); treat all arrays as read-only.

    ``weight_bytes`` feeds both the cold-start latency model and the HBM
    occupancy replay (``cluster_vector`` phase D). Eviction ties break on
    the *string* app id, exactly like the oracle's heap — canonical
    ``app-%06d`` ids compare lexicographically in index order up to one
    million apps, which the engine exploits; tables carrying custom
    ``app_ids`` fall back to explicit lexicographic ranks.
    """

    times: np.ndarray          # [n, M] minutes, sorted, +inf padded
    counts: np.ndarray         # [n] int32 valid events per app
    exec_s: np.ndarray         # [n] float64 mean execution seconds
    memory_mb: np.ndarray      # [n] float64 allocated memory
    weight_bytes: np.ndarray   # [n] int64 model-image bytes (cold-start cost)
    app_hash: np.ndarray       # [n] uint64 FNV-1a of the app id
    duration_minutes: float
    app_ids: Optional[Tuple[str, ...]] = None   # only when non-canonical

    @property
    def n_apps(self) -> int:
        return int(self.times.shape[0])

    @property
    def n_events(self) -> int:
        return int(self.counts.sum())

    def app_id(self, i: int) -> str:
        if self.app_ids is not None:
            return self.app_ids[i]
        return f"app-{i:06d}"

    # -- construction ---------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: WorkloadSpec, *, exec_s=None, memory_mb=None,
                  weight_bytes=None, trace: Optional[Trace] = None
                  ) -> "AppTable":
        """Build from a declarative workload — no eager AppSpec loop.

        ``'patterns'`` specs pull exec/memory straight from the population
        columns; ``'uniform'`` specs carry no population, so ``exec_s`` and
        ``memory_mb`` must be given (scalar or per-app). ``trace`` may pass
        an already-materialized trace of the same spec to skip regenerating.
        """
        if trace is None:
            trace = spec.materialize()
        if exec_s is None or memory_mb is None:
            pop = population_columns(spec)     # raises for 'uniform'
            exec_s = pop["execs"] if exec_s is None else exec_s
            memory_mb = pop["memory"] if memory_mb is None else memory_mb
        return cls.from_trace(trace, exec_s=exec_s, memory_mb=memory_mb,
                              weight_bytes=weight_bytes)

    @classmethod
    def from_trace(cls, trace: Trace, *, exec_s=None, memory_mb=None,
                   weight_bytes=None) -> "AppTable":
        """Build from any Trace (eager or padded-only).

        Eager traces supply exec/memory (and app ids) from their AppSpecs;
        padded-only traces use the canonical ``app-%06d`` ids and require
        explicit ``exec_s`` / ``memory_mb`` (scalar or per-app arrays).
        """
        times, counts = trace.to_padded()
        n = trace.n_apps
        ids = None
        if trace.specs is not None:
            if exec_s is None:
                exec_s = np.array([s.exec_time_s for s in trace.specs],
                                  np.float64)
            if memory_mb is None:
                memory_mb = np.array([s.memory_mb for s in trace.specs],
                                     np.float64)
            ids = tuple(s.app_id for s in trace.specs)
            if all(a == f"app-{i:06d}" for i, a in enumerate(ids)):
                ids = None                     # canonical: no need to store
        if exec_s is None or memory_mb is None:
            raise ValueError(
                "padded-only traces carry no per-app metadata; pass exec_s "
                "and memory_mb (scalar or [n_apps] arrays) to AppTable")
        exec_col = _column(exec_s, n, "exec_s", np.float64)
        mem_col = _column(memory_mb, n, "memory_mb", np.float64)
        if weight_bytes is None:
            wb_col = np.round(mem_col * _BYTES_PER_MB).astype(np.int64)
        else:
            wb_col = _column(weight_bytes, n, "weight_bytes", np.int64)
        if ids is None:
            app_hash = fnv1a64_app_indices(np.arange(n))
        else:
            app_hash = np.array([fnv1a64(a) for a in ids], np.uint64)
        return cls(times=times, counts=np.asarray(counts, np.int32),
                   exec_s=exec_col, memory_mb=mem_col, weight_bytes=wb_col,
                   app_hash=app_hash,
                   duration_minutes=float(trace.duration_minutes),
                   app_ids=ids)

    # -- worker placement -----------------------------------------------------

    def worker_assignment(self, n_workers: int,
                          balancing: str = "affinity") -> np.ndarray:
        """Per-app worker index under the requested balancing mode.

        ``"affinity"`` reproduces the scalar oracle's
        least-loaded-at-first-sight placement: every new app adds exactly
        one resident entry to its worker, and argmin ties break toward the
        lowest index, so placement is round-robin in order of first arrival
        (ties by app index, matching the oracle's (time, index) event sort).
        ``"hash"`` is stateless FNV-1a placement. Apps with zero events are
        assigned worker 0; they generate no load.
        """
        if n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {n_workers}")
        n = self.n_apps
        if balancing == "hash":
            return (self.app_hash % np.uint64(n_workers)).astype(np.int64)
        if balancing != "affinity":
            raise ValueError(
                f"unknown balancing {balancing!r}; use 'affinity' or 'hash'")
        assign = np.zeros(n, np.int64)
        active = self.counts > 0
        t0 = np.where(active, self.times[:, 0] if self.times.shape[1] else
                      np.inf, np.inf)
        order = np.lexsort((np.arange(n), t0))
        n_active = int(active.sum())
        assign[order[:n_active]] = np.arange(n_active) % n_workers
        return assign

    # -- bridges to the scalar oracle -----------------------------------------

    def to_trace(self) -> Trace:
        """Eager Trace view (float64 times + AppSpecs) for the scalar oracle.

        Pattern metadata the table does not keep (pattern class, period,
        trigger mix) is filled with placeholders — the cluster simulator
        reads only ``app_id`` and ``exec_time_s``.
        """
        days = max(self.duration_minutes / MINUTES_PER_DAY, 1e-12)
        times = [np.asarray(self.times[i, : int(c)], np.float64)
                 for i, c in enumerate(self.counts)]
        specs = [AppSpec(app_id=self.app_id(i), pattern="poisson",
                         rate_per_day=float(self.counts[i]) / days,
                         period_minutes=0.0,
                         exec_time_s=float(self.exec_s[i]),
                         memory_mb=float(self.memory_mb[i]), n_functions=1,
                         triggers=("http",))
                 for i in range(self.n_apps)]
        return Trace(specs=specs, times=times,
                     duration_minutes=self.duration_minutes)

    def to_registry(self) -> Registry:
        """Registry of weight-only endpoints for the scalar oracle."""
        reg = Registry()
        for i in range(self.n_apps):
            reg.register(ModelEndpoint(app_id=self.app_id(i), cfg=None,
                                       weight_bytes=int(self.weight_bytes[i])))
        return reg
