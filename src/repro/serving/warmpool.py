"""Warm pool: the paper's hybrid histogram policy managing HBM residency.

This is the OpenWhisk-Invoker analog (DESIGN.md §2): instead of Docker
containers it manages *model images* (weights + compiled step) in device
memory. The policy decides, per endpoint:

  * when to UNLOAD after a request finishes (pre-warming window > 0 means
    unload immediately and reload later);
  * when to PRE-WARM (load ahead of the predicted next request);
  * how long to KEEP ALIVE after the (re)load.

All in virtual time (the cluster simulator drives `now`); the same object
drives the real engine in examples/serve_serverless.py. Memory-budget
pressure evicts the app whose keep-alive expires soonest (the policy's own
estimate of "least likely to be needed"); apps pinned mid-request are never
victims, and a load that cannot fit even after evicting everything evictable
proceeds over budget but is counted (``PoolStats.budget_overflows``).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from ..core import policy_math
from ..core.policy import Policy, PolicyWindows
from .registry import ModelEndpoint, Registry

MINUTE = 60.0


@dataclasses.dataclass
class AppState:
    loaded: bool = False
    compile_cached: bool = False
    pinned: bool = False            # mid-request: never an eviction victim
    last_end: float = -1.0          # end of last request (s)
    unload_at: float = float("inf")  # keep-alive expiry (s)
    prewarm_at: float = float("inf")  # scheduled pre-warm (s)
    windows: Optional[PolicyWindows] = None
    cold_starts: int = 0
    requests: int = 0
    loaded_since: float = 0.0
    resident_seconds: float = 0.0   # accumulated memory time
    bytes_loaded: int = 0


@dataclasses.dataclass
class PoolStats:
    cold_starts: int = 0
    warm_starts: int = 0
    prewarms: int = 0
    unloads: int = 0
    evictions: int = 0
    budget_overflows: int = 0       # loads that proceeded over budget
    bytes_moved: float = 0.0
    resident_byte_seconds: float = 0.0


class WarmPool:
    def __init__(self, registry: Registry, policy,
                 budget_bytes: float = float("inf")):
        # ``policy`` may be a stateful Policy or a declarative PolicySpec
        # (repro.core.experiment) — the same specs the simulators sweep.
        if not isinstance(policy, Policy) and hasattr(policy, "build"):
            policy = policy.build()
        for ep in registry:
            if ep.weight_bytes > budget_bytes:
                raise ValueError(
                    f"endpoint {ep.app_id!r} needs {ep.weight_bytes} bytes "
                    f"but the HBM budget is {budget_bytes:.0f}: a single "
                    f"image larger than the budget can never fit (evicting "
                    f"everything still leaves the pool over budget forever)")
        self.registry = registry
        self.policy = policy
        self.budget = budget_bytes
        self.state: Dict[str, AppState] = {}
        self.stats = PoolStats()
        self._used = 0.0

    # -- residency bookkeeping ------------------------------------------------

    def _st(self, app_id: str) -> AppState:
        if app_id not in self.state:
            self.state[app_id] = AppState()
        return self.state[app_id]

    def _load(self, app_id: str, now: float) -> float:
        """Load an image; returns the latency paid (0 if already loaded)."""
        st = self._st(app_id)
        if st.loaded:
            return 0.0
        ep = self.registry.get(app_id)
        self._ensure_budget(ep.weight_bytes, now, exclude=app_id)
        lat = ep.cold_start_seconds(st.compile_cached)
        st.loaded = True
        st.compile_cached = True
        st.loaded_since = now
        st.bytes_loaded = ep.weight_bytes
        self._used += ep.weight_bytes
        self.stats.bytes_moved += ep.weight_bytes
        return lat

    def _unload(self, app_id: str, now: float) -> None:
        st = self._st(app_id)
        if not st.loaded:
            return
        st.loaded = False
        dt = max(now - st.loaded_since, 0.0)
        st.resident_seconds += dt
        self.stats.resident_byte_seconds += dt * st.bytes_loaded
        self._used -= st.bytes_loaded
        st.unload_at = float("inf")
        self.stats.unloads += 1

    def _ensure_budget(self, need: float, now: float, exclude: str) -> None:
        if self._used + need <= self.budget:
            return
        # Evict loaded apps in order of soonest keep-alive expiry. Pinned
        # (mid-request) apps are never candidates: their ``unload_at`` is
        # inf while they execute, which used to make them indistinguishable
        # from never-unload apps and thus evictable by a concurrent
        # pre-warm's budget pass.
        candidates = [(st.unload_at, app) for app, st in self.state.items()
                      if st.loaded and not st.pinned and app != exclude]
        heapq.heapify(candidates)
        while candidates and self._used + need > self.budget:
            _, app = heapq.heappop(candidates)
            self._unload(app, now)
            self.stats.evictions += 1
        if self._used + need > self.budget:
            # Nothing evictable is left and the load still does not fit:
            # the pool proceeds over budget (the load must happen), but no
            # longer silently — overflows are counted and surfaced in
            # ClusterResult.stats_per_worker.
            self.stats.budget_overflows += 1

    # -- the policy surface ---------------------------------------------------

    def tick(self, now: float) -> None:
        """Advance virtual time: expire keep-alives, then fire pre-warms.

        Iterates over a snapshot: a pre-warm ``_load`` can trigger
        ``_ensure_budget`` evictions that mutate other apps' states, so the
        pass must not interleave with live dict iteration. All keep-alive
        expiries are processed first (freeing memory that is rightfully free
        at ``now``, so pre-warms do not force spurious evictions), then due
        pre-warms fire in scheduled-time order.
        """
        items = list(self.state.items())
        for app_id, st in items:
            if st.loaded and now >= st.unload_at:
                self._unload(app_id, now)
        due = [(st.prewarm_at, app_id, st) for app_id, st in items
               if not st.loaded and now >= st.prewarm_at]
        for _, app_id, st in sorted(due, key=lambda d: (d[0], d[1])):
            self._load(app_id, now)
            st.prewarm_at = float("inf")
            w = st.windows or self.policy.windows(app_id)
            st.unload_at = now + w.keep_alive * MINUTE
            self.stats.prewarms += 1

    def on_request(self, app_id: str, now: float) -> Tuple[bool, float]:
        """A request arrives. Returns (was_cold, startup_latency_s)."""
        self.tick(now)
        st = self._st(app_id)
        st.requests += 1
        cold = not st.loaded
        lat = self._load(app_id, now) if cold else 0.0
        if cold:
            st.cold_starts += 1
            self.stats.cold_starts += 1
        else:
            self.stats.warm_starts += 1
        st.prewarm_at = float("inf")    # a real request supersedes pre-warm
        st.unload_at = float("inf")
        st.pinned = True                # pinned while executing
        return cold, lat

    def on_request_end(self, app_id: str, now: float) -> None:
        """Request finished: record IT, get fresh windows, schedule actions."""
        st = self._st(app_id)
        # Computed as a difference of end-times-in-minutes (not a difference
        # of seconds divided by 60) so the scalar oracle sees bit-identical
        # idle values to the vectorized cluster engine, which scans columns
        # of end times already expressed in minutes.
        idle_min = ((now / MINUTE - st.last_end / MINUTE)
                    if st.last_end >= 0 else None)
        st.last_end = now
        st.pinned = False
        w = self.policy.on_invocation(app_id, idle_min)
        st.windows = w
        # The residency schedule comes from the same single-source bounds the
        # simulators use: resident on [load_at, unload_at] from the gap start.
        load_at, unload_at = policy_math.window_bounds(w.prewarm, w.keep_alive)
        if load_at <= 0.0:
            st.unload_at = now + float(unload_at) * MINUTE
            st.prewarm_at = float("inf")
        else:
            # unload immediately; reload right before the predicted arrival
            self._unload(app_id, now)
            st.prewarm_at = now + float(load_at) * MINUTE
            st.unload_at = float("inf")

    # -- reporting ------------------------------------------------------------

    def finalize(self, now: float) -> PoolStats:
        for app_id, st in list(self.state.items()):
            if st.loaded:
                self._unload(app_id, now)
        return self.stats

    # -- controller fault tolerance ------------------------------------------

    def state_dict(self) -> dict:
        policy_state = (self.policy.state_dict()
                        if hasattr(self.policy, "state_dict") else {})
        return {
            "policy": policy_state,
            "apps": {a: dataclasses.asdict(st) for a, st in self.state.items()},
            "used": self._used,
            "stats": dataclasses.asdict(self.stats),
        }

    def load_state_dict(self, sd: dict) -> None:
        if sd.get("policy") and hasattr(self.policy, "load_state_dict"):
            self.policy.load_state_dict(sd["policy"])
        self.state = {}
        for a, d in sd["apps"].items():
            w = d.pop("windows", None)
            st = AppState(**{k: v for k, v in d.items() if k != "windows"})
            if w:
                st.windows = (PolicyWindows(**w) if isinstance(w, dict)
                              else PolicyWindows(*w))
            self.state[a] = st
        self._used = sd["used"]
        self.stats = PoolStats(**sd["stats"])
