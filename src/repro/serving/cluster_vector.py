"""Vectorized fleet-scale cluster engine (the columnar ClusterSim).

The per-event oracle in :mod:`repro.serving.cluster_sim` replays one global
heap-merged event stream through per-worker warm pools — exact, but ~10^5
events/s of pure Python. This module computes the *same trajectory* from the
columnar :class:`repro.serving.apptable.AppTable` in three array passes:

  A. **Merged events.** Flatten the padded time frame to one event list,
     rank it by the oracle's ``(t, app_idx)`` sort, and draw the shared
     per-rank hedging uniforms so both engines see identical stragglers.

  B. **Policy windows.** The windows an app's pool consults after event
     ``k`` depend only on that app's end-time column — not on warm/cold
     outcomes — so a chunked ``lax.scan`` of
     :func:`repro.core.policy_math.fused_hybrid_step_math` (float64, the
     PR 2 fused engine's step) yields every per-gap residency bound up
     front. Apps whose out-of-bounds counter ever trips the ARIMA gate are
     recomputed through the scalar policy (same post-pass idiom as
     ``simulator._simulate_hybrid_batch_reference``).

  C. **Gap replay.** With windows known, each inter-arrival gap closes in
     closed form: keep-alive expiries and pre-warm fires happen at the
     first *worker tick* (any arrival on that worker) past the scheduled
     time, found with one ``searchsorted`` per worker. Cold verdicts,
     loads/unloads, residency time, latency, and per-worker stats all fall
     out as segmented reductions.

Exactness contract (enforced by ``tests/test_cluster_conformance.py``):
cold counts, per-app cold %, latencies and load/unload/prewarm counters are
*bit-identical* to the oracle; resident byte-seconds agree to float64
accumulation-order tolerance. The one regime difference: HBM-budget
evictions are inherently sequential, so the vector engine *proves* the run
eviction-free (a pessimistic per-worker occupancy peak) and refuses
otherwise, pointing at ``engine="scalar"``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core import policy_math
from ..core.experiment import (FixedSpec, HybridSpec, NoUnloadSpec,
                               PolicySpec, as_spec)
from ..core.policy import HybridHistogramPolicy
from ..core.simulator import (DEFAULT_APP_CHUNK, _chunked_buckets,
                              _step_config_for)
from ..core.workload import Trace
from ..core.workload_spec import WorkloadSpec
from ..runtime.straggler import HedgePolicy
from .apptable import AppTable
from .cluster_sim import MINUTE, ClusterConfig, ClusterResult, ClusterSim
from .registry import (BASE_LOAD_LATENCY, COMPILE_MISS_LATENCY,
                       H2D_BANDWIDTH)

__all__ = ["CLUSTER_ENGINES", "ClusterSpec", "ClusterSweep", "as_table",
           "run_cluster", "sweep_cluster"]

CLUSTER_ENGINES = ("auto", "vector", "scalar")


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Declarative cluster shape: the third axis of an experiment grid.

    Mirrors :class:`repro.serving.cluster_sim.ClusterConfig` knob-for-knob
    (same defaults) as a frozen spec, so ``trace x policy x cluster`` grids
    compose through ``experiment.run(..., cluster=...)`` and
    ``experiment.sweep(..., clusters=[...])``.
    """
    n_workers: int = 18
    hbm_budget_bytes: float = 16e9
    balancing: str = "affinity"          # "affinity" | "hash"
    hedge: Optional[HedgePolicy] = None
    checkpoint_at_minute: Optional[float] = None
    label: Optional[str] = None

    @property
    def name(self) -> str:
        return self.label or f"{self.balancing}-{self.n_workers}w"

    def validate(self) -> None:
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.balancing not in ("affinity", "hash"):
            raise ValueError(f"unknown balancing {self.balancing!r}; "
                             "use 'affinity' or 'hash'")

    def to_config(self) -> ClusterConfig:
        """The oracle's mutable config (the ``engine="scalar"`` bridge)."""
        return ClusterConfig(
            n_workers=self.n_workers, hbm_budget_bytes=self.hbm_budget_bytes,
            hedge=self.hedge, checkpoint_at_minute=self.checkpoint_at_minute,
            balancing=self.balancing)


def as_table(workload, *, exec_s=None, memory_mb=None,
             weight_bytes=None) -> AppTable:
    """Coerce the workload axis: AppTable passes through, WorkloadSpec and
    Trace are converted columnar."""
    if isinstance(workload, AppTable):
        return workload
    if isinstance(workload, WorkloadSpec):
        return AppTable.from_spec(workload, exec_s=exec_s,
                                  memory_mb=memory_mb,
                                  weight_bytes=weight_bytes)
    if isinstance(workload, Trace):
        return AppTable.from_trace(workload, exec_s=exec_s,
                                   memory_mb=memory_mb,
                                   weight_bytes=weight_bytes)
    raise TypeError(f"expected an AppTable, WorkloadSpec or Trace, "
                    f"got {type(workload).__name__}")


# --------------------------------------------------------------------------
# Phase B: per-gap policy windows from the end-time columns
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnums=(1,))
def _hybrid_windows_scan(e_min, cfg: policy_math.HybridStepConfig):
    """Scan the fused hybrid step over one chunk's end-time columns.

    Returns the residency bounds decided *at* each event (they govern the
    following gap) and the sticky any-step out-of-bounds-heavy flag that
    routes an app to the scalar ARIMA post-pass.
    """
    n = e_min.shape[0]
    dt = e_min.dtype
    init = (
        jnp.full((n,), -jnp.inf, dt),                       # prev end time
        jnp.zeros((n, cfg.n_bins), jnp.int32),              # cum histogram
        jnp.zeros((n,), jnp.int32),                         # oob count
        jnp.zeros((n,), dt),                                # Welford sum
        jnp.zeros((n,), dt),                                # Welford sum sq
        jnp.zeros((n,), dt),                                # load bound
        jnp.full((n,), jnp.asarray(cfg.standard_keep, dt)),  # unload bound
        jnp.zeros((n,), jnp.int32),                         # cold (unused)
        jnp.zeros((n,), dt),                                # waste (unused)
    )

    def body(carry, t_col):
        out = policy_math.fused_hybrid_step_math(
            t_col, *carry, cfg=cfg, gather=True)
        cum, oob = out[1], out[2]
        heavy = policy_math.oob_heavy(cum[:, -1].astype(jnp.int32), oob,
                                      cfg.oob_threshold)
        return out, (out[5], out[6], heavy)

    _, (load_seq, unload_seq, heavy_seq) = jax.lax.scan(body, init, e_min.T)
    return load_seq.T, unload_seq.T, jnp.any(heavy_seq, axis=0)


def _policy_windows(table: AppTable, spec: PolicySpec, e_min2d: np.ndarray,
                    counts: np.ndarray, app_chunk: int):
    """(load_at, unload_at) bounds [n, M] decided after each event.

    Bounds are float64 minutes past the execution end — exactly the values
    ``policy_math.window_bounds`` hands the oracle's warm pool (float32
    window values widen exactly; keep-alive is recovered as their float64
    difference, which is how ``AppHistogram.windows`` defines it).
    """
    n, m_ev = e_min2d.shape
    la = np.zeros((n, m_ev))
    ua = np.zeros((n, m_ev))
    if isinstance(spec, NoUnloadSpec):
        ua[:] = np.inf
        return la, ua
    if isinstance(spec, FixedSpec):
        ua[:] = float(spec.keep_alive)
        return la, ua
    if not isinstance(spec, HybridSpec):
        raise TypeError(
            f"the vectorized cluster engine needs a declarative PolicySpec "
            f"(Fixed/NoUnload/Hybrid), got {type(spec).__name__}; arbitrary "
            f"Policy objects run on engine='scalar'")

    hybrid = spec.to_config()
    cfg = _step_config_for(hybrid)
    ua[:] = hybrid.standard_keep_alive       # zero-event rows: never read
    heavy = np.zeros(n, bool)
    with enable_x64():
        for sel, sub in _chunked_buckets(e_min2d, counts, app_chunk):
            la_seq, ua_seq, flag = _hybrid_windows_scan(
                jnp.asarray(sub, jnp.float64), cfg)
            width = sub.shape[1]
            la[sel, :width] = np.asarray(la_seq)
            ua[sel, :width] = np.asarray(ua_seq)
            heavy[sel] = np.asarray(flag)

    # ARIMA post-pass: the fused step carries no forecaster, so any app
    # whose OOB counter ever looked heavy (a superset of "the ARIMA branch
    # was ever consulted") replays through the stateful scalar policy.
    if hybrid.use_arima and heavy.any():
        pol = HybridHistogramPolicy(hybrid)
        for i in np.nonzero(heavy)[0]:
            app_id = table.app_id(int(i))
            prev = None
            for k in range(int(counts[i])):
                e_k = float(e_min2d[i, k])
                w = pol.on_invocation(app_id,
                                      None if prev is None else e_k - prev)
                lo, hi = policy_math.window_bounds(w.prewarm, w.keep_alive)
                la[i, k] = lo
                ua[i, k] = hi
                prev = e_k
    return la, ua


# --------------------------------------------------------------------------
# Phase C: closed-form gap replay
# --------------------------------------------------------------------------


def _first_tick_ge(ticks_by_w, woff, tick_src, worker_q, thr_q):
    """First worker tick at time >= threshold, per query.

    ``ticks_by_w`` holds every arrival time grouped by worker (sorted within
    each group); a keep-alive expiry or pre-warm only *happens* when some
    event on that worker ticks the pool. Returns ``(time, flat_idx)`` with
    ``(inf, -1)`` when no tick qualifies. Queries are grouped by worker so
    each group is one exact float64 ``searchsorted`` — no scaled-offset key
    tricks that could round two distinct times together.
    """
    q_order = np.argsort(worker_q, kind="stable")
    wq = worker_q[q_order]
    tq = thr_q[q_order]
    n_workers = len(woff) - 1
    qoff = np.zeros(n_workers + 1, np.int64)
    np.cumsum(np.bincount(wq, minlength=n_workers), out=qoff[1:])
    t_sorted = np.full(tq.shape, np.inf)
    i_sorted = np.full(tq.shape, -1, np.int64)
    for w in range(n_workers):
        a, b = qoff[w], qoff[w + 1]
        if b == a:
            continue
        seg = ticks_by_w[woff[w]:woff[w + 1]]
        if not len(seg):
            continue
        pos = np.searchsorted(seg, tq[a:b], side="left")
        ok = pos < len(seg)
        pos_c = np.minimum(pos, len(seg) - 1)
        t_sorted[a:b] = np.where(ok, seg[pos_c], np.inf)
        i_sorted[a:b] = np.where(ok, tick_src[woff[w] + pos_c], -1)
    t_out = np.empty_like(t_sorted)
    i_out = np.empty_like(i_sorted)
    t_out[q_order] = t_sorted
    i_out[q_order] = i_sorted
    return t_out, i_out


def _check_no_evictions(spec: ClusterSpec,
                        load_steps, load_bytes, unload_steps, unload_bytes,
                        load_workers, unload_workers) -> None:
    """Prove the run never trips the HBM eviction path.

    Replays per-worker occupancy deltas in oracle *processing* order
    (global event rank), applying same-step loads before unloads — a
    pessimistic peak. Evictions unload other apps mid-run, which feeds back
    into every later verdict; that is inherently sequential, so the vector
    engine refuses rather than silently diverging.
    """
    budget = float(spec.hbm_budget_bytes)
    steps = np.concatenate([load_steps, unload_steps])
    delta = np.concatenate([load_bytes, -unload_bytes])
    workers = np.concatenate([load_workers, unload_workers])
    order = np.lexsort((-delta, steps, workers))
    cum = np.cumsum(delta[order])
    w_sorted = workers[order]
    starts = np.nonzero(np.diff(w_sorted, prepend=-1))[0]
    base = np.where(starts > 0, cum[starts - 1], 0.0)
    peaks = np.maximum.reduceat(cum, starts) - base
    if peaks.max(initial=0.0) > budget:
        raise ValueError(
            "per-worker HBM pressure would trigger evictions, which the "
            "vectorized cluster engine does not model (they are inherently "
            "sequential); raise hbm_budget_bytes, add workers, or run "
            "engine='scalar'")


def _run_vector(table: AppTable, spec: PolicySpec, cluster: ClusterSpec,
                app_chunk: int) -> ClusterResult:
    n = table.n_apps
    n_workers = cluster.n_workers
    counts = np.asarray(table.counts, np.int64)
    t_end = float(table.duration_minutes) * MINUTE

    # ---- Phase A: the merged event stream -------------------------------
    m_ev = table.times.shape[1]
    valid = np.arange(m_ev)[None, :] < counts[:, None]
    rows, cols = np.nonzero(valid)              # row-major: (app, k) order
    n_events = len(rows)
    t_flat = table.times[rows, cols].astype(np.float64) * MINUTE
    order = np.lexsort((rows, t_flat))          # oracle sort: (t, app_idx)
    rank = np.empty(n_events, np.int64)
    rank[order] = np.arange(n_events)

    x_flat = table.exec_s[rows].astype(np.float64)
    if cluster.hedge is not None and n_events:
        u1, u2 = cluster.hedge.event_uniforms(n_events)
        x_flat = np.asarray(cluster.hedge.latency_from_uniforms(
            x_flat, u1[rank], u2[rank]), np.float64)
    e_flat = t_flat + x_flat
    e_min_flat = e_flat / MINUTE

    # ---- Phase B: policy windows per gap --------------------------------
    e_min2d = np.full((n, m_ev), np.inf)
    e_min2d[rows, cols] = e_min_flat
    la2d, ua2d = _policy_windows(table, spec, e_min2d, counts, app_chunk)
    la = la2d[rows, cols]
    ua = ua2d[rows, cols]
    ka_sec = (ua - la) * MINUTE                 # == keep_alive * MINUTE

    # ---- Phase C: closed-form gap replay --------------------------------
    assign = table.worker_assignment(n_workers, cluster.balancing)
    w_flat = assign[rows]
    tick_src = np.lexsort((t_flat, w_flat))     # per-worker sorted arrivals
    ticks_by_w = t_flat[tick_src]
    woff = np.zeros(n_workers + 1, np.int64)
    np.cumsum(np.bincount(w_flat, minlength=n_workers), out=woff[1:])

    last = cols == counts[rows] - 1
    first = cols == 0
    nxt = np.full(n_events, np.inf)
    nxt[~last] = t_flat[np.nonzero(~last)[0] + 1]

    stay = la <= 0.0                            # keep loaded through the gap
    u_stay = e_flat + ua * MINUTE               # expiry schedule (stay)
    p_pre = e_flat + la * MINUTE                # pre-warm schedule (else)

    # Stay branch: unloaded at the first tick past the expiry — which
    # exists whenever the next arrival is cold; the run end finalizes the
    # last gap when no tick ever reaches it.
    need_u = stay & ((nxt >= u_stay) | last)
    ut_stay = np.full(n_events, np.inf)
    ui_stay = np.full(n_events, -1, np.int64)
    ut_stay[need_u], ui_stay[need_u] = _first_tick_ge(
        ticks_by_w, woff, tick_src, w_flat[need_u], u_stay[need_u])

    # Pre-warm branch: unloaded immediately at the execution end; the fire
    # happens at the first tick past the schedule unless the app's own next
    # arrival (which cancels the pre-warm) comes first.
    pre = ~stay
    tau = np.full(n_events, np.inf)
    tau_i = np.full(n_events, -1, np.int64)
    tau[pre], tau_i[pre] = _first_tick_ge(
        ticks_by_w, woff, tick_src, w_flat[pre], p_pre[pre])
    fired = pre & np.isfinite(tau) & (last | (tau <= nxt))
    q_fire = tau + ka_sec                       # post-fire expiry schedule
    need_f = fired & ((nxt >= q_fire) | last)
    ut_fire = np.full(n_events, np.inf)
    ui_fire = np.full(n_events, -1, np.int64)
    ut_fire[need_f], ui_fire[need_f] = _first_tick_ge(
        ticks_by_w, woff, tick_src, w_flat[need_f], q_fire[need_f])

    # Cold verdicts: event k is cold iff gap k-1 lost the image.
    next_cold = np.where(stay, nxt >= u_stay,
                         np.where(fired, nxt >= q_fire, True))
    cold = np.empty(n_events, bool)
    cold[first] = True
    not_first = np.nonzero(~first)[0]
    cold[not_first] = next_cold[not_first - 1]

    # Loads and unloads (time, step, worker, bytes) for residency + stats.
    wb = table.weight_bytes.astype(np.float64)
    wb_flat = wb[rows]
    load_m = [cold, fired]
    load_t = [t_flat[cold], tau[fired]]
    load_step = [rank[cold], rank[tau_i[fired]]]
    unload_m = [pre, need_u, need_f]
    unload_t = [e_flat[pre],
                np.where(np.isfinite(ut_stay[need_u]), ut_stay[need_u], t_end),
                np.where(np.isfinite(ut_fire[need_f]), ut_fire[need_f], t_end)]
    # Expiries missing their tick are finalized at the run end (after every
    # event: step n_events); found ticks carry that tick's processing rank.
    unload_step = [
        rank[pre],
        np.where(ui_stay[need_u] >= 0, rank[np.maximum(ui_stay[need_u], 0)],
                 n_events),
        np.where(ui_fire[need_f] >= 0, rank[np.maximum(ui_fire[need_f], 0)],
                 n_events)]

    lw = np.concatenate([w_flat[m] for m in load_m]) if n_events else \
        np.zeros(0, np.int64)
    uw = np.concatenate([w_flat[m] for m in unload_m]) if n_events else \
        np.zeros(0, np.int64)
    lr = np.concatenate([rows[m] for m in load_m]) if n_events else \
        np.zeros(0, np.int64)
    ur = np.concatenate([rows[m] for m in unload_m]) if n_events else \
        np.zeros(0, np.int64)
    lb = wb[lr]
    ub = wb[ur]
    lt = np.concatenate(load_t) if n_events else np.zeros(0)
    ut = np.concatenate(unload_t) if n_events else np.zeros(0)

    n_loads = np.bincount(lr, minlength=n)
    n_unloads = np.bincount(ur, minlength=n)
    if not np.array_equal(n_loads, n_unloads):  # pragma: no cover
        raise AssertionError("cluster_vector invariant violated: "
                             "per-app loads != unloads")

    # Cheap eviction screen: a worker whose assigned apps all fit at once
    # can never evict; only workers past the sum test get the exact
    # processing-order occupancy replay.
    budget = float(cluster.hbm_budget_bytes)
    active = counts > 0
    per_w_assigned = np.bincount(assign[active], weights=wb[active],
                                 minlength=n_workers)
    if np.isfinite(budget) and per_w_assigned.max(initial=0.0) > budget:
        _check_no_evictions(
            cluster,
            np.concatenate(load_step) if n_events else np.zeros(0, np.int64),
            lb,
            np.concatenate(unload_step) if n_events else np.zeros(0, np.int64),
            ub, lw, uw)

    # ---- Results --------------------------------------------------------
    base_cold = BASE_LOAD_LATENCY + wb / H2D_BANDWIDTH
    start_lat = np.where(
        cold, base_cold[rows] + np.where(first, COMPILE_MISS_LATENCY, 0.0),
        0.0)
    lat = np.empty(n_events)
    lat[rank] = start_lat + x_flat              # oracle (arrival) order

    cold_per_app = np.bincount(rows, weights=cold.astype(np.float64),
                               minlength=n)
    inv = counts.astype(np.float64)
    # Per-app first, per-worker second: the load/unload time sums cancel
    # within each app's handful of events instead of across the fleet,
    # keeping resident time at float64 accumulation accuracy.
    res_app = (np.bincount(ur, weights=ut * ub, minlength=n)
               - np.bincount(lr, weights=lt * lb, minlength=n))
    resident_bs = np.bincount(assign, weights=res_app, minlength=n_workers)

    stats = []
    cold_w = np.bincount(w_flat[cold], minlength=n_workers)
    warm_w = (np.bincount(w_flat, minlength=n_workers) - cold_w)
    fire_w = np.bincount(w_flat[fired], minlength=n_workers)
    unl_w = np.bincount(uw, minlength=n_workers)
    moved_w = np.bincount(lw, weights=lb, minlength=n_workers)
    for w in range(n_workers):
        stats.append(dict(
            cold_starts=int(cold_w[w]), warm_starts=int(warm_w[w]),
            prewarms=int(fire_w[w]), unloads=int(unl_w[w]), evictions=0,
            bytes_moved=float(moved_w[w]),
            resident_byte_seconds=float(resident_bs[w])))

    restored = (cluster.checkpoint_at_minute is not None and n_events > 0
                and bool(np.any(
                    t_flat >= cluster.checkpoint_at_minute * MINUTE)))
    return ClusterResult(
        cold_pct_per_app=100.0 * cold_per_app / np.maximum(inv, 1),
        latencies_s=lat,
        wasted_gb_minutes=float(resident_bs.sum()) / 1e9 / 60.0,
        stats_per_worker=stats,
        restored_mid_run=restored)


# --------------------------------------------------------------------------
# Front door
# --------------------------------------------------------------------------


def run_cluster(workload, policy, cluster: Optional[ClusterSpec] = None, *,
                engine: str = "auto", app_chunk: Optional[int] = None,
                exec_s=None, memory_mb=None,
                weight_bytes=None) -> ClusterResult:
    """Run one workload x policy x cluster cell.

    ``workload`` is an :class:`AppTable`, ``WorkloadSpec`` or ``Trace``
    (``exec_s``/``memory_mb``/``weight_bytes`` fill in per-app metadata the
    workload itself does not carry). ``engine="auto"`` picks the vectorized
    engine; ``"scalar"`` runs the per-event oracle on the same table.
    """
    if engine not in CLUSTER_ENGINES:
        raise ValueError(f"unknown cluster engine {engine!r}; expected one "
                         f"of {CLUSTER_ENGINES}")
    cluster = cluster if cluster is not None else ClusterSpec()
    cluster.validate()
    spec = as_spec(policy)
    table = as_table(workload, exec_s=exec_s, memory_mb=memory_mb,
                     weight_bytes=weight_bytes)
    if engine == "scalar":
        sim = ClusterSim(table.to_registry(), spec, cluster.to_config())
        return sim.run(table.to_trace())
    return _run_vector(table, spec, cluster,
                       app_chunk or DEFAULT_APP_CHUNK)


@dataclasses.dataclass
class ClusterSweep:
    """A (T, S, C) grid: policy x cluster sweeps over T workloads.

    ``results[t][s][c]`` is the :class:`ClusterResult` of workload ``t``
    under policy spec ``s`` on cluster shape ``c`` — each cell identical to
    the corresponding single :func:`run_cluster` call.
    """
    tables: List[AppTable]
    specs: List[PolicySpec]
    clusters: List[ClusterSpec]
    results: List[List[List[ClusterResult]]]

    @property
    def shape(self):
        return (len(self.tables), len(self.specs), len(self.clusters))

    def row(self, t: int, s: int, c: int = 0) -> ClusterResult:
        return self.results[t][s][c]


def sweep_cluster(workloads: Union[Sequence, object], specs: Sequence,
                  clusters: Optional[Sequence[ClusterSpec]] = None, *,
                  engine: str = "auto",
                  app_chunk: Optional[int] = None) -> ClusterSweep:
    """Evaluate the full workload x policy x cluster grid.

    Each workload is converted to a columnar :class:`AppTable` ONCE and
    reused across every (policy, cluster) cell.
    """
    if not isinstance(workloads, (list, tuple)):
        workloads = [workloads]
    specs = [as_spec(s) for s in specs]
    clusters = list(clusters) if clusters is not None else [ClusterSpec()]
    if not specs or not clusters or not len(workloads):
        raise ValueError("sweep_cluster needs at least one workload, one "
                         "PolicySpec and one ClusterSpec")
    tables = [as_table(w) for w in workloads]
    results = [[[run_cluster(tab, s, c, engine=engine, app_chunk=app_chunk)
                 for c in clusters] for s in specs] for tab in tables]
    return ClusterSweep(tables=tables, specs=specs, clusters=clusters,
                        results=results)
