"""Vectorized fleet-scale cluster engine (the columnar ClusterSim).

The per-event oracle in :mod:`repro.serving.cluster_sim` replays one global
heap-merged event stream through per-worker warm pools — exact, but ~10^5
events/s of pure Python. This module computes the *same trajectory* from the
columnar :class:`repro.serving.apptable.AppTable` in three array passes:

  A. **Merged events.** Flatten the padded time frame to one event list,
     rank it by the oracle's ``(t, app_idx)`` sort, and draw the shared
     per-rank hedging uniforms so both engines see identical stragglers.

  B. **Policy windows.** The windows an app's pool consults after event
     ``k`` depend only on that app's end-time column — not on warm/cold
     outcomes — so a chunked ``lax.scan`` of
     :func:`repro.core.policy_math.fused_hybrid_step_math` (float64, the
     PR 2 fused engine's step) yields every per-gap residency bound up
     front. Apps whose out-of-bounds counter ever trips the ARIMA gate are
     recomputed through the scalar policy (same post-pass idiom as
     ``simulator._simulate_hybrid_batch_reference``).

  C. **Gap replay.** With windows known, each inter-arrival gap closes in
     closed form: keep-alive expiries and pre-warm fires happen at the
     first *worker tick* (any arrival on that worker) past the scheduled
     time, found with one ``searchsorted`` per worker. Cold verdicts,
     loads/unloads, residency time, latency, and per-worker stats all fall
     out as segmented reductions.

  D. **HBM evictions to a fixed point.** Workers whose assigned image
     bytes exceed the budget (a cheap pessimistic screen — everyone else
     skips this phase entirely) replay their per-worker occupancy in the
     oracle's processing order: one op list (expiries, pre-warm fires,
     request loads, end-of-request unloads, phase-ordered exactly as
     ``WarmPool.tick``/``on_request`` interleave them) whose running
     cumsum exposes every over-budget load. Each violation is resolved the
     way ``WarmPool._ensure_budget`` would — evict resident, unpinned apps
     in ``(unload_at, app_id)`` order until the load fits — then the
     occupancy is patched *in place* (an eviction only removes residency
     between the eviction and the victim's next arrival, which flips cold)
     and the scan resumes. Because an eviction never adds occupancy before
     the violation that caused it, the scan position is monotone and the
     schedule converges to the oracle's in at most one resolution per
     ``_ensure_budget`` call that evicts.

Exactness contract (enforced by ``tests/test_cluster_conformance.py``):
cold counts, per-app cold %, latencies and every
load/unload/prewarm/**eviction** counter are *bit-identical* to the oracle
— including on oversubscribed fleets where HBM pressure evicts (the
fig_cluster 18x16 GB scenario, flash-crowd eviction storms); resident
byte-seconds agree to float64 accumulation-order tolerance. A
``max_eviction_rounds`` escape hatch caps the fixed-point work; past it the
front door falls back to ``engine="scalar"`` with a warning instead of
silently diverging. Like the oracle's ``WarmPool``, construction refuses a
single image larger than the per-worker budget outright.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..core import policy_math
from ..core.experiment import (FixedSpec, HybridSpec, NoUnloadSpec,
                               PolicySpec, SpesSpec, as_spec)
from ..core.simulator import (DEFAULT_APP_CHUNK, _chunked_buckets,
                              _step_config_for)
from ..core.workload import Trace
from ..core.workload_spec import WorkloadSpec
from ..runtime.straggler import HedgePolicy
from .apptable import AppTable
from .cluster_sim import MINUTE, ClusterConfig, ClusterResult, ClusterSim
from .registry import (BASE_LOAD_LATENCY, COMPILE_MISS_LATENCY,
                       H2D_BANDWIDTH)

__all__ = ["CLUSTER_ENGINES", "ClusterSpec", "ClusterSweep",
           "EvictionRoundsExceeded", "as_table", "run_cluster",
           "sweep_cluster"]

CLUSTER_ENGINES = ("auto", "vector", "scalar")


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Declarative cluster shape: the third axis of an experiment grid.

    Mirrors :class:`repro.serving.cluster_sim.ClusterConfig` knob-for-knob
    (same defaults) as a frozen spec, so ``trace x policy x cluster`` grids
    compose through ``experiment.run(..., cluster=...)`` and
    ``experiment.sweep(..., clusters=[...])``.
    """
    n_workers: int = 18
    hbm_budget_bytes: float = 16e9
    balancing: str = "affinity"          # "affinity" | "hash"
    hedge: Optional[HedgePolicy] = None
    checkpoint_at_minute: Optional[float] = None
    label: Optional[str] = None

    @property
    def name(self) -> str:
        return self.label or f"{self.balancing}-{self.n_workers}w"

    def validate(self) -> None:
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.balancing not in ("affinity", "hash"):
            raise ValueError(f"unknown balancing {self.balancing!r}; "
                             "use 'affinity' or 'hash'")

    def to_config(self) -> ClusterConfig:
        """The oracle's mutable config (the ``engine="scalar"`` bridge)."""
        return ClusterConfig(
            n_workers=self.n_workers, hbm_budget_bytes=self.hbm_budget_bytes,
            hedge=self.hedge, checkpoint_at_minute=self.checkpoint_at_minute,
            balancing=self.balancing)


def as_table(workload, *, exec_s=None, memory_mb=None,
             weight_bytes=None) -> AppTable:
    """Coerce the workload axis: AppTable passes through, WorkloadSpec and
    Trace are converted columnar."""
    if isinstance(workload, AppTable):
        return workload
    if isinstance(workload, WorkloadSpec):
        return AppTable.from_spec(workload, exec_s=exec_s,
                                  memory_mb=memory_mb,
                                  weight_bytes=weight_bytes)
    if isinstance(workload, Trace):
        return AppTable.from_trace(workload, exec_s=exec_s,
                                   memory_mb=memory_mb,
                                   weight_bytes=weight_bytes)
    raise TypeError(f"expected an AppTable, WorkloadSpec or Trace, "
                    f"got {type(workload).__name__}")


# --------------------------------------------------------------------------
# Phase B: per-gap policy windows from the end-time columns
# --------------------------------------------------------------------------


@partial(jax.jit, static_argnums=(1,))
def _hybrid_windows_scan(e_min, cfg: policy_math.HybridStepConfig):
    """Scan the fused hybrid step over one chunk's end-time columns.

    Returns the residency bounds decided *at* each event (they govern the
    following gap) and the sticky any-step out-of-bounds-heavy flag that
    routes an app to the scalar ARIMA post-pass.
    """
    n = e_min.shape[0]
    dt = e_min.dtype
    init = (
        jnp.full((n,), -jnp.inf, dt),                       # prev end time
        jnp.zeros((n, cfg.n_bins), jnp.int32),              # cum histogram
        jnp.zeros((n,), jnp.int32),                         # oob count
        jnp.zeros((n,), dt),                                # Welford sum
        jnp.zeros((n,), dt),                                # Welford sum sq
        jnp.zeros((n,), dt),                                # load bound
        jnp.full((n,), jnp.asarray(cfg.standard_keep, dt)),  # unload bound
        jnp.zeros((n,), jnp.int32),                         # cold (unused)
        jnp.zeros((n,), dt),                                # waste (unused)
    )

    def body(carry, t_col):
        out = policy_math.fused_hybrid_step_math(
            t_col, *carry, cfg=cfg, gather=True)
        cum, oob = out[1], out[2]
        heavy = policy_math.oob_heavy(cum[:, -1].astype(jnp.int32), oob,
                                      cfg.oob_threshold)
        return out, (out[5], out[6], heavy)

    _, (load_seq, unload_seq, heavy_seq) = jax.lax.scan(body, init, e_min.T)
    return load_seq.T, unload_seq.T, jnp.any(heavy_seq, axis=0)


@partial(jax.jit, static_argnums=(1, 2))
def _hybrid_windows_scan_sharded(e_min, cfg: policy_math.HybridStepConfig,
                                 mesh):
    """:func:`_hybrid_windows_scan` partitioned along the app axis of
    ``mesh`` (outputs carry apps on axis 0; the config is a replicated
    static). No collectives — shard outputs concatenate in fixed device
    order, bit-identical to the unsharded scan."""
    from ..distributed.scaleout import shard_along_apps
    fn = lambda ts: _hybrid_windows_scan(ts, cfg)
    return shard_along_apps(fn, mesh, (0,), 0)(e_min)


@jax.jit
def _spes_windows_scan(e_min, knobs: policy_math.SpesStepConfig):
    """Scan the fused SPES-predictor step over one chunk's end-time
    columns, emitting the residency bounds decided *at* each event (knob
    leaves are [1, 1] columns; the config axis is squeezed away)."""
    n = e_min.shape[0]
    dt = e_min.dtype
    init = (
        jnp.full((n,), -jnp.inf, dt),                       # prev end time
        jnp.zeros((1, n), jnp.float32),                     # EW mean
        jnp.zeros((1, n), jnp.float32),                     # EW residual var
        jnp.zeros((n,), jnp.int32),                         # observations
        jnp.zeros((1, n), dt),                              # load bound
        jnp.broadcast_to(knobs.standard_keep.astype(dt), (1, n)),
        jnp.zeros((1, n), jnp.int32),                       # cold (unused)
        jnp.zeros((1, n), dt),                              # waste (unused)
    )

    def body(carry, t_col):
        out = policy_math.fused_spes_step_math(t_col, *carry, cfg=knobs)
        return out, (out[4][0], out[5][0])

    _, (load_seq, unload_seq) = jax.lax.scan(body, init, e_min.T)
    return load_seq.T, unload_seq.T


@partial(jax.jit, static_argnums=(2,))
def _spes_windows_scan_sharded(e_min, knobs: policy_math.SpesStepConfig,
                               mesh):
    """:func:`_spes_windows_scan` partitioned along the app axis of
    ``mesh`` (knobs replicate; outputs carry apps on axis 0)."""
    from ..distributed.scaleout import shard_along_apps
    fn = lambda ts, ks: _spes_windows_scan(ts, ks)
    return shard_along_apps(fn, mesh, (0, None), 0)(e_min, knobs)


def _policy_windows(table: AppTable, spec: PolicySpec, e_min2d: np.ndarray,
                    counts: np.ndarray, app_chunk: int, devices=None):
    """(load_at, unload_at) bounds [n, M] decided after each event.

    Bounds are float64 minutes past the execution end — exactly the values
    ``policy_math.window_bounds`` hands the oracle's warm pool (float32
    window values widen exactly; keep-alive is recovered as their float64
    difference, which is how ``AppHistogram.windows`` defines it).
    """
    n, m_ev = e_min2d.shape
    la = np.zeros((n, m_ev))
    ua = np.zeros((n, m_ev))
    if isinstance(spec, NoUnloadSpec):
        ua[:] = np.inf
        return la, ua
    if isinstance(spec, FixedSpec):
        ua[:] = float(spec.keep_alive)
        return la, ua
    if isinstance(spec, SpesSpec):
        from ..core.simulator import _spes_knobs
        from ..distributed import scaleout
        cfg = spec.to_config()
        knobs = _spes_knobs([cfg])
        mesh = scaleout.mesh_for(devices)
        ua[:] = cfg.standard_keep_alive   # zero-event rows: never read
        with enable_x64():
            for sel, sub in _chunked_buckets(e_min2d, counts, app_chunk):
                if mesh is None:
                    la_seq, ua_seq = _spes_windows_scan(
                        jnp.asarray(sub, jnp.float64), knobs)
                else:
                    padded = scaleout.pad_app_rows(
                        np.ascontiguousarray(sub, np.float64),
                        mesh.devices.size)
                    la_seq, ua_seq = _spes_windows_scan_sharded(
                        jax.device_put(padded,
                                       scaleout.app_sharding(mesh, 2)),
                        knobs, mesh)
                k = len(sel)
                width = sub.shape[1]
                la[sel, :width] = np.asarray(la_seq)[:k]
                ua[sel, :width] = np.asarray(ua_seq)[:k]
        return la, ua
    if not isinstance(spec, HybridSpec):
        raise TypeError(
            f"the vectorized cluster engine needs a declarative PolicySpec "
            f"(Fixed/NoUnload/Hybrid/Spes), got {type(spec).__name__}; "
            f"arbitrary Policy objects run on engine='scalar'")

    from ..distributed import scaleout
    hybrid = spec.to_config()
    cfg = _step_config_for(hybrid)
    mesh = scaleout.mesh_for(devices)
    ua[:] = hybrid.standard_keep_alive       # zero-event rows: never read
    heavy = np.zeros(n, bool)
    with enable_x64():
        for sel, sub in _chunked_buckets(e_min2d, counts, app_chunk):
            if mesh is None:
                la_seq, ua_seq, flag = _hybrid_windows_scan(
                    jnp.asarray(sub, jnp.float64), cfg)
            else:
                padded = scaleout.pad_app_rows(
                    np.ascontiguousarray(sub, np.float64),
                    mesh.devices.size)
                la_seq, ua_seq, flag = _hybrid_windows_scan_sharded(
                    jax.device_put(padded, scaleout.app_sharding(mesh, 2)),
                    cfg, mesh)
            k = len(sel)
            width = sub.shape[1]
            la[sel, :width] = np.asarray(la_seq)[:k]
            ua[sel, :width] = np.asarray(ua_seq)[:k]
            heavy[sel] = np.asarray(flag)[:k]

    # Forecast post-pass: the fused step carries no forecaster, so any app
    # whose OOB counter ever looked heavy (a superset of "the ARIMA branch
    # was ever consulted") replays through the batched forecasting
    # subsystem — one rescan plus one grid ARIMA fit over every flagged
    # (app, event) window, bit-identical to stepping the stateful scalar
    # policy through each event.
    if hybrid.use_arima and heavy.any():
        from ..forecast.replay import hybrid_window_sequences
        rows = np.nonzero(heavy)[0]
        la_r, ua_r = hybrid_window_sequences(
            e_min2d[rows], counts[rows].astype(np.int64), hybrid,
            app_chunk=app_chunk)
        la[rows] = la_r
        ua[rows] = ua_r
    return la, ua


# --------------------------------------------------------------------------
# Phase C: closed-form gap replay
# --------------------------------------------------------------------------


def _first_tick_ge(ticks_by_w, woff, tick_src, worker_q, thr_q):
    """First worker tick at time >= threshold, per query.

    ``ticks_by_w`` holds every arrival time grouped by worker (sorted within
    each group); a keep-alive expiry or pre-warm only *happens* when some
    event on that worker ticks the pool. Returns ``(time, flat_idx)`` with
    ``(inf, -1)`` when no tick qualifies. Queries are grouped by worker so
    each group is one exact float64 ``searchsorted`` — no scaled-offset key
    tricks that could round two distinct times together.
    """
    q_order = np.argsort(worker_q, kind="stable")
    wq = worker_q[q_order]
    tq = thr_q[q_order]
    n_workers = len(woff) - 1
    qoff = np.zeros(n_workers + 1, np.int64)
    np.cumsum(np.bincount(wq, minlength=n_workers), out=qoff[1:])
    t_sorted = np.full(tq.shape, np.inf)
    i_sorted = np.full(tq.shape, -1, np.int64)
    for w in range(n_workers):
        a, b = qoff[w], qoff[w + 1]
        if b == a:
            continue
        seg = ticks_by_w[woff[w]:woff[w + 1]]
        if not len(seg):
            continue
        pos = np.searchsorted(seg, tq[a:b], side="left")
        ok = pos < len(seg)
        pos_c = np.minimum(pos, len(seg) - 1)
        t_sorted[a:b] = np.where(ok, seg[pos_c], np.inf)
        i_sorted[a:b] = np.where(ok, tick_src[woff[w] + pos_c], -1)
    t_out = np.empty_like(t_sorted)
    i_out = np.empty_like(i_sorted)
    t_out[q_order] = t_sorted
    i_out[q_order] = i_sorted
    return t_out, i_out


class EvictionRoundsExceeded(RuntimeError):
    """The eviction fixed point ran past ``max_eviction_rounds``.

    Raised by the worker replay; :func:`run_cluster` catches it and falls
    back to ``engine="scalar"`` with a warning rather than spinning (or
    silently diverging from) the oracle's sequential eviction cascade.
    """


def _app_tie_ranks(table: AppTable) -> np.ndarray:
    """Eviction tie-break keys matching the oracle's heap order.

    ``WarmPool._ensure_budget`` pops ``(unload_at, app_id)`` tuples, so
    equal expiries tie-break on the app-id *string*. Canonical
    ``app-%06d`` ids compare in index order while they are 6 digits wide;
    wider fleets (and explicit non-canonical ids) get their true
    lexicographic rank.
    """
    n = table.n_apps
    if table.app_ids is not None:
        ids = np.asarray(table.app_ids)
    elif n > 1_000_000:          # "app-1000000" sorts before "app-999999"
        ids = np.array([table.app_id(i) for i in range(n)])
    else:
        return np.arange(n, dtype=np.int64)
    ranks = np.empty(n, np.int64)
    ranks[np.argsort(ids)] = np.arange(n)
    return ranks


def _evict_worker(j_idx, budget, *, rows, rank, t_by_rank, wb, tie, cold,
                  stay, pre, fired, need_u, need_f, ui_stay, ui_fire,
                  tau_i, u_stay, q_fire, p_pre, max_rounds):
    """Exact HBM-eviction replay for one worker (phase D).

    ``j_idx`` holds the worker's flat event indices in ``(app, k)`` order;
    every other array is global flat-event state from the gap replay. The
    worker's memory ops are laid out in the oracle's processing order —
    per event rank, keep-alive expiries (phase 0), then pre-warm fires
    ordered by ``(prewarm_at, app_id)`` (phase 1), then the request load
    (phase 2), then the end-of-request unload (phase 3) — and the running
    occupancy cumsum is scanned for over-budget loads. Each violation is
    resolved like ``WarmPool._ensure_budget``: resident spans covering the
    violation are candidates, evicted in ``(unload_at, app_id)`` order
    until the load fits (or counted as a budget overflow when nothing
    evictable remains). An eviction removes the victim's occupancy only
    between the violation and the victim's next scheduled end — its next
    arrival (flipped to a cold load, in-place in ``cold``) or scheduled
    expiry — so the patch is a slice subtraction and the scan resumes
    forward; positions are monotone, so each ``_ensure_budget`` call is
    resolved exactly once.

    Returns ``(evicted_local, evict_time_local, overflows, rounds)``.
    """
    E = len(j_idx)
    app = rows[j_idx]
    w_b = wb[j_idx].astype(np.float64)
    g_tie = tie[app]
    step = rank[j_idx]
    st_g, pre_g, fired_g = stay[j_idx], pre[j_idx], fired[j_idx]
    nu_g, nf_g = need_u[j_idx], need_f[j_idx]

    # ---- op table (unsorted layout: expiries | fires | slots | ends) ----
    ui_g = np.where(st_g, ui_stay[j_idx], ui_fire[j_idx])
    g_exp = np.nonzero((nu_g | nf_g) & (ui_g >= 0))[0]
    g_fire = np.nonzero(fired_g)[0]
    g_end = np.nonzero(pre_g)[0]
    n_exp, n_fire, n_end = len(g_exp), len(g_fire), len(g_end)
    slot0 = n_exp + n_fire
    N = slot0 + E + n_end

    op_gap = np.concatenate([g_exp, g_fire, np.arange(E), g_end])
    op_step = np.concatenate([rank[ui_g[g_exp]], rank[tau_i[j_idx[g_fire]]],
                              step, step[g_end]])
    op_phase = np.concatenate([np.zeros(n_exp, np.int8),
                               np.ones(n_fire, np.int8),
                               np.full(E, 2, np.int8),
                               np.full(n_end, 3, np.int8)])
    op_sub1 = np.zeros(N)
    op_sub1[n_exp:slot0] = p_pre[j_idx[g_fire]]
    op_sub2 = np.zeros(N, np.int64)
    op_sub2[n_exp:slot0] = g_tie[g_fire]
    op_delta = np.concatenate([-w_b[g_exp], w_b[g_fire],
                               w_b * cold[j_idx], -w_b[g_end]])
    op_need = np.concatenate([np.zeros(n_exp), w_b[g_fire], w_b,
                              np.zeros(n_end)])
    op_check = np.concatenate([np.zeros(n_exp, bool), np.ones(n_fire, bool),
                               cold[j_idx].copy(), np.zeros(n_end, bool)])

    srt = np.lexsort((op_sub2, op_sub1, op_phase, op_step))
    pos_of = np.empty(N, np.int64)
    pos_of[srt] = np.arange(N)
    slot_pos = pos_of[slot0:slot0 + E]
    fire_pos = np.full(E, -1, np.int64)
    fire_pos[g_fire] = pos_of[n_exp:slot0]
    exp_pos = np.full(E, -1, np.int64)
    exp_pos[g_exp] = pos_of[:n_exp]

    occ = np.cumsum(op_delta[srt])
    check_s = op_check[srt]
    need_s = op_need[srt]
    gap_s = op_gap[srt]
    step_s = op_step[srt]

    # ---- resident spans per gap, in op positions -----------------------
    active = st_g | fired_g
    span_start = np.where(st_g, slot_pos, fire_pos)
    has_sched = np.where(st_g, nu_g, nf_g)
    span_end = np.full(E, N, np.int64)          # scheduled end at run end
    found = active & has_sched & (exp_pos >= 0)
    span_end[found] = exp_pos[found]
    warm_cont = active & ~has_sched             # continues into next event
    if warm_cont.any():
        g_nxt = np.searchsorted(j_idx, j_idx[warm_cont] + 1)
        span_end[warm_cont] = slot_pos[g_nxt]
    u_time = np.where(st_g, u_stay[j_idx], q_fire[j_idx])

    # ---- scan + resolve ------------------------------------------------
    evicted = np.zeros(E, bool)
    evict_t = np.zeros(E)
    overflows = 0
    rounds = 0
    s = 0
    while s < N:
        seg = check_s[s:] & (occ[s:] > budget)
        rel = int(np.argmax(seg))
        if not seg[rel]:
            break
        v = s + rel
        rounds += 1
        if rounds > max_rounds:
            raise EvictionRoundsExceeded(
                f"eviction fixed point exceeded max_eviction_rounds="
                f"{max_rounds} on one worker")
        a_v = app[gap_s[v]]
        t_v = t_by_rank[step_s[v]]
        need = need_s[v]
        used_before = occ[v] - need
        cand = np.nonzero(active & ~evicted & (span_start < v)
                          & (span_end > v) & (app != a_v))[0]
        if len(cand):
            cand = cand[np.lexsort((g_tie[cand], u_time[cand]))]
            freed = np.cumsum(w_b[cand])
            k = int(np.searchsorted(freed, used_before + need - budget,
                                    side="left")) + 1
            if k > len(cand):
                k = len(cand)
                overflows += 1
            victims = cand[:k]
        else:
            victims = cand
            overflows += 1
        for g_e in victims:
            evicted[g_e] = True
            evict_t[g_e] = t_v
            occ[v:span_end[g_e]] -= w_b[g_e]
            if warm_cont[g_e]:
                # The victim's next arrival finds the image gone: cold.
                j_n = j_idx[g_e] + 1
                cold[j_n] = True
                check_s[slot_pos[np.searchsorted(j_idx, j_n)]] = True
        s = v + 1
    return evicted, evict_t, overflows, rounds


def _run_vector(table: AppTable, spec: PolicySpec, cluster: ClusterSpec,
                app_chunk: int, devices=None,
                max_eviction_rounds: Optional[int] = None) -> ClusterResult:
    n = table.n_apps
    n_workers = cluster.n_workers
    counts = np.asarray(table.counts, np.int64)
    t_end = float(table.duration_minutes) * MINUTE

    budget = float(cluster.hbm_budget_bytes)
    if np.isfinite(budget) and n and table.weight_bytes.max() > budget:
        i_big = int(np.argmax(table.weight_bytes))
        raise ValueError(
            f"endpoint {table.app_id(i_big)!r} needs "
            f"{int(table.weight_bytes[i_big])} bytes but the HBM budget is "
            f"{budget:.0f}: a single image larger than the budget can "
            f"never fit (evicting everything still leaves the pool over "
            f"budget forever)")

    # ---- Phase A: the merged event stream -------------------------------
    m_ev = table.times.shape[1]
    valid = np.arange(m_ev)[None, :] < counts[:, None]
    rows, cols = np.nonzero(valid)              # row-major: (app, k) order
    n_events = len(rows)
    t_flat = table.times[rows, cols].astype(np.float64) * MINUTE
    order = np.lexsort((rows, t_flat))          # oracle sort: (t, app_idx)
    rank = np.empty(n_events, np.int64)
    rank[order] = np.arange(n_events)

    x_flat = table.exec_s[rows].astype(np.float64)
    if cluster.hedge is not None and n_events:
        u1, u2 = cluster.hedge.event_uniforms(n_events)
        x_flat = np.asarray(cluster.hedge.latency_from_uniforms(
            x_flat, u1[rank], u2[rank]), np.float64)
    e_flat = t_flat + x_flat
    e_min_flat = e_flat / MINUTE

    # ---- Phase B: policy windows per gap --------------------------------
    e_min2d = np.full((n, m_ev), np.inf)
    e_min2d[rows, cols] = e_min_flat
    la2d, ua2d = _policy_windows(table, spec, e_min2d, counts, app_chunk,
                                 devices=devices)
    la = la2d[rows, cols]
    ua = ua2d[rows, cols]
    ka_sec = (ua - la) * MINUTE                 # == keep_alive * MINUTE

    # ---- Phase C: closed-form gap replay --------------------------------
    assign = table.worker_assignment(n_workers, cluster.balancing)
    w_flat = assign[rows]
    tick_src = np.lexsort((t_flat, w_flat))     # per-worker sorted arrivals
    ticks_by_w = t_flat[tick_src]
    woff = np.zeros(n_workers + 1, np.int64)
    np.cumsum(np.bincount(w_flat, minlength=n_workers), out=woff[1:])

    last = cols == counts[rows] - 1
    first = cols == 0
    nxt = np.full(n_events, np.inf)
    nxt[~last] = t_flat[np.nonzero(~last)[0] + 1]

    stay = la <= 0.0                            # keep loaded through the gap
    u_stay = e_flat + ua * MINUTE               # expiry schedule (stay)
    p_pre = e_flat + la * MINUTE                # pre-warm schedule (else)

    # Stay branch: unloaded at the first tick past the expiry — which
    # exists whenever the next arrival is cold; the run end finalizes the
    # last gap when no tick ever reaches it.
    need_u = stay & ((nxt >= u_stay) | last)
    ut_stay = np.full(n_events, np.inf)
    ui_stay = np.full(n_events, -1, np.int64)
    ut_stay[need_u], ui_stay[need_u] = _first_tick_ge(
        ticks_by_w, woff, tick_src, w_flat[need_u], u_stay[need_u])

    # Pre-warm branch: unloaded immediately at the execution end; the fire
    # happens at the first tick past the schedule unless the app's own next
    # arrival (which cancels the pre-warm) comes first.
    pre = ~stay
    tau = np.full(n_events, np.inf)
    tau_i = np.full(n_events, -1, np.int64)
    tau[pre], tau_i[pre] = _first_tick_ge(
        ticks_by_w, woff, tick_src, w_flat[pre], p_pre[pre])
    fired = pre & np.isfinite(tau) & (last | (tau <= nxt))
    q_fire = tau + ka_sec                       # post-fire expiry schedule
    need_f = fired & ((nxt >= q_fire) | last)
    ut_fire = np.full(n_events, np.inf)
    ui_fire = np.full(n_events, -1, np.int64)
    ut_fire[need_f], ui_fire[need_f] = _first_tick_ge(
        ticks_by_w, woff, tick_src, w_flat[need_f], q_fire[need_f])

    # Cold verdicts: event k is cold iff gap k-1 lost the image.
    next_cold = np.where(stay, nxt >= u_stay,
                         np.where(fired, nxt >= q_fire, True))
    cold = np.empty(n_events, bool)
    cold[first] = True
    not_first = np.nonzero(~first)[0]
    cold[not_first] = next_cold[not_first - 1]

    # ---- Phase D: HBM evictions to a fixed point ------------------------
    # Cheap pessimistic screen first: a worker whose assigned apps all fit
    # at once can never evict; only workers past the sum test replay their
    # exact processing-order occupancy (and most find no violation).
    wb = table.weight_bytes.astype(np.float64)
    wb_flat = wb[rows]
    evicted = np.zeros(n_events, bool)
    evict_time = np.zeros(n_events)
    overflow_w = np.zeros(n_workers, np.int64)
    active = counts > 0
    if np.isfinite(budget) and n_events:
        per_w_assigned = np.bincount(assign[active], weights=wb[active],
                                     minlength=n_workers)
        risky = np.nonzero(per_w_assigned > budget)[0]
        if len(risky):
            tie = _app_tie_ranks(table)
            t_by_rank = t_flat[order]
            rounds_left = (max_eviction_rounds if max_eviction_rounds
                           is not None else np.inf)
            for w in risky:
                j_w = np.nonzero(w_flat == w)[0]
                ev_l, evt_l, n_over, used = _evict_worker(
                    j_w, budget, rows=rows, rank=rank, t_by_rank=t_by_rank,
                    wb=wb_flat, tie=tie, cold=cold, stay=stay, pre=pre,
                    fired=fired, need_u=need_u, need_f=need_f,
                    ui_stay=ui_stay, ui_fire=ui_fire, tau_i=tau_i,
                    u_stay=u_stay, q_fire=q_fire, p_pre=p_pre,
                    max_rounds=rounds_left)
                evicted[j_w] = ev_l
                evict_time[j_w] = evt_l
                overflow_w[w] = n_over
                rounds_left -= used

    # Loads and unloads (time, worker, bytes) for residency + stats. An
    # evicted span's scheduled expiry never happens — its unload is the
    # eviction itself, at the evicting load's tick time.
    sched_u = need_u & ~evicted
    sched_f = need_f & ~evicted
    load_m = [cold, fired]
    load_t = [t_flat[cold], tau[fired]]
    unload_m = [pre, sched_u, sched_f, evicted]
    # Expiries missing their tick are finalized at the run end.
    unload_t = [e_flat[pre],
                np.where(np.isfinite(ut_stay[sched_u]), ut_stay[sched_u],
                         t_end),
                np.where(np.isfinite(ut_fire[sched_f]), ut_fire[sched_f],
                         t_end),
                evict_time[evicted]]

    lw = np.concatenate([w_flat[m] for m in load_m]) if n_events else \
        np.zeros(0, np.int64)
    uw = np.concatenate([w_flat[m] for m in unload_m]) if n_events else \
        np.zeros(0, np.int64)
    lr = np.concatenate([rows[m] for m in load_m]) if n_events else \
        np.zeros(0, np.int64)
    ur = np.concatenate([rows[m] for m in unload_m]) if n_events else \
        np.zeros(0, np.int64)
    lb = wb[lr]
    ub = wb[ur]
    lt = np.concatenate(load_t) if n_events else np.zeros(0)
    ut = np.concatenate(unload_t) if n_events else np.zeros(0)

    n_loads = np.bincount(lr, minlength=n)
    n_unloads = np.bincount(ur, minlength=n)
    if not np.array_equal(n_loads, n_unloads):  # pragma: no cover
        raise AssertionError("cluster_vector invariant violated: "
                             "per-app loads != unloads")

    # ---- Results --------------------------------------------------------
    base_cold = BASE_LOAD_LATENCY + wb / H2D_BANDWIDTH
    start_lat = np.where(
        cold, base_cold[rows] + np.where(first, COMPILE_MISS_LATENCY, 0.0),
        0.0)
    lat = np.empty(n_events)
    lat[rank] = start_lat + x_flat              # oracle (arrival) order

    cold_per_app = np.bincount(rows, weights=cold.astype(np.float64),
                               minlength=n)
    inv = counts.astype(np.float64)
    # Per-app first, per-worker second: the load/unload time sums cancel
    # within each app's handful of events instead of across the fleet,
    # keeping resident time at float64 accumulation accuracy.
    res_app = (np.bincount(ur, weights=ut * ub, minlength=n)
               - np.bincount(lr, weights=lt * lb, minlength=n))
    resident_bs = np.bincount(assign, weights=res_app, minlength=n_workers)

    stats = []
    cold_w = np.bincount(w_flat[cold], minlength=n_workers)
    warm_w = (np.bincount(w_flat, minlength=n_workers) - cold_w)
    fire_w = np.bincount(w_flat[fired], minlength=n_workers)
    unl_w = np.bincount(uw, minlength=n_workers)   # includes evictions
    evict_w = np.bincount(w_flat[evicted], minlength=n_workers)
    moved_w = np.bincount(lw, weights=lb, minlength=n_workers)
    for w in range(n_workers):
        stats.append(dict(
            cold_starts=int(cold_w[w]), warm_starts=int(warm_w[w]),
            prewarms=int(fire_w[w]), unloads=int(unl_w[w]),
            evictions=int(evict_w[w]),
            budget_overflows=int(overflow_w[w]),
            bytes_moved=float(moved_w[w]),
            resident_byte_seconds=float(resident_bs[w])))

    restored = (cluster.checkpoint_at_minute is not None and n_events > 0
                and bool(np.any(
                    t_flat >= cluster.checkpoint_at_minute * MINUTE)))
    return ClusterResult(
        cold_pct_per_app=100.0 * cold_per_app / np.maximum(inv, 1),
        latencies_s=lat,
        wasted_gb_minutes=float(resident_bs.sum()) / 1e9 / 60.0,
        stats_per_worker=stats,
        restored_mid_run=restored)


# --------------------------------------------------------------------------
# Front door
# --------------------------------------------------------------------------


def run_cluster(workload, policy, cluster: Optional[ClusterSpec] = None, *,
                engine: str = "auto", app_chunk: Optional[int] = None,
                devices=None, max_eviction_rounds: Optional[int] = None,
                exec_s=None, memory_mb=None,
                weight_bytes=None) -> ClusterResult:
    """Run one workload x policy x cluster cell.

    ``workload`` is an :class:`AppTable`, ``WorkloadSpec`` or ``Trace``
    (``exec_s``/``memory_mb``/``weight_bytes`` fill in per-app metadata the
    workload itself does not carry). ``engine="auto"`` picks the vectorized
    engine — including on oversubscribed fleets, where HBM evictions are
    replayed to a fixed point; ``"scalar"`` runs the per-event oracle on
    the same table. ``max_eviction_rounds`` (an ``EngineOptions``-style
    execution knob; default unlimited) caps the total fixed-point
    resolutions — past it the run falls back to the scalar oracle with a
    warning instead of spinning. ``devices`` shards the policy-window
    scan's app rows (see :mod:`repro.distributed.scaleout`; results stay
    bit-identical).
    """
    if engine not in CLUSTER_ENGINES:
        raise ValueError(f"unknown cluster engine {engine!r}; expected one "
                         f"of {CLUSTER_ENGINES}")
    cluster = cluster if cluster is not None else ClusterSpec()
    cluster.validate()
    spec = as_spec(policy)
    table = as_table(workload, exec_s=exec_s, memory_mb=memory_mb,
                     weight_bytes=weight_bytes)
    if engine != "scalar":
        try:
            return _run_vector(table, spec, cluster,
                               app_chunk or DEFAULT_APP_CHUNK,
                               devices=devices,
                               max_eviction_rounds=max_eviction_rounds)
        except EvictionRoundsExceeded as e:
            warnings.warn(
                f"{e}; falling back to engine='scalar' (raise "
                f"max_eviction_rounds to keep the vectorized engine)",
                RuntimeWarning, stacklevel=2)
    sim = ClusterSim(table.to_registry(), spec, cluster.to_config())
    return sim.run(table.to_trace())


@dataclasses.dataclass
class ClusterSweep:
    """A (T, S, C) grid: policy x cluster sweeps over T workloads.

    ``results[t][s][c]`` is the :class:`ClusterResult` of workload ``t``
    under policy spec ``s`` on cluster shape ``c`` — each cell identical to
    the corresponding single :func:`run_cluster` call.
    """
    tables: List[AppTable]
    specs: List[PolicySpec]
    clusters: List[ClusterSpec]
    results: List[List[List[ClusterResult]]]

    @property
    def shape(self):
        return (len(self.tables), len(self.specs), len(self.clusters))

    def row(self, t: int, s: int, c: int = 0) -> ClusterResult:
        return self.results[t][s][c]


def sweep_cluster(workloads: Union[Sequence, object], specs: Sequence,
                  clusters: Optional[Sequence[ClusterSpec]] = None, *,
                  engine: str = "auto", app_chunk: Optional[int] = None,
                  devices=None,
                  max_eviction_rounds: Optional[int] = None) -> ClusterSweep:
    """Evaluate the full workload x policy x cluster grid.

    Each workload is converted to a columnar :class:`AppTable` ONCE and
    reused across every (policy, cluster) cell.
    """
    if not isinstance(workloads, (list, tuple)):
        workloads = [workloads]
    specs = [as_spec(s) for s in specs]
    clusters = list(clusters) if clusters is not None else [ClusterSpec()]
    if not specs or not clusters or not len(workloads):
        raise ValueError("sweep_cluster needs at least one workload, one "
                         "PolicySpec and one ClusterSpec")
    tables = [as_table(w) for w in workloads]
    results = [[[run_cluster(tab, s, c, engine=engine, app_chunk=app_chunk,
                             devices=devices,
                             max_eviction_rounds=max_eviction_rounds)
                 for c in clusters] for s in specs] for tab in tables]
    return ClusterSweep(tables=tables, specs=specs, clusters=clusters,
                        results=results)
