"""Request scheduler: admission, per-endpoint queues, continuous batching.

Sits between the trace/front door and the engine: requests for the same
endpoint are batched (decode steps run one batched `serve_step` across all
active sequences of that endpoint — continuous batching), subject to a
max batch size and a queueing delay budget. Cold endpoints are routed
through the warm pool first; the scheduler exposes the arrival events the
policy needs (`on_request` / `on_request_end`).

Fleet-level placement (which worker's scheduler a request reaches) lives
one layer up, in the cluster engines: the per-event oracle
(:mod:`repro.serving.cluster_sim`) and the columnar engine
(:mod:`repro.serving.cluster_vector`), both driven by the balancing modes
on :class:`repro.serving.cluster_vector.ClusterSpec`.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict, deque
from typing import Callable, Deque, Dict, List, Optional, Tuple

from .warmpool import WarmPool


@dataclasses.dataclass
class Request:
    app_id: str
    arrival_s: float
    exec_s: float                 # service demand once running
    id: int = 0
    start_s: float = -1.0
    finish_s: float = -1.0

    @property
    def latency(self) -> float:
        return self.finish_s - self.arrival_s


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 8            # continuous-batching width per endpoint
    batch_wait_s: float = 0.005   # max time to hold a request for batching
    batch_efficiency: float = 0.85  # batched step cost vs sum of singles


class Scheduler:
    """Discrete-event scheduler over one worker's endpoints."""

    def __init__(self, pool: WarmPool, cfg: SchedulerConfig = SchedulerConfig()):
        self.pool = pool
        self.cfg = cfg
        self.queues: Dict[str, Deque[Request]] = defaultdict(deque)
        self.busy_until: Dict[str, float] = defaultdict(float)
        self.completed: List[Request] = []
        self._next_id = 0

    def submit(self, app_id: str, arrival_s: float, exec_s: float) -> Request:
        r = Request(app_id=app_id, arrival_s=arrival_s, exec_s=exec_s,
                    id=self._next_id)
        self._next_id += 1
        self.queues[app_id].append(r)
        return r

    def _drain_endpoint(self, app_id: str, now: float) -> float:
        """Run queued requests for one endpoint in batches; returns the time
        the endpoint becomes idle."""
        q = self.queues[app_id]
        t = max(now, self.busy_until[app_id])
        while q:
            batch = []
            while q and len(batch) < self.cfg.max_batch:
                batch.append(q.popleft())
            was_cold, startup = self.pool.on_request(app_id, t)
            # batched execution: dominated by the longest member, padded by
            # the batching efficiency factor
            span = max(r.exec_s for r in batch) * (
                1.0 + self.cfg.batch_efficiency * (len(batch) - 1)
                / max(len(batch), 1))
            start = t + startup + self.cfg.batch_wait_s
            for r in batch:
                r.start_s = start
                r.finish_s = start + span
                self.completed.append(r)
            t = start + span
            self.pool.on_request_end(app_id, t)
        self.busy_until[app_id] = t
        return t

    def run(self, events: List[Tuple[float, str, float]]) -> List[Request]:
        """events: sorted (arrival_s, app_id, exec_s). Returns completions.

        Arrivals within ``batch_wait_s`` of each other are admitted together
        before their endpoints drain — this is what forms decode batches.
        """
        i = 0
        n = len(events)
        while i < n:
            t0 = events[i][0]
            touched = []
            while i < n and events[i][0] <= t0 + self.cfg.batch_wait_s:
                arrival, app_id, exec_s = events[i]
                self.submit(app_id, arrival, exec_s)
                touched.append(app_id)
                i += 1
            for app_id in dict.fromkeys(touched):
                self._drain_endpoint(app_id, t0)
        return self.completed
