"""Serving-cluster discrete-event simulator (the §5.3 OpenWhisk analog).

Replays an invocation trace against a fleet of invoker workers, each with
an HBM budget and a warm pool driven by a cold-start policy. Includes
straggler mitigation (hedged requests — see `repro.runtime.straggler`) and
controller fault injection (the policy/warm-pool state is checkpointed and
restored mid-run, demonstrating that learned windows survive restarts).

Outputs the same metrics the paper reports: per-app cold-start %, wasted
(resident-idle) memory time, plus latency distributions from the cold-start
cost model.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.policy import FixedKeepAlivePolicy, HybridHistogramPolicy, Policy
from ..core.workload import Trace
from ..runtime.straggler import HedgePolicy
from .apptable import fnv1a64
from .registry import ModelEndpoint, Registry
from .warmpool import WarmPool

MINUTE = 60.0


@dataclasses.dataclass
class ClusterConfig:
    n_workers: int = 18                  # paper: 18 invoker VMs
    hbm_budget_bytes: float = 16e9       # per worker (v5e HBM)
    hedge: Optional[HedgePolicy] = None
    checkpoint_at_minute: Optional[float] = None   # controller fault injection
    balancing: str = "affinity"          # "affinity" | "hash"


@dataclasses.dataclass
class ClusterResult:
    cold_pct_per_app: np.ndarray
    latencies_s: np.ndarray
    wasted_gb_minutes: float
    stats_per_worker: List[dict]
    restored_mid_run: bool = False

    @property
    def cold_pct_p75(self) -> float:
        return float(np.percentile(self.cold_pct_per_app, 75))

    @property
    def evictions(self) -> int:
        """Total HBM-pressure evictions across the fleet."""
        return int(sum(s["evictions"] for s in self.stats_per_worker))

    @property
    def budget_overflows(self) -> int:
        """Loads that proceeded over budget (nothing left to evict)."""
        return int(sum(s.get("budget_overflows", 0)
                       for s in self.stats_per_worker))

    def latency_pct(self, q: float) -> float:
        return float(np.percentile(self.latencies_s, q))


class ClusterSim:
    """Controller + N invoker workers, each with its own warm pool.

    ``policy`` is a declarative PolicySpec (repro.core.experiment) — every
    worker builds its own stateful policy from it — or, for backward
    compatibility, a zero-arg factory returning ``Policy`` objects.
    """

    def __init__(self, registry: Registry, policy, cfg: ClusterConfig):
        if cfg.balancing not in ("affinity", "hash"):
            raise ValueError(f"unknown balancing {cfg.balancing!r}; "
                             "use 'affinity' or 'hash'")
        self.registry = registry
        self.cfg = cfg
        make_policy = policy if callable(policy) else policy.build
        self.pools = [WarmPool(registry, make_policy(),
                               budget_bytes=cfg.hbm_budget_bytes)
                      for _ in range(cfg.n_workers)]
        self._assign: Dict[str, int] = {}
        # Incremental per-worker resident-app counters: every assigned app
        # immediately creates exactly one pool.state entry, so these equal
        # len(pool.state) at each assignment point without a per-event
        # list rebuild over every pool.
        self._loads = [0] * cfg.n_workers

    def _worker_for(self, app_id: str) -> int:
        # Affinity load-balancer: an app sticks to one worker (maximizes
        # warm hits), assigned by least-loaded-at-first-sight. Hash mode is
        # the stateless alternative (FNV-1a, no controller state).
        w = self._assign.get(app_id)
        if w is None:
            if self.cfg.balancing == "hash":
                w = fnv1a64(app_id) % self.cfg.n_workers
            else:
                w = int(np.argmin(self._loads))
                self._loads[w] += 1
            self._assign[app_id] = w
        return w

    def run(self, trace, exec_time_s: Optional[Dict[str, float]] = None
            ) -> ClusterResult:
        # Declarative workloads are materialized eagerly: the cluster sim
        # needs per-app AppSpecs (exec times, app ids) alongside the events.
        from ..core.workload_spec import WorkloadSpec
        if isinstance(trace, WorkloadSpec):
            trace = trace.materialize(eager=True)
        if trace.specs is None:
            raise ValueError(
                "ClusterSim needs an eager trace with AppSpecs; use "
                "generate_trace(...), spec.materialize(eager=True), or "
                "AppTable.to_trace() — or run the columnar engine "
                "(repro.serving.cluster_vector) on the padded trace directly")
        # Merge all app invocation streams into one global event queue.
        events: List[Tuple[float, int, str]] = []
        for i, spec in enumerate(trace.specs):
            for t in trace.events(i):
                events.append((float(t) * MINUTE, i, spec.app_id))
        events.sort()

        n_apps = trace.n_apps
        cold = np.zeros(n_apps)
        inv = np.zeros(n_apps)
        lats: List[float] = []
        saved_state = None
        restored = False
        # `is not None`: checkpoint_at_minute=0.0 means "checkpoint at the
        # first event", not "no checkpoint" (a falsy check dropped it).
        ckpt_t = (self.cfg.checkpoint_at_minute * MINUTE
                  if self.cfg.checkpoint_at_minute is not None else None)
        hedge = self.cfg.hedge
        if hedge is not None:
            # One uniform pair per event, indexed by global arrival rank —
            # the same streams the vectorized engine consumes, so both
            # engines see identical stragglers.
            u1, u2 = hedge.event_uniforms(len(events))

        for rank, (t, idx, app_id) in enumerate(events):
            if ckpt_t is not None and t >= ckpt_t and saved_state is None:
                # controller checkpoint + simulated crash + restore
                saved_state = [p.state_dict() for p in self.pools]
                for p, sd in zip(self.pools, saved_state):
                    p.load_state_dict(sd)
                restored = True
            w = self._worker_for(app_id)
            pool = self.pools[w]
            was_cold, start_lat = pool.on_request(app_id, t)
            inv[idx] += 1
            cold[idx] += was_cold
            exec_s = (exec_time_s or {}).get(
                app_id, trace.specs[idx].exec_time_s)
            if hedge is not None:
                exec_s = float(hedge.latency_from_uniforms(
                    exec_s, u1[rank], u2[rank]))
            lats.append(start_lat + exec_s)
            pool.on_request_end(app_id, t + exec_s)

        end = trace.duration_minutes * MINUTE
        stats = [dataclasses.asdict(p.finalize(end)) for p in self.pools]
        wasted = sum(s["resident_byte_seconds"] for s in stats) / 1e9 / 60.0
        return ClusterResult(
            cold_pct_per_app=100.0 * cold / np.maximum(inv, 1),
            latencies_s=np.asarray(lats),
            wasted_gb_minutes=wasted,
            stats_per_worker=stats,
            restored_mid_run=restored,
        )
