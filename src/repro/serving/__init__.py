"""Serving: serverless model platform (paper technique as warm-pool policy).

Fleet simulation lives in two engines: the per-event oracle
(:mod:`repro.serving.cluster_sim`) and the columnar vectorized engine
(:mod:`repro.serving.cluster_vector`, driven by
:class:`repro.serving.apptable.AppTable`).
"""
