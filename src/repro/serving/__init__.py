"""Serving: serverless model platform (paper technique as warm-pool policy)."""
