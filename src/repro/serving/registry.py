"""Model registry: the serverless platform's "application" catalog.

Each endpoint is a deployed model (an application in the paper's sense):
architecture config + weights reference + the cold-start cost model inputs
(weight bytes, estimated compile seconds). The registry is what the warm
pool and scheduler resolve app ids against.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig
from ..models import build

# Cold-start cost model constants (DESIGN.md §2): weights move host->HBM
# over PCIe-class links; a compile-cache miss adds compilation time.
H2D_BANDWIDTH = 25e9          # bytes/s host->device
BASE_LOAD_LATENCY = 0.15      # s — allocation, runtime bookkeeping
COMPILE_MISS_LATENCY = 8.0    # s — XLA compile on executable-cache miss


@dataclasses.dataclass
class ModelEndpoint:
    app_id: str
    cfg: ModelConfig
    seed: int = 0
    replicas: int = 1
    weight_bytes: int = 0          # 0 -> derived from cfg (bf16)
    avg_request_s: float = 0.5     # mean request execution time

    def __post_init__(self):
        if not self.weight_bytes:
            self.weight_bytes = 2 * build(self.cfg).n_params()

    def cold_start_seconds(self, compile_cached: bool) -> float:
        t = BASE_LOAD_LATENCY + self.weight_bytes / H2D_BANDWIDTH
        if not compile_cached:
            t += COMPILE_MISS_LATENCY
        return t


class Registry:
    def __init__(self):
        self._apps: Dict[str, ModelEndpoint] = {}

    def register(self, ep: ModelEndpoint) -> None:
        self._apps[ep.app_id] = ep

    def get(self, app_id: str) -> ModelEndpoint:
        return self._apps[app_id]

    def __contains__(self, app_id: str) -> bool:
        return app_id in self._apps

    def __iter__(self) -> Iterator[ModelEndpoint]:
        return iter(self._apps.values())

    def __len__(self) -> int:
        return len(self._apps)
