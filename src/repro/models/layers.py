"""Shared neural-net layers (pure JAX, param pytrees, no framework).

Conventions:
  * params are nested dicts of jnp arrays; init functions take a PRNG key;
  * activations flow in ``cfg.dtype`` (bf16 on TPU), params are stored fp32
    and cast at use (master-weight training);
  * attention is GQA with RoPE; ``window > 0`` masks to a local band;
  * KV caches are dicts ``{"k": [B, L, Hkv, hd], "v": ..., "pos": i32}``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

Params = Dict


def compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def scan_blocks(body, carry, xs, use_scan: bool = True):
    """lax.scan over stacked layer params, or an unrolled Python loop.

    The unrolled form exists for cost accounting: XLA's cost_analysis counts
    a while-loop body once (not x trip count), so the dry-run lowers shallow
    unrolled variants to measure true per-layer flops/bytes/collectives.
    """
    if use_scan:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


def _init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return scale * jax.random.normal(key, shape, dtype)


def linear_init(key, d_in: int, d_out: int, bias: bool = False) -> Params:
    p = {"w": _init(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def linear(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: [B, S, H, hd]; positions: [B, S] (int)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq       # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA, optional local window, optional cross-attention)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    return {
        "wq": linear_init(ks[0], cfg.d_model, cfg.n_heads * hd, cfg.qkv_bias),
        "wk": linear_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wv": linear_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wo": linear_init(ks[3], cfg.n_heads * hd, cfg.d_model),
    }


# Above this many query rows the reference attention switches to the
# q-blocked (flash-style) path so the S x S score matrix never materializes.
CHUNKED_Q_THRESHOLD = 8192


def _sdpa_chunked(q, k, v, *, causal: bool, window: int, q_offset,
                  kv_len=None, q_block: int = 1024) -> jnp.ndarray:
    """Query-blocked attention: lax.scan over q blocks; each block computes
    complete softmax rows against the full K/V, so no online rescaling is
    needed and the transient is O(bq * Skv) instead of O(Sq * Skv)."""
    from ..distributed import ctx
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    nq = Sq // q_block
    qb = jnp.moveaxis(q.reshape(B, nq, q_block, Hq, hd), 1, 0)

    k_pos = jnp.arange(Skv)[None, None, :]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def body(_, xs):
        qblk, qi = xs                                   # [B,bq,H,d], scalar
        qf = qblk.astype(jnp.float32) / math.sqrt(hd)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        msize = ctx.axis_size("model")
        if Hq % max(msize, 1) == 0:
            logits = ctx.hint(logits, "data", "model", None, None)
        else:
            logits = ctx.hint(logits, "data", None, "model", None)
        q_pos = (qi * q_block + jnp.arange(q_block)[:, None]
                 + jnp.asarray(q_offset).reshape(-1, 1, 1))
        mask = jnp.ones((1, q_block, Skv), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window and window > 0:
            mask &= k_pos > q_pos - window
        if kv_len is not None:
            mask &= k_pos < jnp.asarray(kv_len).reshape(-1, 1, 1)
        logits = jnp.where(mask[:, None, :, :], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(body, None, (qb, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hq, hd)


def _sdpa(q, k, v, *, causal: bool, window: int, q_offset,
          kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Reference scaled-dot-product attention with GQA.

    q: [B, Sq, Hq, hd], k/v: [B, Skv, Hkv, hd]. ``q_offset`` is the absolute
    position of q[0] (scalar or per-batch [B]) so causal masks are correct for
    decode. ``kv_len`` optionally masks out cache positions >= kv_len.

    Sharding: KV heads are broadcast up to the q heads (Megatron-style GQA
    replication — cheap, K/V are small), so the attention matrix shards over
    (batch=data, heads=model); when heads don't divide the model axis the
    query-sequence dim takes it instead (sequence parallelism). The `ctx.hint`
    calls are no-ops outside a mesh.
    """
    from ..distributed import ctx
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    if Sq >= CHUNKED_Q_THRESHOLD and Sq % 1024 == 0:
        return _sdpa_chunked(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, kv_len=kv_len)
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    logits = jnp.einsum("bqhd,bkhd->bhqk", qf, k.astype(jnp.float32))
    msize = ctx.axis_size("model")
    if Hq % max(msize, 1) == 0:
        logits = ctx.hint(logits, "data", "model", None, None)
    else:
        logits = ctx.hint(logits, "data", None, "model", None)

    q_pos = jnp.arange(Sq)[:, None] + jnp.asarray(q_offset).reshape(-1, 1, 1)
    k_pos = jnp.arange(Skv)[None, None, :]
    mask = jnp.ones((1, Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > q_pos - window
    if kv_len is not None:
        mask &= k_pos < jnp.asarray(kv_len).reshape(-1, 1, 1)
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_apply(
    p: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    cache: Optional[Params] = None,
    kv_source: Optional[jnp.ndarray] = None,
    use_rope: bool = True,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Self- or cross-attention.

    cache: if given, decode mode — append this step's K/V at ``cache['pos']``
    and attend over the whole cache. kv_source: cross-attention memory
    (encoder states); K/V come from it and no cache/causality applies.
    """
    B, S, _ = x.shape
    hd = cfg.hd
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    kv_in = kv_source if kv_source is not None else x
    k = linear(p["wk"], kv_in).reshape(B, kv_in.shape[1], cfg.n_kv_heads, hd)
    v = linear(p["wv"], kv_in).reshape(B, kv_in.shape[1], cfg.n_kv_heads, hd)

    if kv_source is None and use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        pos = cache["pos"]
        from ..distributed import dist_decode
        if dist_decode.applicable(cache["k"].shape[1], S):
            # distributed flash-decode: sequence-sharded cache, local write,
            # log-sum-exp merge (see distributed/dist_decode.py)
            out, ck, cv = dist_decode.decode_attention(
                q, k, v, cache["k"], cache["v"], pos)
            new_cache = {"k": ck, "v": cv, "pos": pos + S}
        else:
            # decode: scatter K/V of this step into the cache at `pos`
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), pos, axis=1)
            new_cache = {"k": ck, "v": cv, "pos": pos + S}
            out = _sdpa(q, ck, cv, causal=causal, window=window,
                        q_offset=pos, kv_len=pos + S)
    elif kv_source is not None:
        out = _sdpa(q, k, v, causal=False, window=0, q_offset=0)
    else:
        if cfg.use_kernels and S % 128 == 0 and hd % 8 == 0 and causal and kv_source is None:
            from ..kernels import ops as kops
            out = kops.flash_attention(q, k, v, causal=True, window=window)
        else:
            out = _sdpa(q, k, v, causal=causal, window=window, q_offset=0)
    y = linear(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
    return y, new_cache


def make_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
               dtype) -> Params:
    """Stacked (scan-compatible) KV cache for n_layers attention layers."""
    hd = cfg.hd
    return {
        "k": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wi": linear_init(ks[0], d_model, d_ff),
        "wg": linear_init(ks[1], d_model, d_ff),
        "wo": linear_init(ks[2], d_ff, d_model),
    }


def mlp_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["wo"], jax.nn.silu(linear(p["wg"], x)) * linear(p["wi"], x))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int) -> Params:
    # GPT-style 0.02 scale: keeps tied-unembedding logits O(1) at init.
    return {"table": _init(key, (vocab, d_model), scale=0.02)}


def embed(p: Params, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["table"].astype(x.dtype).T


def softmax_xent_chunked(x: jnp.ndarray, table: jnp.ndarray,
                         labels: jnp.ndarray,
                         mask: Optional[jnp.ndarray] = None,
                         transpose_table: bool = False,
                         chunk: int = 512) -> jnp.ndarray:
    """Cross-entropy WITHOUT materializing the [B, S, V] logits.

    Scans over sequence chunks: each step computes a [B, chunk, V] logit
    block, reduces it to per-token (logz, label-logit) scalars, and discards
    it. For big-vocab models (256k) this removes the dominant memory-traffic
    term of the training step (see EXPERIMENTS.md §Perf cell D).

    x: final hidden [B, S, D]; table: unembedding [V, D] (tied) or head
    weight [D, V] (transpose_table=True).
    """
    from ..distributed import ctx
    B, S, D = x.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    xs = jnp.moveaxis(x.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)

    def body(_, inp):
        xc, lc = inp
        w = table.astype(xc.dtype)
        logits = (xc @ w.T if not transpose_table else xc @ w)
        logits = ctx.hint(logits, "data", None, "model").astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
                  == lc[..., None])
        ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        return None, logz - ll

    body = jax.checkpoint(body)
    _, losses = jax.lax.scan(body, None, (xs, ls))
    loss = jnp.moveaxis(losses, 0, 1).reshape(B, S)
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy in fp32.

    The label logit is extracted with an iota-compare masked reduction (not
    take_along_axis): a gather across the vocab dim would force an all-gather
    of the vocab-sharded logits, whereas the masked reduce partitions cleanly
    (partial sums + a tiny cross-shard reduce).
    """
    from ..distributed import ctx
    logits = ctx.hint(logits, "data", None, "model").astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = (jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
              == labels[..., None])
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    loss = logz - ll
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()
