"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention
[arXiv:2402.19427].

Layer pattern ``(rec, rec, attn)`` repeating (1 local-attention layer per 2
recurrent layers). Every temporal block is followed by a SwiGLU MLP block.

TPU adaptation notes:
  * the RG-LRU linear recurrence ``h_t = a_t*h_{t-1} + b_t`` is evaluated
    with ``jax.lax.associative_scan`` (log-depth) over the sequence —
    the Pallas kernel (`repro.kernels.rglru_scan`) does the same within
    VMEM-resident blocks and carries the state across blocks sequentially;
  * local attention uses *blocked banded* attention for full sequences
    (each query block attends to its own + previous key block) and a
    **ring-buffer KV cache** of size ``window`` for decode, so the
    long_500k cell needs O(window), not O(seq), memory.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from ..distributed import ctx

Params = Dict
_C = 8.0  # RG-LRU "c" constant


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def rglru_scan_ref(x_gated, a, h0=None):
    """h_t = a_t * h_{t-1} + b_t with b = sqrt(1-a^2) * x_gated.

    x_gated, a: [B, L, D]. Returns (h [B,L,D], h_last [B,D]).
    """
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x_gated
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h, h[:, -1]


def rglru_decode(h, x_gated, a):
    """One-step recurrence. h, x_gated, a: [B, D]."""
    return a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x_gated


def rec_init(key, cfg: ModelConfig) -> Params:
    D = cfg.d_model
    DR = cfg.rglru_d_rnn or cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "ln": L.rmsnorm_init(D),
        "wx": L.linear_init(ks[0], D, DR),
        "wy": L.linear_init(ks[1], D, DR),
        "conv_w": 0.1 * jax.random.normal(ks[2], (cfg.conv_width, DR), jnp.float32),
        "conv_b": jnp.zeros((DR,), jnp.float32),
        "wa": L.linear_init(ks[3], DR, DR),          # recurrence gate
        "wi": L.linear_init(ks[4], DR, DR),          # input gate
        "lam": 0.5 * jax.random.normal(ks[5], (DR,), jnp.float32) - 4.0,
        "out": L.linear_init(ks[6], DR, D),
    }


def _conv1d(x, w, b):
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pad[:, i: i + x.shape[1], :] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _rglru_gates(p, u):
    """u: [..., DR] conv output -> (a, gated_input) in fp32."""
    r = jax.nn.sigmoid(L.linear(p["wa"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(L.linear(p["wi"], u).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    return a, i * u.astype(jnp.float32)


def rec_apply(cfg: ModelConfig, p: Params, x, state: Optional[Params] = None,
              use_kernel: bool = False):
    """Recurrent temporal block. state: dict(h [B,DR], conv [B,W-1,DR])."""
    h_in = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    gate = jax.nn.gelu(L.linear(p["wy"], h_in))
    u = L.linear(p["wx"], h_in)
    new_state = None
    if state is None:
        u_raw = u
        u = _conv1d(u, p["conv_w"], p["conv_b"])
        a, b_in = _rglru_gates(p, u)
        if use_kernel and cfg.use_kernels and x.shape[1] % 128 == 0:
            from ..kernels import ops as kops
            h, h_last = kops.rglru_scan(b_in, a)
        else:
            h, h_last = rglru_scan_ref(b_in, a)
        W = cfg.conv_width
        new_state = {"h": h_last, "conv": u_raw[:, u.shape[1] - (W - 1):, :]}
    else:
        conv_buf = jnp.concatenate([state["conv"], u], axis=1)
        u1 = jnp.einsum("bwc,wc->bc", conv_buf, p["conv_w"].astype(x.dtype))
        u1 = u1 + p["conv_b"].astype(x.dtype)
        a, b_in = _rglru_gates(p, u1[:, None])
        h1 = rglru_decode(state["h"], b_in[:, 0], a[:, 0])
        h = h1[:, None]
        new_state = {"h": h1, "conv": conv_buf[:, 1:]}
    y = h.astype(x.dtype) * gate
    return x + L.linear(p["out"], y), new_state


# ---------------------------------------------------------------------------
# Local attention with ring-buffer cache
# ---------------------------------------------------------------------------

def attn_apply_local(cfg: ModelConfig, p: Params, x, positions, window,
                     ring: Optional[Params] = None):
    """Full-seq: banded attention via window mask (flash kernel skips
    out-of-window blocks). Decode: ring-buffer cache of size ``window``."""
    if ring is None:
        return L.attention_apply(p, cfg, x, positions, causal=True,
                                 window=window)
    B, S, _ = x.shape
    hd = cfg.hd
    q = L.linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)
    k = L.linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = L.linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)

    pos = ring["pos"]                       # absolute position of this token
    slot = jnp.mod(pos, window)
    ck = jax.lax.dynamic_update_slice_in_dim(ring["k"], k.astype(ring["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(ring["v"], v.astype(ring["v"].dtype), slot, axis=1)
    # absolute position held by each slot j after the write
    j = jnp.arange(window)
    abs_pos = pos - jnp.mod(slot - j, window)
    valid = abs_pos >= 0
    import math
    qf = q.astype(jnp.float32) / math.sqrt(hd)
    group = cfg.n_heads // cfg.n_kv_heads
    qf = qf.reshape(B, S, cfg.n_kv_heads, group, hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, ck.astype(jnp.float32))
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, cv.astype(jnp.float32))
    out = out.reshape(B, S, cfg.n_heads, hd).astype(x.dtype)
    y = L.linear(p["wo"], out.reshape(B, S, cfg.n_heads * hd))
    return y, {"k": ck, "v": cv, "pos": pos + 1}


def make_ring(cfg: ModelConfig, batch: int, window: int, n_attn: int, dtype):
    hd = cfg.hd
    return {
        "k": jnp.zeros((n_attn, batch, window, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_attn, batch, window, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Super-block = (rec + mlp, rec + mlp, attn + mlp)
# ---------------------------------------------------------------------------

def sblock_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 7)
    return {
        "rec1": rec_init(ks[0], cfg),
        "mlp1": {"ln": L.rmsnorm_init(cfg.d_model),
                 "ffn": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff)},
        "rec2": rec_init(ks[2], cfg),
        "mlp2": {"ln": L.rmsnorm_init(cfg.d_model),
                 "ffn": L.mlp_init(ks[3], cfg.d_model, cfg.d_ff)},
        "attn_ln": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[4], cfg),
        "mlp3": {"ln": L.rmsnorm_init(cfg.d_model),
                 "ffn": L.mlp_init(ks[5], cfg.d_model, cfg.d_ff)},
    }


def _mlp_res(cfg, p, x):
    return x + L.mlp_apply(p["ffn"], L.rmsnorm(p["ln"], x, cfg.norm_eps))


def sblock_apply(cfg: ModelConfig, p: Params, x, positions, state=None,
                 use_kernel=False):
    """state: None or dict(h1, conv1, h2, conv2, ring_k, ring_v)."""
    st = state or {}
    x, s1 = rec_apply(cfg, p["rec1"], x,
                      state=None if state is None else
                      {"h": st["h1"], "conv": st["conv1"]},
                      use_kernel=use_kernel)
    x = _mlp_res(cfg, p["mlp1"], x)
    x, s2 = rec_apply(cfg, p["rec2"], x,
                      state=None if state is None else
                      {"h": st["h2"], "conv": st["conv2"]},
                      use_kernel=use_kernel)
    x = _mlp_res(cfg, p["mlp2"], x)
    xa = L.rmsnorm(p["attn_ln"], x, cfg.norm_eps)
    if state is None:
        h, _ = attn_apply_local(cfg, p["attn"], xa, positions, cfg.attn_window)
        # Fill the ring buffer with the last `window` keys/values so decode
        # continues seamlessly after a full-sequence prefill.
        B, S, _ = xa.shape
        win = cfg.attn_window
        hd = cfg.hd
        tail_len = min(S, win)
        xt = xa[:, S - tail_len:]
        pt = positions[:, S - tail_len:]
        kt = L.rope(L.linear(p["attn"]["wk"], xt).reshape(B, tail_len, cfg.n_kv_heads, hd),
                    pt, cfg.rope_theta)
        vt = L.linear(p["attn"]["wv"], xt).reshape(B, tail_len, cfg.n_kv_heads, hd)
        slots = (jnp.arange(S - tail_len, S)) % win
        rk = jnp.zeros((B, win, cfg.n_kv_heads, hd), x.dtype).at[:, slots].set(kt)
        rv = jnp.zeros((B, win, cfg.n_kv_heads, hd), x.dtype).at[:, slots].set(vt)
        new_state = {"h1": s1["h"], "conv1": s1["conv"],
                     "h2": s2["h"], "conv2": s2["conv"],
                     "ring_k": rk, "ring_v": rv}
    else:
        ring = {"k": st["ring_k"], "v": st["ring_v"], "pos": st["pos"]}
        h, nring = attn_apply_local(cfg, p["attn"], xa, positions,
                                    cfg.attn_window, ring=ring)
        new_state = {"h1": s1["h"], "conv1": s1["conv"],
                     "h2": s2["h"], "conv2": s2["conv"],
                     "ring_k": nring["k"], "ring_v": nring["v"]}
    x = x + h
    x = _mlp_res(cfg, p["mlp3"], x)
    return x, new_state


# ---------------------------------------------------------------------------
# Model: n_super superblocks + trailing recurrent layers
# ---------------------------------------------------------------------------

def _structure(cfg: ModelConfig) -> Tuple[int, int]:
    pat = len(cfg.block_pattern) or 3
    n_super = cfg.n_layers // pat
    n_tail = cfg.n_layers - n_super * pat   # trailing rec layers
    return n_super, n_tail


def init(cfg: ModelConfig, key) -> Params:
    n_super, n_tail = _structure(cfg)
    keys = jax.random.split(key, n_super + n_tail + 2)
    p = {
        "embed": L.embedding_init(keys[-2], cfg.vocab, cfg.d_model),
        "blocks": jax.vmap(lambda k: sblock_init(k, cfg))(keys[:n_super]),
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }
    for i in range(n_tail):
        ks = jax.random.split(keys[n_super + i], 2)
        p[f"tail_rec{i}"] = rec_init(ks[0], cfg)
        p[f"tail_mlp{i}"] = {"ln": L.rmsnorm_init(cfg.d_model),
                             "ffn": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff)}
    return p


def forward(cfg: ModelConfig, params: Params, tokens):
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    n_super, n_tail = _structure(cfg)

    def body(x, bp):
        x, _ = sblock_apply(cfg, bp, x, positions, use_kernel=True)
        return ctx.hint(x, "data", "model", None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = L.scan_blocks(body, x, params["blocks"], cfg.scan_layers)
    for i in range(n_tail):
        x, _ = rec_apply(cfg, params[f"tail_rec{i}"], x, use_kernel=True)
        x = _mlp_res(cfg, params[f"tail_mlp{i}"], x)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict):
    logits = forward(cfg, params, batch["tokens"])
    return L.softmax_xent(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, dtype):
    n_super, n_tail = _structure(cfg)
    DR = cfg.rglru_d_rnn or cfg.d_model
    W = cfg.conv_width
    win = cfg.attn_window
    hd = cfg.hd
    blocks = {
        "h1": jnp.zeros((n_super, batch, DR), jnp.float32),
        "conv1": jnp.zeros((n_super, batch, W - 1, DR), dtype),
        "h2": jnp.zeros((n_super, batch, DR), jnp.float32),
        "conv2": jnp.zeros((n_super, batch, W - 1, DR), dtype),
        "ring_k": jnp.zeros((n_super, batch, win, cfg.n_kv_heads, hd), dtype),
        "ring_v": jnp.zeros((n_super, batch, win, cfg.n_kv_heads, hd), dtype),
    }
    tail = {
        f"tail{i}": {"h": jnp.zeros((batch, DR), jnp.float32),
                     "conv": jnp.zeros((batch, W - 1, DR), dtype)}
        for i in range(n_tail)
    }
    return {"blocks": blocks, "tail": tail, "pos": jnp.zeros((), jnp.int32)}


def prefill(cfg: ModelConfig, params: Params, tokens, max_len: int, embeds=None):
    """Prompt pass; returns last-token logits + recurrent/ring state.

    For simplicity the ring buffer after prefill holds the last ``window``
    keys laid out by absolute-position mod window (recomputed cheaply here).
    """
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    n_super, n_tail = _structure(cfg)
    cache = init_cache(cfg, B, dtype)

    def body(x, xs):
        bp, st = xs
        x, ns = sblock_apply(cfg, bp, x, positions, use_kernel=True)
        # full-seq pass produces rec states; ring stays zero-filled (only the
        # next `window` decode steps need it, and the mask handles validity)
        merged = dict(st)
        merged.update({k: v for k, v in ns.items() if k in st})
        return ctx.hint(x, "data", "model", None), merged

    if cfg.remat:
        body = jax.checkpoint(body)
    x, bstates = L.scan_blocks(body, x, (params["blocks"], cache["blocks"]),
                               cfg.scan_layers)
    tail_state = {}
    for i in range(n_tail):
        x, s = rec_apply(cfg, params[f"tail_rec{i}"], x, use_kernel=True)
        x = _mlp_res(cfg, params[f"tail_mlp{i}"], x)
        tail_state[f"tail{i}"] = s
    x = L.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return logits, {"blocks": bstates, "tail": tail_state,
                    "pos": jnp.asarray(S, jnp.int32)}


def decode_step(cfg: ModelConfig, params: Params, token, cache):
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], token[:, None], dtype)
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    n_super, n_tail = _structure(cfg)

    def body(x, xs):
        bp, st = xs
        st = dict(st, pos=pos)
        x, ns = sblock_apply(cfg, bp, x, positions, state=st)
        return x, ns

    x, bstates = L.scan_blocks(body, x, (params["blocks"], cache["blocks"]),
                               cfg.scan_layers)
    tail_state = {}
    for i in range(n_tail):
        x, s = rec_apply(cfg, params[f"tail_rec{i}"], x,
                         state=cache["tail"][f"tail{i}"])
        x = _mlp_res(cfg, params[f"tail_mlp{i}"], x)
        tail_state[f"tail{i}"] = s
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return logits, {"blocks": bstates, "tail": tail_state, "pos": pos + 1}
