"""Unified model interface over all architecture families.

``Model(cfg)`` exposes:
  * ``init(key)``                              — parameter pytree
  * ``loss(params, batch)``                    — scalar LM loss (train)
  * ``forward(params, ...)``                   — full-seq logits
  * ``prefill(params, tokens, max_len, ...)``  — (logits, cache/state)
  * ``decode_step(params, token, cache)``      — (logits, cache/state)
  * ``input_specs(shape)``                     — ShapeDtypeStruct stand-ins
    for every input of the step the shape exercises (used by the dry-run:
    weak-type-correct, shardable, no device allocation)
  * ``make_serve_state(shape)``                — cache specs for decode cells
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from . import layers as L
from . import mamba2, moe, rglru, transformer

Params = Dict


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        fam = cfg.family
        if fam == "dense":
            self._m = transformer
        elif fam == "moe":
            self._m = moe
        elif fam == "ssm":
            self._m = mamba2
        elif fam == "hybrid":
            self._m = rglru
        elif fam == "encdec":
            self._m = transformer  # enc-dec entry points below
        else:
            raise ValueError(f"unknown family {fam}")

    # -- parameters -----------------------------------------------------------

    def init(self, key) -> Params:
        if self.cfg.family == "encdec":
            return transformer.encdec_init(self.cfg, key)
        return self._m.init(self.cfg, key)

    def param_count(self, params: Params) -> int:
        return int(sum(np.prod(p.shape) for p in jax.tree.leaves(params)))

    # -- steps ----------------------------------------------------------------

    def loss(self, params: Params, batch: Dict) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.family == "encdec":
            return transformer.encdec_loss(cfg, params, batch)
        return self._m.loss_fn(cfg, params, batch)

    def forward(self, params: Params, tokens=None, embeds=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return transformer.encdec_forward(cfg, params, tokens, embeds)
        if cfg.family == "dense":
            return transformer.forward(cfg, params, tokens, embeds)
        return self._m.forward(cfg, params, tokens)

    def prefill(self, params: Params, tokens, max_len: int, embeds=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            return transformer.encdec_prefill(cfg, params, tokens, max_len,
                                              embeds=embeds)
        return self._m.prefill(cfg, params, tokens, max_len, embeds=embeds)

    def decode_step(self, params: Params, token, cache):
        cfg = self.cfg
        if cfg.family == "encdec":
            return transformer.encdec_decode_step(cfg, params, token, cache)
        return self._m.decode_step(cfg, params, token, cache)

    # -- dry-run stand-ins ----------------------------------------------------

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the step this shape lowers.

        train/prefill: the full batch. decode: one new token per sequence.
        Modality frontends are STUBS — ``embeds`` are precomputed frame/patch
        embeddings with the model's d_model.
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        f32 = jnp.float32
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        specs: Dict[str, Any] = {}
        if shape.kind == "train":
            if cfg.frontend == "vision":
                s_text = S - cfg.frontend_tokens
                specs["tokens"] = sds((B, s_text), i32)
                specs["embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), f32)
                specs["labels"] = sds((B, s_text), i32)
            elif cfg.family == "encdec":
                specs["tokens"] = sds((B, S), i32)
                specs["embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), f32)
                specs["labels"] = sds((B, S), i32)
            else:
                specs["tokens"] = sds((B, S), i32)
                specs["labels"] = sds((B, S), i32)
        elif shape.kind == "prefill":
            if cfg.frontend == "vision":
                specs["tokens"] = sds((B, S - cfg.frontend_tokens), i32)
                specs["embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), f32)
            elif cfg.family == "encdec":
                specs["tokens"] = sds((B, S), i32)
                specs["embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model), f32)
            else:
                specs["tokens"] = sds((B, S), i32)
        else:  # decode: one new token against a cache of length S
            specs["token"] = sds((B,), i32)
            specs["cache"] = self.cache_specs(B, S)
        return specs

    def cache_specs(self, batch: int, kv_len: int):
        """ShapeDtypeStructs for the decode cache at a given KV length."""
        cfg = self.cfg
        dtype = L.compute_dtype(cfg)
        sds = jax.ShapeDtypeStruct
        as_spec = lambda t: jax.tree.map(
            lambda x: sds(x.shape, x.dtype), t)
        if cfg.family == "ssm":
            st = mamba2.init_state(cfg, batch, dtype)
            return {**as_spec(st), "pos": sds((), jnp.int32)}
        if cfg.family == "hybrid":
            return as_spec(rglru.init_cache(cfg, batch, dtype))
        hd = cfg.hd
        cache = {
            "k": sds((cfg.n_layers, batch, kv_len, cfg.n_kv_heads, hd), dtype),
            "v": sds((cfg.n_layers, batch, kv_len, cfg.n_kv_heads, hd), dtype),
            "pos": sds((), jnp.int32),
        }
        if cfg.family == "encdec":
            cache["enc"] = sds((batch, cfg.frontend_tokens, cfg.d_model), dtype)
        return cache

    def make_inputs(self, shape: ShapeConfig, key=None, concrete_batch=None):
        """Concrete random inputs matching input_specs (smoke tests)."""
        key = key if key is not None else jax.random.PRNGKey(0)
        specs = self.input_specs(shape if concrete_batch is None else
                                 dataclasses.replace(shape, global_batch=concrete_batch))
        out = {}
        for name, spec in specs.items():
            if name == "cache":
                out[name] = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)
            elif spec.dtype == jnp.int32:
                key, k = jax.random.split(key)
                out[name] = jax.random.randint(k, spec.shape, 0, self.cfg.vocab, jnp.int32)
            else:
                key, k = jax.random.split(key)
                out[name] = 0.02 * jax.random.normal(k, spec.shape, spec.dtype)
        return out

    # -- analytic model flops (roofline §: MODEL_FLOPS) -----------------------

    def n_params(self, active_only: bool = False) -> int:
        """Analytic parameter count (active = top_k experts only for MoE)."""
        cfg = self.cfg
        D, V = cfg.d_model, cfg.vocab
        hd = cfg.hd
        attn = D * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * D
        if cfg.family == "dense":
            per_layer = attn + 3 * D * cfg.d_ff
            total = cfg.n_layers * per_layer + V * D * (1 if cfg.tie_embeddings else 2)
        elif cfg.family == "moe":
            e = cfg.top_k if active_only else cfg.n_experts
            per_layer = attn + e * 3 * D * cfg.d_expert + D * cfg.n_experts
            total = cfg.n_layers * per_layer + 2 * V * D
        elif cfg.family == "ssm":
            DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
            per_layer = D * (2 * DI + 2 * N + H) + DI * D
            total = cfg.n_layers * per_layer + V * D
        elif cfg.family == "hybrid":
            DR = cfg.rglru_d_rnn or D
            rec = 2 * D * DR + 2 * DR * DR + DR * D
            mlp = 3 * D * cfg.d_ff
            n_super, n_tail = rglru._structure(cfg)
            total = (n_super * (2 * rec + attn + 3 * mlp) +
                     n_tail * (rec + mlp) + V * D)
        elif cfg.family == "encdec":
            per_enc = attn + 3 * D * cfg.d_ff
            per_dec = 2 * attn + 3 * D * cfg.d_ff
            total = (cfg.n_encoder_layers * per_enc + cfg.n_layers * per_dec
                     + 2 * V * D)
        else:
            raise ValueError(cfg.family)
        return int(total)


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
