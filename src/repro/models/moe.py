"""Mixture-of-Experts transformer (Qwen3-MoE / OLMoE family).

The FFN of every block is a top-k routed MoE. Dispatch follows the GShard
capacity-based algorithm (groups of ``moe_group_size`` tokens, capacity
``ceil(top_k * T * cf / E)`` slots per expert per group, overflow dropped):

  * ``moe_impl="einsum"`` — the classical dense dispatch/combine einsum
    ([G,T,E,C] one-hot). Paper-standard baseline; flops-heavy but maps
    directly onto the MXU.
  * ``moe_impl="gather"`` — index-based dispatch (take/segment-sum) with the
    same routing semantics and far fewer flops; the beyond-paper optimized
    path (see EXPERIMENTS.md §Perf).

Expert weights are stacked ``[E, d_model, d_expert]`` and shard naturally
over the ``model`` mesh axis (expert parallelism).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from ..distributed import ctx
from .transformer import _logits, block_init

Params = Dict

MOE_IMPL = "einsum"  # module default; overridden via cfg-like plumbing


def moe_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_expert
    s_in = 1.0 / jnp.sqrt(D)
    s_out = 1.0 / jnp.sqrt(F)
    return {
        "router": {"w": s_in * jax.random.normal(ks[0], (D, E), jnp.float32)},
        "wi": s_in * jax.random.normal(ks[1], (E, D, F), jnp.float32),
        "wg": s_in * jax.random.normal(ks[2], (E, D, F), jnp.float32),
        "wo": s_out * jax.random.normal(ks[3], (E, F, D), jnp.float32),
    }


def _route(cfg: ModelConfig, p: Params, xg: jnp.ndarray):
    """Router + slot assignment. xg: [G, T, D] -> gating structures."""
    G, T, D = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(int(cfg.moe_capacity_factor * k * T / E), 1)

    logits = (xg.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                       # [G,T,E]
    topv, topi = jax.lax.top_k(gates, k)                          # [G,T,k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # Slot positions: iterate the k choices in priority order, tracking how
    # many tokens each expert has admitted so far in the group.
    counts = jnp.zeros((G, E), jnp.int32)
    pos_list, keep_list = [], []
    for j in range(k):
        e_j = topi[..., j]                                        # [G,T]
        onehot = jax.nn.one_hot(e_j, E, dtype=jnp.int32)          # [G,T,E]
        prior = jnp.cumsum(onehot, axis=1) - onehot               # tokens ahead
        pos = (prior + counts[:, None, :] )                       # [G,T,E]
        pos_j = jnp.take_along_axis(pos, e_j[..., None], axis=-1)[..., 0]
        keep_j = pos_j < C
        counts = counts + onehot.sum(axis=1)
        pos_list.append(pos_j)
        keep_list.append(keep_j)
    positions = jnp.stack(pos_list, -1)                           # [G,T,k]
    keep = jnp.stack(keep_list, -1)                               # [G,T,k]

    # Load-balancing auxiliary loss (Switch): E * mean(frac_tokens * frac_prob)
    me = gates.mean(axis=(0, 1))                                  # [E]
    ce = jax.nn.one_hot(topi[..., 0], E).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return topi, topv, positions, keep, C, aux


def _moe_einsum(cfg, p, xg, topi, topv, positions, keep, C):
    """Dense GShard dispatch/combine (baseline)."""
    G, T, D = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = xg.dtype
    # dispatch one-hot [G,T,E,C]
    e_oh = jax.nn.one_hot(topi, E, dtype=dt)                       # [G,T,k,E]
    c_oh = jax.nn.one_hot(positions, C, dtype=dt)                  # [G,T,k,C]
    kd = e_oh * keep[..., None].astype(dt)
    dispatch = jnp.einsum("gtke,gtkc->gtec", kd, c_oh)             # [G,T,E,C]
    dispatch = ctx.hint(dispatch, "data", None, "model", None)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", kd, c_oh, topv.astype(dt))
    combine = ctx.hint(combine, "data", None, "model", None)
    xe = jnp.einsum("gtd,gtec->gecd", xg, dispatch)                # [G,E,C,D]
    xe = ctx.hint(xe, "data", "model", None, None)   # EP: experts over model
    h = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dt))
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(dt))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))       # [G,E,C,D]
    return jnp.einsum("gecd,gtec->gtd", ye, combine)


def _moe_gather(cfg, p, xg, topi, topv, positions, keep, C):
    """Index-based dispatch: same semantics, no [G,T,E,C] one-hot einsums."""
    G, T, D = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = xg.dtype
    # flat slot id for each (token, choice): e * C + pos (or dropped -> E*C)
    slot = jnp.where(keep, topi * C + positions, E * C)            # [G,T,k]
    # scatter tokens into slots: xe [G, E*C+1, D]
    xe = jnp.zeros((G, E * C + 1, D), dt)
    gi = jnp.arange(G)[:, None, None]
    xe = xe.at[gi, slot].add(xg[:, :, None, :] * keep[..., None].astype(dt))
    xe = xe[:, : E * C].reshape(G, E, C, D)
    xe = ctx.hint(xe, "data", "model", None, None)   # EP: experts over model
    h = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dt))
    h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(dt))
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    ye = ye.reshape(G, E * C, D)
    ye = jnp.concatenate([ye, jnp.zeros((G, 1, D), dt)], axis=1)
    out = jnp.take_along_axis(ye, slot.reshape(G, T * k)[..., None], axis=1)
    out = out.reshape(G, T, k, D) * topv[..., None].astype(dt)
    return out.sum(axis=2)


def moe_apply(cfg: ModelConfig, p: Params, x: jnp.ndarray,
              impl: str = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (y, aux_loss)."""
    impl = impl or cfg.moe_impl or MOE_IMPL
    B, S, D = x.shape
    T = min(cfg.moe_group_size, B * S)
    G = (B * S) // T
    xg = x.reshape(G, T, D)
    topi, topv, positions, keep, C, aux = _route(cfg, p, xg)
    fn = _moe_einsum if impl == "einsum" else _moe_gather
    y = fn(cfg, p, xg, topi, topv, positions, keep, C)
    return y.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# MoE transformer model
# ---------------------------------------------------------------------------

def moe_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "moe": moe_init(ks[1], cfg),
    }


def moe_block_apply(cfg, p, x, positions, cache=None, impl=None):
    h, new_cache = L.attention_apply(
        p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
        cache=cache)
    x = x + h
    h, aux = moe_apply(cfg, p["moe"], L.rmsnorm(p["ln2"], x, cfg.norm_eps),
                       impl=impl)
    return x + h, new_cache, aux


def init(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 2)
    stacked = jax.vmap(lambda k: moe_block_init(k, cfg))(keys[: cfg.n_layers])
    return {
        "embed": L.embedding_init(keys[-2], cfg.vocab, cfg.d_model),
        "layers": stacked,
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "head": L.linear_init(keys[-1], cfg.d_model, cfg.vocab),
    }


def forward(cfg: ModelConfig, params: Params, tokens, impl=None):
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(carry, lp):
        x, aux = carry
        x, _, a = moe_block_apply(cfg, lp, x, positions, impl=impl)
        return (ctx.hint(x, "data", "model", None), aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    (x, aux), _ = L.scan_blocks(body, (x, jnp.zeros((), jnp.float32)),
                                params["layers"], cfg.scan_layers)
    return _logits(cfg, params, x), aux / cfg.n_layers


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict, impl=None):
    logits, aux = forward(cfg, params, batch["tokens"], impl=impl)
    return (L.softmax_xent(logits, batch["labels"], batch.get("mask"))
            + cfg.router_aux_weight * aux)


def prefill(cfg: ModelConfig, params: Params, tokens, max_len: int,
            embeds=None, impl=None):
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cache = L.make_cache(cfg, B, max_len, cfg.n_layers, dtype)

    def body(x, xs):
        lp, ck, cv = xs
        lcache = {"k": ck, "v": cv, "pos": jnp.zeros((), jnp.int32)}
        x, nc, _ = moe_block_apply(cfg, lp, x, positions, cache=lcache, impl=impl)
        return ctx.hint(x, "data", "model", None), (nc["k"], nc["v"])

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = L.scan_blocks(body, x, (params["layers"], cache["k"], cache["v"]),
                                cfg.scan_layers)
    return _logits(cfg, params, x[:, -1:]), {"k": ks, "v": vs,
                                             "pos": jnp.asarray(S, jnp.int32)}


def decode_step(cfg: ModelConfig, params: Params, token, cache, impl=None):
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], token[:, None], dtype)
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)

    def body(x, xs):
        lp, ck, cv = xs
        lcache = {"k": ck, "v": cv, "pos": pos}
        x, nc, _ = moe_block_apply(cfg, lp, x, positions, cache=lcache, impl=impl)
        return x, (nc["k"], nc["v"])

    x, (ks, vs) = L.scan_blocks(body, x, (params["layers"], cache["k"], cache["v"]),
                                cfg.scan_layers)
    return _logits(cfg, params, x)[:, 0], {"k": ks, "v": vs, "pos": pos + 1}
