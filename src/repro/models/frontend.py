"""Modality frontend STUBS (DESIGN.md §2, §4).

The assigned [vlm]/[audio] entries specify the transformer BACKBONE only —
per instructions the modality frontend is a stub whose job is to provide
precomputed patch/frame embeddings with the right shapes. These helpers
generate them for examples and smoke tests; `input_specs()` provides the
ShapeDtypeStruct versions for the dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def vision_patches(cfg: ModelConfig, batch: int, key=None) -> jnp.ndarray:
    """Anyres tiling stand-in: `frontend_tokens` patch embeddings per image
    (llava-next: 672x672 anyres -> 2880 patch tokens after projection)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return 0.02 * jax.random.normal(
        key, (batch, cfg.frontend_tokens, cfg.d_model), jnp.float32)


def audio_frames(cfg: ModelConfig, batch: int, key=None) -> jnp.ndarray:
    """w2v-BERT feature-extractor stand-in: `frontend_tokens` frame
    embeddings per utterance (seamless-m4t medium: 1024 frames)."""
    key = key if key is not None else jax.random.PRNGKey(1)
    return 0.02 * jax.random.normal(
        key, (batch, cfg.frontend_tokens, cfg.d_model), jnp.float32)
