"""Mamba-2 (SSD — state-space duality) language model [arXiv:2405.21060].

TPU adaptation: the CUDA selective-scan is replaced by the *chunked SSD
block decomposition* — within a chunk everything is dense matmuls (MXU
friendly); across chunks a tiny [H, N, P] state recurrence is carried with a
``lax.scan``. The same decomposition is what `repro.kernels.ssd_scan`
implements as a Pallas kernel (sequential grid over chunks).

Decode is O(1)/token: state update ``S <- a*S + dt * B ⊗ x`` plus a rolling
causal-conv buffer.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from ..distributed import ctx

Params = Dict


# ---------------------------------------------------------------------------
# SSD core (reference; kernels/ssd_scan.py mirrors this math)
# ---------------------------------------------------------------------------

def _effective_chunk(l: int, chunk: int) -> int:
    c = min(chunk, l)
    while l % c:
        c -= 1
    return max(c, 1)


def ssd_reference(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD scan.

    x:  [b, l, h, p]  (inputs, already dt-scaled outside? NO: raw)
    dt: [b, l, h]     (positive step sizes)
    A:  [h]           (negative decay rates)
    B:  [b, l, n]     (input projection, shared across heads)
    C:  [b, l, n]     (output projection, shared across heads)
    Returns (y [b,l,h,p], final_state [b,h,n,p]).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    chunk = _effective_chunk(l, chunk)
    nc = l // chunk
    xb = x.reshape(b, nc, chunk, h, p)
    dtb = dt.reshape(b, nc, chunk, h)
    Bb = B.reshape(b, nc, chunk, n)
    Cb = C.reshape(b, nc, chunk, n)

    dA = dtb * A[None, None, None, :]             # [b,nc,q,h] (negative)
    cum = jnp.cumsum(dA, axis=2)                  # running log-decay in chunk
    # intra-chunk: M[i,j] = C_i . B_j * exp(cum_i - cum_j) * dt_j  (j <= i)
    CB = jnp.einsum("bcin,bcjn->bcij", Cb, Bb)    # [b,nc,q,q]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [b,nc,i,j,h]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    M = CB[..., None] * decay                     # [b,nc,i,j,h]
    xdt = xb * dtb[..., None]                     # dt-scaled inputs
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xdt)

    # chunk-local states: S_c = sum_j exp(cum_last - cum_j) B_j (dt_j x_j)
    last = cum[:, :, -1:, :]                      # [b,nc,1,h]
    w = jnp.exp(last - cum)                       # [b,nc,q,h]
    S_loc = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bb, w * dtb, xb)

    # inter-chunk recurrence (tiny state [b,h,n,p])
    chunk_decay = jnp.exp(last[:, :, 0, :])       # [b,nc,h]
    init = (jnp.zeros((b, h, n, p), x.dtype) if initial_state is None
            else initial_state)

    def step(S, inputs):
        dec, S_c = inputs                         # [b,h], [b,h,n,p]
        S_new = S * dec[..., None, None] + S_c
        return S_new, S                           # emit state *entering* chunk

    Ss = jnp.moveaxis(S_loc, 1, 0)                # [nc,b,h,n,p]
    decs = jnp.moveaxis(chunk_decay, 1, 0)        # [nc,b,h]
    final, S_in = jax.lax.scan(step, init, (decs, Ss))

    # inter-chunk output: y_i += C_i . (exp(cum_i) * S_entering)
    S_in = jnp.moveaxis(S_in, 0, 1)               # [b,nc,h,n,p]
    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cb, jnp.exp(cum), S_in)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y, final


def ssd_decode_step(S, x, dt, A, B, C):
    """One-token SSD update. S: [b,h,n,p]; x: [b,h,p]; dt: [b,h]; B,C: [b,n]."""
    a = jnp.exp(dt * A[None, :])                                   # [b,h]
    S = S * a[..., None, None] + jnp.einsum("bn,bh,bhp->bhnp", B, dt, x)
    y = jnp.einsum("bn,bhnp->bhp", C, S)
    return y, S


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig) -> Params:
    D, DI, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    conv_dim = DI + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "ln": L.rmsnorm_init(D),
        # in_proj -> [z (DI), xBC (DI + 2N), dt (H)]
        "in_proj": L.linear_init(ks[0], D, 2 * DI + 2 * N + H),
        "conv_w": 0.1 * jax.random.normal(ks[1], (cfg.conv_width, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.rmsnorm_init(DI),
        "out_proj": L.linear_init(ks[2], DI, D),
    }


def _causal_conv(x, w, b):
    """x: [B, L, C]; w: [W, C] depthwise causal conv."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(W):
        out = out + pad[:, i: i + x.shape[1], :] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _split_proj(cfg, proj):
    DI, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    z = proj[..., :DI]
    xBC = proj[..., DI: 2 * DI + 2 * N]
    dt = proj[..., 2 * DI + 2 * N:]
    return z, xBC, dt


def block_apply(cfg: ModelConfig, p: Params, x, state=None, use_kernel=False):
    """state: None (full seq) or dict(ssm [B,H,N,P], conv [B,W-1,convdim])."""
    B_, Lq, D = x.shape
    DI, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    h = L.rmsnorm(p["ln"], x, cfg.norm_eps)
    proj = L.linear(p["in_proj"], h)
    z, xBC, dt = _split_proj(cfg, proj)
    A = -jnp.exp(p["A_log"])

    new_state = None
    if state is None:
        xBC_raw = xBC
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
        xs = xBC[..., :DI].reshape(B_, Lq, H, P)
        Bm = xBC[..., DI: DI + N]
        Cm = xBC[..., DI + N:]
        dts = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        if use_kernel and cfg.use_kernels and Lq % cfg.ssm_chunk == 0:
            from ..kernels import ops as kops
            y, S_fin = kops.ssd_scan(xs, dts, A, Bm, Cm, chunk=cfg.ssm_chunk)
        else:
            y, S_fin = ssd_reference(xs.astype(jnp.float32), dts, A,
                                     Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                                     cfg.ssm_chunk)
        y = y.astype(x.dtype)
        W = cfg.conv_width
        new_state = {"ssm": S_fin.astype(jnp.float32),
                     "conv": xBC_raw[:, Lq - (W - 1):, :]}
    else:
        # decode: roll the conv buffer, single-step SSD
        conv_buf = jnp.concatenate([state["conv"], xBC], axis=1)  # [B, W, C]
        xBC1 = jnp.einsum("bwc,wc->bc", conv_buf,
                          p["conv_w"].astype(x.dtype)) + p["conv_b"].astype(x.dtype)
        xBC1 = jax.nn.silu(xBC1)
        xs = xBC1[..., :DI].reshape(B_, H, P)
        Bm = xBC1[..., DI: DI + N].astype(jnp.float32)
        Cm = xBC1[..., DI + N:].astype(jnp.float32)
        dts = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
        y1, S = ssd_decode_step(state["ssm"], xs.astype(jnp.float32), dts, A, Bm, Cm)
        y = y1[:, None].astype(x.dtype)
        xs = xs[:, None]
        new_state = {"ssm": S, "conv": conv_buf[:, 1:]}

    y = y + xs.reshape(B_, Lq, H, P) * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B_, Lq, DI)
    y = L.rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return x + L.linear(p["out_proj"], y), new_state


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 1)
    stacked = jax.vmap(lambda k: block_init(k, cfg))(keys[: cfg.n_layers])
    return {
        "embed": L.embedding_init(keys[-1], cfg.vocab, cfg.d_model),
        "layers": stacked,
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }


def forward(cfg: ModelConfig, params: Params, tokens):
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)

    def body(x, lp):
        x, _ = block_apply(cfg, lp, x, use_kernel=True)
        return ctx.hint(x, "data", "model", None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = L.scan_blocks(body, x, params["layers"], cfg.scan_layers)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict):
    logits = forward(cfg, params, batch["tokens"])
    return L.softmax_xent(logits, batch["labels"], batch.get("mask"))


def init_state(cfg: ModelConfig, batch: int, dtype) -> Params:
    N, H, P = cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_dim = cfg.d_inner + 2 * N
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, conv_dim), dtype),
    }


def prefill(cfg: ModelConfig, params: Params, tokens, max_len: int, embeds=None):
    """Process the prompt; return (last logits, recurrent state).

    The state is O(1) in sequence length — this is what makes long_500k
    viable for this family.
    """
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)

    def body(x, lp):
        x, ns = block_apply(cfg, lp, x, use_kernel=True)
        return ctx.hint(x, "data", "model", None), (ns["ssm"], ns["conv"])

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ssms, convs) = L.scan_blocks(body, x, params["layers"], cfg.scan_layers)
    x = L.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return logits, {"ssm": ssms, "conv": convs,
                    "pos": jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_step(cfg: ModelConfig, params: Params, token, cache):
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], token[:, None], dtype)

    def body(x, xs):
        lp, ssm, conv = xs
        x, ns = block_apply(cfg, lp, x, state={"ssm": ssm, "conv": conv})
        return x, (ns["ssm"], ns["conv"])

    x, (ssms, convs) = L.scan_blocks(body, x, (params["layers"], cache["ssm"],
                                               cache["conv"]), cfg.scan_layers)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return logits, {"ssm": ssms, "conv": convs, "pos": cache["pos"] + 1}
