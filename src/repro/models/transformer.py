"""Dense decoder-only transformer (llama/qwen family) + encoder-decoder.

Layers are stacked along a leading axis and executed with ``jax.lax.scan``
(+ optional remat) so the HLO stays compact for 80–95-layer models; this is
what keeps the multi-pod dry-run compile times sane and is also the idiomatic
TPU structure (one compiled block, XLA pipelines the weights).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from ..distributed import ctx

Params = Dict


# ---------------------------------------------------------------------------
# Decoder block
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.rmsnorm_init(cfg.d_model),
        "attn": L.attention_init(ks[0], cfg),
        "ln2": L.rmsnorm_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff),
    }
    if cross:
        p["ln_x"] = L.rmsnorm_init(cfg.d_model)
        p["xattn"] = L.attention_init(ks[2], cfg, cross=True)
    return p


def block_apply(cfg: ModelConfig, p: Params, x, positions, *, causal=True,
                window=0, cache=None, enc=None, xcache=None):
    h, new_cache = L.attention_apply(
        p["attn"], cfg, L.rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
        causal=causal, window=window, cache=cache)
    x = x + h
    if enc is not None:
        h, _ = L.attention_apply(
            p["xattn"], cfg, L.rmsnorm(p["ln_x"], x, cfg.norm_eps), positions,
            causal=False, kv_source=enc, use_rope=False)
        x = x + h
    x = x + L.mlp_apply(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x, new_cache


# ---------------------------------------------------------------------------
# Dense decoder-only LM (also the VLM backbone: ``embeds`` are prepended)
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, cfg.n_layers + 2)
    stacked = jax.vmap(lambda k: block_init(k, cfg))(keys[: cfg.n_layers])
    p = {
        "embed": L.embedding_init(keys[-2], cfg.vocab, cfg.d_model),
        "layers": stacked,
        "ln_f": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.linear_init(keys[-1], cfg.d_model, cfg.vocab)
    return p


def _logits(cfg: ModelConfig, params: Params, x) -> jnp.ndarray:
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return L.unembed(params["embed"], x)
    return L.linear(params["head"], x)


def _embed_inputs(cfg, params, tokens, embeds, dtype):
    """Token embeddings, with frontend embeddings (VLM patches) prepended."""
    parts = []
    if embeds is not None:
        parts.append(embeds.astype(dtype))
    if tokens is not None:
        parts.append(L.embed(params["embed"], tokens, dtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return x


def forward(cfg: ModelConfig, params: Params, tokens=None, embeds=None,
            window: int = 0, return_hidden: bool = False) -> jnp.ndarray:
    """Full-sequence causal forward -> logits [B, S, V] (or final hidden)."""
    dtype = L.compute_dtype(cfg)
    x = _embed_inputs(cfg, params, tokens, embeds, dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    if cfg.pipeline_stages > 1:
        from ..distributed.pipeline import pipeline_scan

        def block_fn(lp, h):
            h, _ = block_apply(cfg, lp, h, positions[: h.shape[0]],
                               window=window)
            return ctx.hint(h, "data", "model", None)

        if cfg.remat:
            block_fn = jax.checkpoint(block_fn)
        x = pipeline_scan(block_fn, params["layers"], x,
                          n_stages=cfg.pipeline_stages,
                          n_microbatches=cfg.pipeline_microbatches)
        return _logits(cfg, params, x)

    def body(x, lp):
        x, _ = block_apply(cfg, lp, x, positions, window=window)
        # sequence-shard the residual stream between blocks (Megatron-SP):
        # this is what the remat stash stores, so it must not be replicated
        return ctx.hint(x, "data", "model", None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = L.scan_blocks(body, x, params["layers"], cfg.scan_layers)
    if return_hidden:
        return L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return _logits(cfg, params, x)


def loss_fn(cfg: ModelConfig, params: Params, batch: Dict) -> jnp.ndarray:
    labels = batch["labels"]
    if cfg.chunked_xent:
        h = forward(cfg, params, batch.get("tokens"), batch.get("embeds"),
                    return_hidden=True)
        if h.shape[1] != labels.shape[1]:
            h = h[:, -labels.shape[1]:]
        if cfg.tie_embeddings:
            return L.softmax_xent_chunked(h, params["embed"]["table"],
                                          labels, batch.get("mask"))
        return L.softmax_xent_chunked(h, params["head"]["w"], labels,
                                      batch.get("mask"),
                                      transpose_table=True)
    logits = forward(cfg, params, batch.get("tokens"), batch.get("embeds"))
    if logits.shape[1] != labels.shape[1]:   # frontend tokens carry no labels
        logits = logits[:, -labels.shape[1]:]
    return L.softmax_xent(logits, labels, batch.get("mask"))


def prefill(cfg: ModelConfig, params: Params, tokens, max_len: int,
            embeds=None) -> Tuple[jnp.ndarray, Params]:
    """Run the prompt, build a KV cache of capacity ``max_len``."""
    dtype = L.compute_dtype(cfg)
    x = _embed_inputs(cfg, params, tokens, embeds, dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cache = L.make_cache(cfg, B, max_len, cfg.n_layers, dtype)
    cache0 = {"k": cache["k"][0] * 0, "v": cache["v"][0] * 0}  # template

    def body(carry, xs):
        x = carry
        lp, ck, cv = xs
        lcache = {"k": ck, "v": cv, "pos": jnp.zeros((), jnp.int32)}
        x, nc = block_apply(cfg, lp, x, positions, cache=lcache)
        return ctx.hint(x, "data", "model", None), (nc["k"], nc["v"])

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = L.scan_blocks(body, x, (params["layers"], cache["k"], cache["v"]),
                                cfg.scan_layers)
    new_cache = {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32)}
    return _logits(cfg, params, x[:, -1:]), new_cache


def decode_step(cfg: ModelConfig, params: Params, token, cache,
                window: int = 0) -> Tuple[jnp.ndarray, Params]:
    """One token through the stack against the KV cache.

    token: [B] int32; cache as returned by prefill (pos = current length).
    """
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], token[:, None], dtype)    # [B, 1, D]
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)

    def body(x, xs):
        lp, ck, cv = xs
        lcache = {"k": ck, "v": cv, "pos": pos}
        x, nc = block_apply(cfg, lp, x, positions, cache=lcache, window=window)
        return x, (nc["k"], nc["v"])

    x, (ks, vs) = L.scan_blocks(body, x, (params["layers"], cache["k"], cache["v"]),
                                cfg.scan_layers)
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return _logits(cfg, params, x)[:, 0], new_cache


# ---------------------------------------------------------------------------
# Encoder-decoder (seamless-m4t backbone): encoder over frame embeddings,
# decoder with self- + cross-attention.
# ---------------------------------------------------------------------------

def encdec_init(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 4)
    enc_keys = jax.random.split(keys[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    return {
        "embed": L.embedding_init(keys[2], cfg.vocab, cfg.d_model),
        "enc_layers": jax.vmap(lambda k: block_init(k, cfg))(enc_keys),
        "enc_ln": L.rmsnorm_init(cfg.d_model),
        "layers": jax.vmap(lambda k: block_init(k, cfg, cross=True))(dec_keys),
        "ln_f": L.rmsnorm_init(cfg.d_model),
        "head": L.linear_init(keys[3], cfg.d_model, cfg.vocab),
    }


def encode(cfg: ModelConfig, params: Params, frames) -> jnp.ndarray:
    """frames: precomputed frontend embeddings [B, S_enc, D] (audio stub)."""
    dtype = L.compute_dtype(cfg)
    x = frames.astype(dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        x, _ = block_apply(cfg, lp, x, positions, causal=False)
        return ctx.hint(x, "data", "model", None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = L.scan_blocks(body, x, params["enc_layers"], cfg.scan_layers)
    return L.rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def encdec_forward(cfg: ModelConfig, params: Params, tokens, frames,
                   return_hidden: bool = False):
    enc = encode(cfg, params, frames)
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, lp):
        x, _ = block_apply(cfg, lp, x, positions, enc=enc)
        return ctx.hint(x, "data", "model", None), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = L.scan_blocks(body, x, params["layers"], cfg.scan_layers)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_hidden:
        return x
    return L.linear(params["head"], x)


def encdec_loss(cfg: ModelConfig, params: Params, batch: Dict) -> jnp.ndarray:
    if cfg.chunked_xent:
        h = encdec_forward(cfg, params, batch["tokens"], batch["embeds"],
                           return_hidden=True)
        return L.softmax_xent_chunked(h, params["head"]["w"],
                                      batch["labels"], batch.get("mask"),
                                      transpose_table=True)
    logits = encdec_forward(cfg, params, batch["tokens"], batch["embeds"])
    return L.softmax_xent(logits, batch["labels"], batch.get("mask"))


def encdec_prefill(cfg: ModelConfig, params: Params, tokens, max_len: int,
                   embeds=None) -> Tuple[jnp.ndarray, Params]:
    enc = encode(cfg, params, embeds)
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], tokens, dtype)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    cache = L.make_cache(cfg, B, max_len, cfg.n_layers, dtype)

    def body(x, xs):
        lp, ck, cv = xs
        lcache = {"k": ck, "v": cv, "pos": jnp.zeros((), jnp.int32)}
        x, nc = block_apply(cfg, lp, x, positions, cache=lcache, enc=enc)
        return ctx.hint(x, "data", "model", None), (nc["k"], nc["v"])

    if cfg.remat:
        body = jax.checkpoint(body)
    x, (ks, vs) = L.scan_blocks(body, x, (params["layers"], cache["k"], cache["v"]),
                                cfg.scan_layers)
    x = L.rmsnorm(params["ln_f"], x[:, -1:], cfg.norm_eps)
    logits = L.linear(params["head"], x)
    return logits, {"k": ks, "v": vs, "pos": jnp.asarray(S, jnp.int32), "enc": enc}


def encdec_decode_step(cfg: ModelConfig, params: Params, token, cache):
    dtype = L.compute_dtype(cfg)
    x = L.embed(params["embed"], token[:, None], dtype)
    B = x.shape[0]
    pos = cache["pos"]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    enc = cache["enc"]

    def body(x, xs):
        lp, ck, cv = xs
        lcache = {"k": ck, "v": cv, "pos": pos}
        x, nc = block_apply(cfg, lp, x, positions, cache=lcache, enc=enc)
        return x, (nc["k"], nc["v"])

    x, (ks, vs) = L.scan_blocks(body, x, (params["layers"], cache["k"], cache["v"]),
                                cfg.scan_layers)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = L.linear(params["head"], x)[:, 0]
    return logits, {"k": ks, "v": vs, "pos": pos + 1, "enc": enc}
