"""Model zoo: dense / MoE / SSM / hybrid / enc-dec families."""
from .model import Model, build

__all__ = ["Model", "build"]
