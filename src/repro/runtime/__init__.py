"""Runtime: fault tolerance, elastic scaling, straggler mitigation."""
