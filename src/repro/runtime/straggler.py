"""Straggler mitigation: hedged requests.

At scale some workers run slow (background compaction, thermal throttling,
failing HBM). The standard mitigation is to hedge: if a request hasn't
completed by the p-th latency percentile, fire a backup on another worker
and take whichever finishes first. This module models that policy for the
cluster simulator and quantifies the tail-latency improvement.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class HedgePolicy:
    straggler_prob: float = 0.03     # fraction of executions that straggle
    straggler_factor: float = 8.0    # slowdown multiplier when straggling
    hedge_after_factor: float = 2.0  # hedge when t > factor * expected
    enabled: bool = True

    def effective_latency(self, exec_s: float, rng: np.random.Generator
                          ) -> float:
        straggled = rng.uniform() < self.straggler_prob
        primary = exec_s * (self.straggler_factor if straggled else 1.0)
        if not self.enabled or not straggled:
            return primary
        # Backup fires once the request exceeds the hedge threshold; the
        # backup itself may straggle (independently).
        hedge_at = exec_s * self.hedge_after_factor
        backup_straggle = rng.uniform() < self.straggler_prob
        backup = hedge_at + exec_s * (self.straggler_factor
                                      if backup_straggle else 1.0)
        return min(primary, backup)

    def latency_from_uniforms(self, exec_s, u1, u2):
        """Pure hedged-latency formula over pre-drawn uniforms.

        Both cluster engines draw ``u1``/``u2`` up front (one pair per event,
        indexed by global arrival rank) and evaluate this identical formula,
        so the scalar oracle and the vectorized engine see the same stragglers
        regardless of evaluation order. Accepts scalars or numpy arrays.
        """
        straggled = u1 < self.straggler_prob
        primary = exec_s * np.where(straggled, self.straggler_factor, 1.0)
        if not self.enabled:
            return primary
        backup = exec_s * self.hedge_after_factor + exec_s * np.where(
            u2 < self.straggler_prob, self.straggler_factor, 1.0)
        return np.where(straggled, np.minimum(primary, backup), primary)

    def event_uniforms(self, n_events: int):
        """The shared per-event uniform streams (seeded, engine-agnostic)."""
        rng = np.random.default_rng(0)
        return rng.uniform(size=n_events), rng.uniform(size=n_events)
