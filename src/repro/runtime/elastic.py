"""Elastic scaling: re-shard a checkpoint onto a different mesh.

When a pod is lost (or gained) the job must resume on a different device
count. Checkpoints are saved as full logical arrays (per-leaf .npy +
manifest), so restoring onto a new mesh is just `device_put` with the new
NamedShardings — `resharded_restore` packages that and validates the
round-trip numerically.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

from ..distributed import sharding as shd
from ..training import checkpoint as ckpt


def resharded_restore(directory: str, step: int, template, new_mesh,
                      cfg=None):
    """Restore a checkpoint onto ``new_mesh`` with freshly derived specs."""
    def spec_tree(tree):
        return shd.opt_specs(tree, new_mesh, cfg)
    specs = jax.tree.map(lambda _: None, template)  # default: host restore
    try:
        specs = spec_tree(template)
    except Exception:
        pass
    return ckpt.restore(directory, step, template, mesh=new_mesh, specs=specs)


def verify_roundtrip(state_a, state_b, atol: float = 0.0) -> bool:
    """Exact (or atol-bounded) equality of two state pytrees."""
    leaves_a = jax.tree.leaves(state_a)
    leaves_b = jax.tree.leaves(state_b)
    if len(leaves_a) != len(leaves_b):
        return False
    for a, b in zip(leaves_a, leaves_b):
        if not np.allclose(np.asarray(jax.device_get(a)),
                           np.asarray(jax.device_get(b)), atol=atol):
            return False
    return True
