"""Fault-tolerance orchestration.

Two failure domains, both exercised by tests and examples:

  * **Training workers** — checkpoint/restart: `run_with_restarts` drives
    the training loop, catching (injected or real) worker failures and
    resuming from the latest durable checkpoint. Determinstic data keyed by
    step means the loss trajectory is bit-identical to an uninterrupted run
    once re-executed steps are accounted for.

  * **Serving controller** — the warm pool + policy state (histograms,
    learned windows, ARIMA observations) is checkpointed via
    `WarmPool.state_dict()`; a controller restart therefore does NOT reset
    every application to the conservative standard keep-alive (which would
    cause a fleet-wide cold-start regression while histograms re-learn).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from ..configs.base import ModelConfig, ShapeConfig
from ..training import train_loop
from ..training import optimizer as opt


@dataclasses.dataclass
class RestartReport:
    attempts: int
    total_steps_run: int
    result: Dict


def run_with_restarts(cfg: ModelConfig, shape: ShapeConfig,
                      loop: train_loop.LoopConfig,
                      opt_cfg: opt.OptConfig = opt.OptConfig(),
                      batch_override: Optional[int] = None,
                      fault_at_step: Optional[int] = None,
                      max_restarts: int = 3,
                      log: Callable[[str], None] = print) -> RestartReport:
    """Run training to completion, restarting on failure.

    fault_at_step injects a crash once (the retry runs clean), emulating a
    preempted/failed node; requires loop.checkpoint_dir for recovery.
    """
    attempts = 0
    injected = fault_at_step
    while True:
        attempts += 1
        try:
            result = train_loop.train(cfg, shape, loop, opt_cfg,
                                      batch_override=batch_override,
                                      fault_at_step=injected, log=log)
            return RestartReport(attempts=attempts,
                                 total_steps_run=loop.steps,
                                 result=result)
        except RuntimeError as e:
            log(f"[fault-tolerance] caught failure: {e}; restarting "
                f"(attempt {attempts + 1})")
            injected = None   # the injected fault fires only once
            if attempts > max_restarts:
                raise
