"""Production meshes (defined as FUNCTIONS so importing this module never
touches jax device state).

Targets (per chip): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI. Single pod = 16x16 = 256 chips; multi-pod = 2 pods = 512 chips with the
leading "pod" axis mapped across the DCN/ICI pod interconnect.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

import jax
from jax.sharding import Mesh

# Hardware constants used by the roofline analysis (benchmarks/roofline.py).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape: Tuple[int, ...] = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} present; "
            "the dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import")
    dev = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev, axes)


def make_app_mesh(n_devices: int = None) -> Mesh:
    """1-D mesh over the first ``n_devices`` local devices, axis ``"apps"``.

    The reproduction engines' data-parallel axis (see
    :mod:`repro.distributed.scaleout`): apps are embarrassingly parallel, so
    the only mesh the sweep engines ever need is this flat one. ``None``
    takes every local device.
    """
    devices = jax.devices()
    n = len(devices) if n_devices is None else int(n_devices)
    if n < 1:
        raise ValueError(f"an app mesh needs at least one device, got "
                         f"n_devices={n_devices!r}")
    if n > len(devices):
        raise RuntimeError(
            f"devices={n} requested but only {len(devices)} present; on CPU "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n} "
            f"before the first jax import to emulate an {n}-device host")
    return Mesh(np.asarray(devices[:n]), ("apps",))


def make_host_mesh(model_parallel: int = 1) -> Mesh:
    """Tiny mesh over the real host devices (tests / examples)."""
    devices = jax.devices()
    mp = min(model_parallel, len(devices))
    dp = len(devices) // mp
    dev = np.asarray(devices[: dp * mp]).reshape(dp, mp)
    return Mesh(dev, ("data", "model"))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch (pod included when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_axis(mesh: Mesh) -> str:
    return "model"
