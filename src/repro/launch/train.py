"""Training launcher.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 50 \
      --reduced --batch 8 --seq 256 --checkpoint-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses

from .. import configs
from ..configs.base import SHAPES
from ..training import optimizer as opt
from ..training import train_loop


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config (CPU friendly)")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fault-at-step", type=int, default=None,
                    help="inject a crash (tests restart)")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    shape = SHAPES[args.shape]
    if args.seq:
        shape = dataclasses.replace(shape, seq_len=args.seq)
    loop = train_loop.LoopConfig(
        steps=args.steps, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every)
    opt_cfg = opt.OptConfig(lr=args.lr, total_steps=args.steps)
    if args.fault_at_step:
        from ..runtime.fault_tolerance import run_with_restarts
        report = run_with_restarts(cfg, shape, loop, opt_cfg,
                                   batch_override=args.batch,
                                   fault_at_step=args.fault_at_step)
        res = report.result
        print(f"[done after {report.attempts} attempts] "
              f"loss {res['first_loss']:.4f} -> {res['final_loss']:.4f}")
    else:
        res = train_loop.train(cfg, shape, loop, opt_cfg,
                               batch_override=args.batch)
        print(f"[done] loss {res['first_loss']:.4f} -> {res['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
