"""Serving launcher: serverless model platform driven by a synthetic trace.

Example:
  PYTHONPATH=src python -m repro.launch.serve --apps 40 --minutes 120 \
      --policy hybrid

``--engine auto`` (default) runs the columnar fleet engine
(:mod:`repro.serving.cluster_vector`), which scales to millions of apps
and replays HBM evictions to a fixed point, bit-identical to the oracle
when the registry oversubscribes the worker budget. ``--engine scalar``
runs the per-event oracle.
"""
from __future__ import annotations

import argparse

import numpy as np

from ..core.experiment import FixedSpec, HybridSpec
from ..core.workload import generate_trace
from ..serving.apptable import AppTable
from ..serving.cluster_vector import ClusterSpec, run_cluster
from ..serving.registry import ModelEndpoint, Registry
from ..runtime.straggler import HedgePolicy
from .. import configs


def build_registry(n_apps: int, seed: int = 0,
                   hbm_budget_bytes: float = 16e9) -> Registry:
    """Endpoints cycle through the assigned architectures whose weights fit
    a single worker's HBM budget (a 145 GB model can never be resident in a
    16 GB worker -- those serve from multi-worker slices, out of scope for
    the single-worker pool), giving a realistic 0.3-13 GB cold-start
    spread."""
    reg = Registry()
    from ..models import build as build_model
    fitting = [a for a in configs.ARCHS
               if 2 * build_model(configs.get(a)).n_params()
               <= 0.8 * hbm_budget_bytes]
    rng = np.random.default_rng(seed)
    for i in range(n_apps):
        cfg = configs.get(fitting[i % len(fitting)])
        reg.register(ModelEndpoint(app_id=f"app-{i:06d}", cfg=cfg, seed=i,
                                   avg_request_s=float(rng.uniform(0.05, 2))))
    return reg


def make_policy_spec(name: str, keep_alive: float):
    if name == "hybrid":
        return HybridSpec()
    if name == "fixed":
        return FixedSpec(keep_alive)
    raise ValueError(name)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--apps", type=int, default=40)
    ap.add_argument("--minutes", type=float, default=240)
    ap.add_argument("--policy", default="hybrid", choices=["hybrid", "fixed"])
    ap.add_argument("--keep-alive", type=float, default=10.0)
    ap.add_argument("--workers", type=int, default=18)
    ap.add_argument("--hbm-gb", type=float, default=16.0)
    ap.add_argument("--hedge", action="store_true")
    ap.add_argument("--engine", default="auto",
                    choices=["auto", "vector", "scalar"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    trace = generate_trace(args.apps, days=args.minutes / 1440.0,
                           seed=args.seed)
    reg = build_registry(args.apps, args.seed, args.hbm_gb * 1e9)
    table = AppTable.from_trace(
        trace, weight_bytes=[reg.get(s.app_id).weight_bytes
                             for s in trace.specs])
    res = run_cluster(
        table, make_policy_spec(args.policy, args.keep_alive),
        ClusterSpec(n_workers=args.workers,
                    hbm_budget_bytes=args.hbm_gb * 1e9,
                    hedge=HedgePolicy() if args.hedge else None),
        engine=args.engine)
    print(f"policy={args.policy} apps={args.apps} minutes={args.minutes:g}")
    print(f"  cold-start p75 over apps: {res.cold_pct_p75:.1f}%")
    print(f"  latency p50/p95/p99: {res.latency_pct(50):.2f}/"
          f"{res.latency_pct(95):.2f}/{res.latency_pct(99):.2f} s")
    print(f"  wasted HBM: {res.wasted_gb_minutes:.1f} GB-minutes")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
