"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with no device allocation (ShapeDtypeStruct inputs).

For each cell it records:
  * memory_analysis()  — proves the sharded program fits per-device HBM;
  * cost_analysis()    — HLO flops/bytes for the roofline;
  * collective bytes   — parsed from the optimized HLO text (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operand
    sizes), since cost_analysis does not expose them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results.json
"""
# The VERY FIRST action: force 512 host platform devices BEFORE any other
# import can initialize jax (jax locks the device count on first init).
import os  # noqa: E402
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import configs
from ..configs.base import SHAPES, ShapeConfig
from ..distributed import sharding as shd
from ..launch import mesh as mesh_lib
from ..launch.steps import make_prefill_step, make_serve_step, make_train_step
from ..models import build
from ..training import optimizer as opt

HLO_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective op in optimized HLO."""
    totals = {c: 0.0 for c in _COLLECTIVES}
    totals["count"] = 0
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+(\S+)", s)
        if not m:
            continue
        op = m.group(2).split("(")[0]
        base = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                base = c
                break
        if base is None:
            continue
        nbytes = 0.0
        for dt, dims in shape_re.findall(m.group(1)):
            if dt not in HLO_DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * HLO_DTYPE_BYTES[dt]
        totals[base] += nbytes
        totals["count"] += 1
    totals["total"] = sum(totals[c] for c in _COLLECTIVES)
    return totals


def cpu_upcast_bytes(hlo_text: str, min_bytes: int = 1 << 26) -> float:
    """Estimate CPU-backend bf16->f32 legalization artifacts.

    XLA:CPU upcasts bf16 dots / dynamic-update-slices to f32, materializing
    f32 copies of weights and KV caches that would NOT exist on TPU (bf16 is
    native there). We sum large f32 `convert` outputs so per-device memory
    can be reported both raw and TPU-adjusted (see EXPERIMENTS.md §Dry-run).
    """
    total = 0.0
    for m in re.finditer(r"=\s*f32\[([\d,]+)\][^=]*?\bconvert\(", hlo_text):
        n = 1
        for d in m.group(1).split(","):
            if d:
                n *= int(d)
        if n * 4 >= min_bytes:
            total += n * 4
    return total


def _eval_param_sds(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))


def _as_bf16(tree):
    def f(x):
        dt = jnp.bfloat16 if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype
        return jax.ShapeDtypeStruct(x.shape, dt)
    return jax.tree.map(f, tree)


def _lower_one(cfg, shape, mesh, donate=True, cast_bf16=False):
    """Lower + compile a step for an explicit ModelConfig. Returns
    (compiled, model). cast_bf16: bf16 param-gather/grad-RS (hillclimb)."""
    from ..distributed import ctx
    ctx.set_mesh(mesh)
    model = build(cfg)
    da = mesh_lib.data_axes(mesh)
    dp = da if len(da) > 1 else da[0]
    param_sds = _eval_param_sds(model)
    pspecs = shd.param_specs(param_sds, mesh, cfg)
    input_sds = model.input_specs(shape)
    bspecs = shd.batch_specs(cfg, input_sds, mesh)
    if shape.kind == "train":
        opt_cfg = opt.OptConfig()
        state_sds = jax.eval_shape(opt.init_state, param_sds)
        ospecs = shd.opt_specs(param_sds, mesh, cfg)
        step_fn = make_train_step(model, opt_cfg,
                                  grad_shardings=shd.named(ospecs, mesh),
                                  cast_bf16=cast_bf16)
        state_specs = opt.TrainState(step=P(), params=ospecs, m=ospecs,
                                     v=ospecs)
        in_sh = (shd.named(state_specs, mesh), shd.named(bspecs, mesh))
        out_sh = (shd.named(state_specs, mesh),
                  {"loss": NamedSharding(mesh, P()),
                   "grad_norm": NamedSharding(mesh, P()),
                   "lr": NamedSharding(mesh, P())})
        jfn = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=(0,) if donate else ())
        lowered = jfn.lower(state_sds, input_sds)
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(model, max_len=shape.seq_len)
        psds_bf16 = _as_bf16(param_sds)
        in_sh = (shd.named(pspecs, mesh), shd.named(bspecs, mesh))
        # Explicit output shardings: the produced KV cache must come out in
        # the serving layout (otherwise XLA replicates it).
        out_sds = jax.eval_shape(step_fn, psds_bf16, input_sds)
        tok_spec = shd._fit((dp, None), out_sds[0].shape, mesh)
        cache_out_specs = shd.cache_specs_tree(cfg, out_sds[1], mesh)
        out_sh = (NamedSharding(mesh, tok_spec),
                  shd.named(cache_out_specs, mesh))
        jfn = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jfn.lower(psds_bf16, input_sds)
    else:
        step_fn = make_serve_step(model)
        psds_bf16 = _as_bf16(param_sds)
        cache_sds = input_sds["cache"]
        cspecs = bspecs["cache"]
        tok_sh = NamedSharding(mesh, bspecs["token"])
        in_sh = (shd.named(pspecs, mesh), tok_sh, shd.named(cspecs, mesh))
        out_sh = (tok_sh, shd.named(cspecs, mesh))
        jfn = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=(2,) if donate else ())
        lowered = jfn.lower(psds_bf16, input_sds["token"], cache_sds)
    return lowered.compile(), model


def _cell_costs(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll["total"],
        "collectives": {k: v for k, v in coll.items()
                        if k in _COLLECTIVES or k == "count"},
    }


def _depth_variants(cfg):
    """(reduced_cfg_1, reduced_cfg_2, multiplier) for depth extrapolation.

    cost_analysis does not multiply scan (while-loop) bodies by their trip
    count, so per-layer costs are measured as the delta between a 2-deep and
    a 1-deep lowering and extrapolated: total = base + (L-1)*delta.
    """
    # The variants are lowered UNROLLED (scan_layers=False): a lax.scan of
    # length 1 and length 2 produce the same while-body HLO, so the delta
    # would be ~0; unrolled shallow stacks are cheap to compile and give the
    # true per-layer cost.
    if cfg.family == "hybrid":
        pat = len(cfg.block_pattern) or 3
        n_super = cfg.n_layers // pat
        n_tail = cfg.n_layers - n_super * pat
        c1 = cfg.with_(n_layers=pat + n_tail, scan_layers=False)
        c2 = cfg.with_(n_layers=2 * pat + n_tail, scan_layers=False)
        return c1, c2, n_super - 1
    if cfg.family == "encdec":
        c1 = cfg.with_(n_layers=1, n_encoder_layers=1, scan_layers=False)
        c2 = cfg.with_(n_layers=2, n_encoder_layers=2, scan_layers=False)
        # one combined delta applied to both stacks (enc and dec depths are
        # equal for seamless-m4t); multiplier = L-1
        return c1, c2, cfg.n_layers - 1
    c1 = cfg.with_(n_layers=1, scan_layers=False)
    c2 = cfg.with_(n_layers=2, scan_layers=False)
    return c1, c2, cfg.n_layers - 1


def depth_scaled_costs(cfg, shape, mesh, cast_bf16=False) -> Dict[str, float]:
    """HLO flop/byte/collective totals with scan bodies correctly scaled."""
    c1, c2, mult = _depth_variants(cfg)
    comp1, _ = _lower_one(c1, shape, mesh, cast_bf16=cast_bf16)
    comp2, _ = _lower_one(c2, shape, mesh, cast_bf16=cast_bf16)
    k1, k2 = _cell_costs(comp1), _cell_costs(comp2)
    out = {}
    for key in ("flops", "bytes_accessed", "collective_bytes"):
        delta = max(k2[key] - k1[key], 0.0)
        out[key] = k1[key] + mult * delta
    out["collectives"] = {
        k: k1["collectives"].get(k, 0.0)
        + mult * max(k2["collectives"].get(k, 0.0)
                     - k1["collectives"].get(k, 0.0), 0.0)
        for k in set(k1["collectives"]) | set(k2["collectives"])
    }
    return out


def lower_cell(arch_id: str, shape_name: str, mesh, *,
               donate: bool = True, depth_scale: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch, shape) cell on the given mesh.

    The full-depth compile proves the sharded program builds and yields the
    per-device memory picture; flop/byte/collective totals come from the
    depth-delta extrapolation (scan bodies are counted once by
    cost_analysis, so per-layer costs are measured at depths 1 and 2 and
    scaled -- see depth_scaled_costs).
    """
    cfg = configs.get(arch_id)
    shape = SHAPES[shape_name]
    t0 = time.time()
    compiled, model = _lower_one(cfg, shape, mesh, donate=donate)
    t_compile = time.time() - t0

    # NOTE: under SPMD partitioning both cost_analysis() and
    # memory_analysis() report PER-DEVICE numbers (validated against an
    # analytically known sharded matmul -- see EXPERIMENTS.md section Dry-run).
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    if depth_scale:
        costs = depth_scaled_costs(cfg, shape, mesh)
    else:
        costs = _cell_costs(compiled)

    n_dev = int(np.prod(list(mesh.shape.values())))
    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "n_devices": n_dev,
        "kind": shape.kind,
        "flops": costs["flops"],
        "bytes_accessed": costs["bytes_accessed"],
        "collective_bytes": costs["collective_bytes"],
        "collectives": costs["collectives"],
        "argument_size": float(getattr(mem, "argument_size_in_bytes", 0)),
        "output_size": float(getattr(mem, "output_size_in_bytes", 0)),
        "temp_size": float(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes": float(getattr(mem, "argument_size_in_bytes", 0)
                            + getattr(mem, "temp_size_in_bytes", 0)),
        "cpu_upcast_bytes": cpu_upcast_bytes(hlo),
        "compile_s": round(t_compile, 2),
        "n_params": model.n_params(),
        "n_params_active": model.n_params(active_only=True),
    }
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 (512-chip) mesh")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--optimized", action="store_true",
                    help="enable the EXPERIMENTS.md §Perf optimizations: "
                         "chunked cross-entropy for train cells and "
                         "distributed flash-decode for decode cells")
    args = ap.parse_args(argv)
    if args.optimized:
        from ..distributed import dist_decode
        dist_decode.ENABLED = True
        configs.ARCHS.update({k: v.with_(chunked_xent=True)
                              for k, v in configs.ARCHS.items()})

    cells = (list(configs.cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = []
    if not args.multi_pod or args.single_pod_only:
        meshes.append(("single-pod", mesh_lib.make_production_mesh()))
    if args.multi_pod:
        meshes.append(("multi-pod", mesh_lib.make_production_mesh(multi_pod=True)))

    done = set()
    if args.out and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    done.add((r.get("mesh_name"), r["arch"], r["shape"]))
                except Exception:
                    pass

    failures = 0
    for mesh_name, mesh in meshes:
        for arch_id, shape_name in cells:
            tag = f"{mesh_name}:{arch_id}:{shape_name}"
            if (mesh_name, arch_id, shape_name) in done:
                print(f"SKIP {tag:55s} (already in {args.out})", flush=True)
                continue
            try:
                with mesh:
                    res = lower_cell(arch_id, shape_name, mesh)
                res["mesh_name"] = mesh_name
                per_dev_gb = res["peak_bytes"] / 2**30   # already per-device
                print(f"OK   {tag:55s} flops/dev={res['flops']:.3e} "
                      f"coll/dev={res['collective_bytes']:.3e}B "
                      f"peak/dev={per_dev_gb:.2f}GiB "
                      f"compile={res['compile_s']}s", flush=True)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(res) + "\n")
            except Exception as e:  # noqa: BLE001 — report and continue
                failures += 1
                print(f"FAIL {tag:55s} {type(e).__name__}: {e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
