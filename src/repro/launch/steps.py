"""Step builders: train_step / prefill_step / serve_step for any arch.

These are the functions the launcher jits with explicit in/out shardings and
the dry-run lowers against ShapeDtypeStructs.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import Model
from ..training import optimizer as opt


def make_train_step(model: Model, opt_cfg: opt.OptConfig,
                    grad_shardings=None, cast_bf16: bool = False) -> Callable:
    """Build the train step.

    grad_shardings: optional pytree of NamedSharding matching the params —
    constraining the gradients to the ZeRO layout makes XLA reduce-scatter
    them during the backward pass instead of materializing TP-only
    (replicated-over-data) gradients before the optimizer update.

    cast_bf16: cast the fp32 master params to bf16 *before* they leave their
    ZeRO shards, so the per-layer all-gathers (and the matching gradient
    reduce-scatters) move half the bytes. The optimizer math stays fp32.
    """
    def train_step(state: opt.TrainState, batch: Dict):
        if cast_bf16:
            # Cast to bf16 while STILL in the ZeRO layout (the sharding
            # constraint pins the converted copy to the sharded spec), and
            # differentiate w.r.t. the bf16 copy: the per-layer all-gathers
            # AND the backward reduce-scatters then carry bf16, halving the
            # ZeRO collective bytes. Optimizer math stays fp32.
            p_half = jax.tree.map(
                lambda p: (p.astype(jnp.bfloat16)
                           if p.dtype == jnp.float32 else p), state.params)
            if grad_shardings is not None:
                p_half = jax.tree.map(jax.lax.with_sharding_constraint,
                                      p_half, grad_shardings)
            loss, grads = jax.value_and_grad(
                lambda ph: model.loss(ph, batch))(p_half)
            if grad_shardings is not None:
                grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                     grad_shardings)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            loss, grads = jax.value_and_grad(
                lambda p: model.loss(p, batch))(state.params)
            if grad_shardings is not None:
                grads = jax.tree.map(jax.lax.with_sharding_constraint, grads,
                                     grad_shardings)
        new_state, metrics = opt.apply_updates(state, grads, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_state, metrics

    return train_step


def make_prefill_step(model: Model, max_len: int) -> Callable:
    def prefill_step(params, batch: Dict):
        logits, cache = model.prefill(params, batch.get("tokens"), max_len,
                                      embeds=batch.get("embeds"))
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    def serve_step(params, token, cache):
        logits, new_cache = model.decode_step(params, token, cache)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

    return serve_step
