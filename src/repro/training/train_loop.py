"""Fault-tolerant training loop.

Restart semantics: the loop is a pure function of (checkpoint, data seed) —
on startup it restores the latest checkpoint (if any) and resumes from the
recorded step; the deterministic pipeline regenerates exactly the batches
that follow. A preemption signal (or injected fault) between steps loses at
most `checkpoint_every` steps of work. Straggler mitigation and elastic
re-meshing live in `repro.runtime`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..models import build
from . import checkpoint as ckpt
from . import data as data_lib
from . import optimizer as opt
from ..launch.steps import make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_last: int = 3
    log_every: int = 10
    seed: int = 0


def train(cfg: ModelConfig, shape: ShapeConfig, loop: LoopConfig,
          opt_cfg: opt.OptConfig = opt.OptConfig(),
          batch_override: Optional[int] = None,
          fault_at_step: Optional[int] = None,
          log: Callable[[str], None] = print) -> Dict:
    """Run (or resume) training; returns final metrics."""
    model = build(cfg)
    dcfg = data_lib.DataConfig(seed=loop.seed)

    start = 0
    state = None
    if loop.checkpoint_dir:
        last = ckpt.latest_step(loop.checkpoint_dir)
        if last is not None:
            template = jax.eval_shape(
                opt.init_state,
                jax.eval_shape(model.init, jax.random.PRNGKey(loop.seed)))
            state = ckpt.restore(loop.checkpoint_dir, last, template)
            start = last
            log(f"[restore] resumed from step {last}")
    if state is None:
        params = model.init(jax.random.PRNGKey(loop.seed))
        state = opt.init_state(params)

    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0,))
    losses = []
    t0 = time.time()
    for step in range(start, loop.steps):
        batch = data_lib.batch_at(step, cfg, shape, dcfg, batch_override)
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (step + 1) % loop.log_every == 0:
            log(f"step {step + 1:5d} loss {loss:.4f} "
                f"gnorm {float(metrics['grad_norm']):.3f} "
                f"({(time.time() - t0) / max(step - start + 1, 1):.2f}s/step)")
        if loop.checkpoint_dir and (step + 1) % loop.checkpoint_every == 0:
            ckpt.save(loop.checkpoint_dir, step + 1, state, loop.keep_last)
        if fault_at_step is not None and step + 1 == fault_at_step:
            raise RuntimeError(f"injected fault at step {step + 1}")
    if loop.checkpoint_dir:
        ckpt.save(loop.checkpoint_dir, loop.steps, state, loop.keep_last)
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "losses": losses, "resumed_from": start}
