"""AdamW with fp32 master weights, built from scratch (no optax offline).

State layout is ZeRO-friendly: master params and both moments are plain
pytrees that the launcher shards with `distributed.sharding.opt_specs`
(data-axis sharding on top of TP), so per-chip optimizer memory is
``12 bytes * n_params / (dp * tp)``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Dict          # fp32 master
    m: Dict
    v: Dict


def init_state(params) -> TrainState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=jax.tree.map(f32, params),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def _schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cosine)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(state: TrainState, grads, cfg: OptConfig
                  ) -> Tuple[TrainState, Dict]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1.0 - b1) * g
        v = b2 * v + (1.0 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p_new = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p * (p.ndim > 1))
        return p_new, m, v

    flat = jax.tree.map(upd, state.params, grads, state.m, state.v)
    params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_state = TrainState(step=step, params=params, m=m, v=v)
    return new_state, {"grad_norm": gnorm, "lr": lr}
