"""Training substrate: optimizer, data, checkpoint, loop."""
