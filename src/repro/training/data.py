"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step), which is the property fault
tolerance needs: after a restart from step k the pipeline regenerates batch
k+1 bit-identically on every host, with no data-loader state to checkpoint.
Each host materializes only its addressable shard (`device_put` with the
batch sharding) — the global batch never exists on one host.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32_000
    # Markov-chain-ish synthetic text: token t+1 depends on t (so the LM loss
    # actually goes down during the example runs).
    order_bias: float = 0.7


def batch_at(step: int, cfg: ModelConfig, shape: ShapeConfig,
             dcfg: DataConfig = DataConfig(),
             batch_override: Optional[int] = None) -> Dict[str, np.ndarray]:
    """The (seed, step)-determined global batch as host numpy arrays."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    rng = np.random.default_rng((dcfg.seed << 20) ^ step)
    vocab = min(dcfg.vocab, cfg.vocab)
    if cfg.frontend == "vision":
        s_text = S - cfg.frontend_tokens
        toks = _markov(rng, B, s_text + 1, vocab, dcfg.order_bias)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "embeds": rng.normal(0, 0.02, (B, cfg.frontend_tokens,
                                           cfg.d_model)).astype(np.float32),
        }
    if cfg.family == "encdec":
        toks = _markov(rng, B, S + 1, vocab, dcfg.order_bias)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "embeds": rng.normal(0, 0.02, (B, cfg.frontend_tokens,
                                           cfg.d_model)).astype(np.float32),
        }
    toks = _markov(rng, B, S + 1, vocab, dcfg.order_bias)
    return {"tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32)}


def _markov(rng, B, S, vocab, bias):
    toks = np.empty((B, S), np.int64)
    toks[:, 0] = rng.integers(0, vocab, B)
    jumps = rng.integers(0, vocab, (B, S))
    stay = rng.uniform(0, 1, (B, S)) < bias
    for t in range(1, S):
        nxt = (toks[:, t - 1] * 7 + 13) % vocab
        toks[:, t] = np.where(stay[:, t], nxt, jumps[:, t])
    return toks


def batches(cfg: ModelConfig, shape: ShapeConfig, start_step: int = 0,
            dcfg: DataConfig = DataConfig(),
            batch_override: Optional[int] = None) -> Iterator[Dict]:
    step = start_step
    while True:
        yield batch_at(step, cfg, shape, dcfg, batch_override)
        step += 1
