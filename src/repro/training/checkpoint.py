"""Sharded checkpointing with atomic commit, retention, and re-sharding.

Design (orbax unavailable offline, so built from scratch):
  * every leaf of the state pytree is saved as a raw ``.npy`` under a
    ``step_<n>.tmp`` directory which is atomically renamed to ``step_<n>``
    only after all leaves + the manifest are durably written — a crash
    mid-save can never corrupt the latest checkpoint (fault tolerance);
  * the manifest records the tree structure, dtypes and the mesh/sharding
    every leaf was saved under;
  * ``restore(..., mesh=new_mesh, specs=new_specs)`` re-shards on load
    (elastic scaling: the same checkpoint restores onto a different mesh —
    each host reads the full leaf and `device_put`s its local shards);
  * ``keep_last`` retention prunes old steps after a successful commit.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "__".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, leaf))
    return out


def save(directory: str, step: int, state, keep_last: int = 3) -> str:
    """Atomically save a state pytree; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves = _leaf_paths(state)
    manifest = {"step": step, "leaves": []}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append({"name": name, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    _retain(directory, keep_last)
    return final


def _retain(directory: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, MANIFEST))]
    return max(steps) if steps else None


def restore(directory: str, step: int, template, *, mesh=None, specs=None):
    """Restore into the structure of ``template``.

    mesh+specs: optional target sharding — enables restoring a checkpoint
    written on one mesh onto a different one (elastic re-shard).
    """
    src = os.path.join(directory, f"step_{step:08d}")
    names = {name: i for i, (name, _) in enumerate(_leaf_paths(template))}
    flat, treedef = jax.tree_util.tree_flatten(template)
    spec_flat = (jax.tree_util.tree_flatten(specs)[0]
                 if specs is not None else [None] * len(flat))
    out = list(flat)
    for name, idx in names.items():
        arr = np.load(os.path.join(src, name + ".npy"))
        if mesh is not None and spec_flat[idx] is not None:
            from jax.sharding import NamedSharding
            sh = (spec_flat[idx] if isinstance(spec_flat[idx], NamedSharding)
                  else NamedSharding(mesh, spec_flat[idx]))
            out[idx] = jax.device_put(jnp.asarray(arr), sh)
        else:
            out[idx] = jnp.asarray(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
