"""Version compatibility for the distributed layer.

``jax.shard_map`` (with ``axis_names``/``check_vma``) only exists in newer
JAX releases; 0.4.x exposes ``jax.experimental.shard_map.shard_map`` with
``auto``/``check_rep`` instead. :func:`shard_map` hides the difference:
``axis_names`` (the *manual* axes) is translated to the old API's ``auto``
set (every mesh axis NOT named manual).
"""
from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, mesh, in_specs, out_specs, axis_names=None, check=False):
    if hasattr(jax, "shard_map"):
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _shard_map
    # Old JAX: partial-manual (auto axes) lowers through an SPMD path whose
    # PartitionId handling is unimplemented on some backends. The callers
    # here disable sharding hints inside the region, so full-manual (every
    # axis manual, unnamed axes simply replicated by the specs) computes the
    # same values.
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check)
