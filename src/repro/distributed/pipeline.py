"""Pipeline parallelism over the pod axis (GPipe schedule, shard_map).

At multi-pod scale the cross-pod links are the slowest; instead of using the
"pod" axis for data parallelism (all-reducing gradients across pods every
step), this module offers the alternative: pods as *pipeline stages*. The
layer stack [L, ...] is split into `n_stages` contiguous groups; activations
flow stage-to-stage with `ppermute` (point-to-point over the pod links — the
cheapest collective there is), and microbatching keeps every stage busy
(GPipe schedule: M microbatches, M + S - 1 ticks, bubble fraction
(S-1)/(M+S-1)).

The backward pass needs no extra code: `jax.grad` through `ppermute`
transposes to the reverse permutation, yielding the standard dataflow under
XLA scheduling. shard_map is *partial-manual* (only the stage axis), so the
data/model sharding of everything inside each stage keeps working.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compat, ctx


def _stage_slice(tree, n_stages: int):
    """[L, ...] stacked params -> [n_stages, L//n_stages, ...]."""
    def f(x):
        L = x.shape[0]
        assert L % n_stages == 0, f"layers {L} not divisible by {n_stages}"
        return x.reshape((n_stages, L // n_stages) + x.shape[1:])
    return jax.tree.map(f, tree)


def pipeline_scan(block_fn: Callable, layer_params, x, *, n_stages: int,
                  n_microbatches: int, stage_axis: str = "pod"):
    """Run ``x`` through the full layer stack, pipelined over `stage_axis`.

    block_fn(params_i, x) -> x  (one layer)
    layer_params: stacked [L, ...]; x: [B, ...] (B % n_microbatches == 0).
    Returns y: [B, ...] after all L layers.
    """
    mesh = ctx._ACTIVE["mesh"]
    assert mesh is not None and stage_axis in mesh.axis_names
    S = n_stages
    assert mesh.shape[stage_axis] == S
    M = n_microbatches
    B = x.shape[0]
    assert B % M == 0
    mb = B // M

    staged = _stage_slice(layer_params, S)             # [S, L/S, ...]
    xs = x.reshape((M, mb) + x.shape[1:])              # [M, mb, ...]

    def per_stage(staged_local, xs_local):
        # staged_local: [1, L/S, ...] (this stage's layers);
        # xs_local: [M, mb, ...] (replicated over the stage axis)
        stage = jax.lax.axis_index(stage_axis)
        params_here = jax.tree.map(lambda p: p[0], staged_local)

        def run_stage(h):
            def body(h, lp):
                return block_fn(lp, h), None
            h, _ = jax.lax.scan(body, h, params_here)
            return h

        n_ticks = M + S - 1
        buf = jnp.zeros((mb,) + xs_local.shape[2:], x.dtype)
        outs = jnp.zeros_like(xs_local)

        def tick(carry, t):
            buf, outs = carry
            mb_idx = t - stage                      # microbatch at this stage
            feed = jax.lax.dynamic_index_in_dim(
                xs_local, jnp.clip(t, 0, M - 1), axis=0, keepdims=False)
            h_in = jnp.where(stage == 0, feed, buf)
            active = (mb_idx >= 0) & (mb_idx < M)
            h_out = run_stage(h_in)
            h_out = jnp.where(active, h_out, h_in)
            # last stage accumulates its finished microbatch
            write_idx = jnp.clip(mb_idx, 0, M - 1)
            do_write = active & (stage == S - 1)
            upd = jax.lax.dynamic_update_index_in_dim(
                outs, h_out, write_idx, axis=0)
            outs = jnp.where(do_write, upd, outs)
            # hand activations downstream (stage i -> i+1); the wraparound
            # edge S-1 -> 0 is ignored by stage 0 (it reads `feed`)
            perm = [(i, (i + 1) % S) for i in range(S)]
            buf = jax.lax.ppermute(h_out, stage_axis, perm)
            return (buf, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # only the last stage holds real outputs; replicate via masked psum
        outs = jax.lax.psum(
            jnp.where(stage == S - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    param_specs = jax.tree.map(lambda _: P(stage_axis), staged)
    fn = compat.shard_map(
        per_stage, mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
        axis_names={stage_axis},
        check=False)
    # ctx.hint-style NamedSharding constraints are not valid inside the
    # partial-manual region (the stage axis is Manual there) — disable them
    # for the duration of this trace.
    with ctx.use_mesh(None):
        ys = fn(staged, xs)
    return ys.reshape((B,) + ys.shape[2:])
