"""Distributed: sharding rules, activation hints."""
