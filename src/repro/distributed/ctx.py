"""Activation-sharding hint context.

Model code is mesh-agnostic; the launcher/dry-run installs the active mesh
here and layers call :func:`hint` with symbolic axis roles ("data", "model",
None). Outside a mesh context the hints are no-ops, so smoke tests and
single-host examples run unchanged.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE: dict = {"mesh": None, "data": None, "model": None}

Role = Union[str, None, Tuple[str, ...]]


def set_mesh(mesh: Optional[Mesh]) -> None:
    if mesh is None:
        _ACTIVE.update(mesh=None, data=None, model=None)
        return
    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    _ACTIVE.update(
        mesh=mesh,
        data=(data if len(data) > 1 else (data[0] if data else None)),
        model="model" if "model" in mesh.axis_names else None,
    )


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = dict(_ACTIVE)
    set_mesh(mesh)
    try:
        yield
    finally:
        _ACTIVE.update(prev)


def axis_size(role: str) -> int:
    mesh = _ACTIVE["mesh"]
    ax = _ACTIVE.get(role)
    if mesh is None or ax is None:
        return 1
    axes = ax if isinstance(ax, tuple) else (ax,)
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in axes]))


def hint(x, *roles: Role):
    """with_sharding_constraint by role; silently drops non-divisible axes."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    spec = []
    for dim, role in zip(x.shape, roles):
        ax = _ACTIVE.get(role) if isinstance(role, str) else None
        if ax is None:
            spec.append(None)
            continue
        size = axis_size(role)
        spec.append(ax if (size > 1 and dim % size == 0) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
