"""Distributed flash-decode: shard_map attention over a sequence-sharded
KV cache (beyond-paper optimization, EXPERIMENTS.md §Perf cell C).

The baseline decode shards the KV cache on head_dim because a sequence-
sharded cache makes XLA's SPMD partitioner fall into "involuntary full
rematerialization" on the dynamic-update-slice at ``pos`` (it replicates the
cache slice every step). Here we take manual control:

  * the cache is sharded over the model axis along SEQUENCE — each shard
    owns a contiguous ``Skv / m`` block;
  * the new token's K/V is written ONLY by the owning shard (a local
    dynamic-update-slice behind a mask — no resharding, no copies);
  * each shard computes a partial flash-attention (running max m, denominator
    l, accumulator acc) over its block — exactly the online-softmax state of
    `kernels/decode_attention.py`;
  * partials merge with one tiny ``pmax`` + two ``psum``s of
    [B, Hq, hd]-sized tensors (the log-sum-exp merge), instead of moving the
    cache.

Per-step collective volume drops from O(cache slice copies) to
O(B * Hq * hd) — a few MB — and the f32 cache copies disappear.

Enable with ``repro.distributed.dist_decode.ENABLED = True`` (the hillclimb
driver flips it); `sharding.cache_specs_tree` then emits sequence-sharded
cache specs.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import compat, ctx

# Flipped by the hillclimb driver / launcher; read by sharding rules too.
ENABLED = False


def applicable(Skv: int, Sq: int) -> bool:
    mesh = ctx._ACTIVE["mesh"]
    if not ENABLED or mesh is None or Sq != 1:
        return False
    m = ctx.axis_size("model")
    return m > 1 and Skv % m == 0


def decode_attention(q, k_new, v_new, cache_k, cache_v, pos
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """q: [B,1,Hq,hd]; k_new/v_new: [B,1,Hkv,hd] (rope'd); cache_k/v:
    [B,Skv,Hkv,hd] sequence-sharded over 'model'. pos: scalar int32.

    Returns (out [B,1,Hq,hd], new_cache_k, new_cache_v).
    """
    mesh = ctx._ACTIVE["mesh"]
    model_ax = "model"
    da = ctx._ACTIVE["data"]
    B, Skv, Hkv, hd = cache_k.shape
    Hq = q.shape[2]
    m_size = mesh.shape[model_ax]
    s_local = Skv // m_size
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    def body(q, k_new, v_new, ck, cv, pos):
        idx = jax.lax.axis_index(model_ax)
        # --- local cache write (only the owning shard's DUS is kept) -------
        off = pos - idx * s_local
        safe_off = jnp.clip(off, 0, s_local - 1)
        ck_upd = jax.lax.dynamic_update_slice_in_dim(
            ck, k_new.astype(ck.dtype), safe_off, axis=1)
        cv_upd = jax.lax.dynamic_update_slice_in_dim(
            cv, v_new.astype(cv.dtype), safe_off, axis=1)
        mine = (off >= 0) & (off < s_local)
        ck = jnp.where(mine, ck_upd, ck)
        cv = jnp.where(mine, cv_upd, cv)

        # --- local partial flash attention --------------------------------
        qf = q[:, 0].reshape(B_loc(q), Hkv, group, hd).astype(jnp.float32)
        qf = qf * scale
        kf = ck.astype(jnp.float32)
        s = jnp.einsum("bhgd,bshd->bhgs", qf, kf)          # [B,Hkv,g,s_local]
        jpos = idx * s_local + jnp.arange(s_local)
        valid = jpos[None, None, None, :] <= pos
        s = jnp.where(valid, s, -1e30)
        m_loc = jnp.max(s, axis=-1)                        # [B,Hkv,g]
        p = jnp.exp(s - m_loc[..., None])
        p = jnp.where(valid, p, 0.0)
        l_loc = p.sum(-1)
        acc = jnp.einsum("bhgs,bshd->bhgd", p, cv.astype(jnp.float32))

        # --- log-sum-exp merge across sequence shards ----------------------
        m_glob = jax.lax.pmax(m_loc, model_ax)
        alpha = jnp.exp(m_loc - m_glob)
        l_glob = jax.lax.psum(l_loc * alpha, model_ax)
        acc_glob = jax.lax.psum(acc * alpha[..., None], model_ax)
        out = acc_glob / jnp.maximum(l_glob, 1e-30)[..., None]
        out = out.reshape(q.shape[0], 1, Hq, hd).astype(q.dtype)
        return out, ck, cv

    def B_loc(qq):
        return qq.shape[0]

    dp = da
    in_specs = (P(dp, None, None, None),     # q
                P(dp, None, None, None),     # k_new
                P(dp, None, None, None),     # v_new
                P(dp, model_ax, None, None),  # cache k
                P(dp, model_ax, None, None),  # cache v
                P())                          # pos
    out_specs = (P(dp, None, None, None),
                 P(dp, model_ax, None, None),
                 P(dp, model_ax, None, None))
    fn = compat.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check=False)
    return fn(q, k_new, v_new, cache_k, cache_v, pos)
