"""Gradient compression with error feedback (int8 quantized all-reduce).

For cross-pod (DCN) gradient reduction the wire cost dominates; a standard
distributed-optimization trick is to quantize gradients to int8 with a
per-block scale before the reduction and carry the quantization error into
the next step (error feedback keeps the *accumulated* update unbiased, so
convergence is preserved — Seide et al., Karimireddy et al.).

`compress/decompress` are pure functions usable inside any jit; the
`ErrorFeedback` wrapper threads the residual through the train step
(state lives next to the optimizer moments). 4x wire reduction vs f32,
2x vs bf16.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: jnp.ndarray        # int8 payload
    scale: jnp.ndarray    # f32 per-block scales


def compress(x: jnp.ndarray, block: int = BLOCK) -> Compressed:
    """Symmetric per-block int8 quantization (shape-preserving)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Compressed(q=q, scale=scale[:, 0])


def decompress(c: Compressed, shape, dtype=jnp.float32) -> jnp.ndarray:
    blocks = c.q.astype(jnp.float32) * c.scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def roundtrip_error(x: jnp.ndarray) -> jnp.ndarray:
    c = compress(x)
    return x - decompress(c, x.shape, x.dtype)


class ErrorFeedback:
    """Stateless helpers for error-feedback compression of a grad pytree."""

    @staticmethod
    def init(params) -> Dict:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads, residual) -> Tuple[Dict, Dict]:
        """Returns (compressed-then-decompressed grads, new residual).

        In a real deployment the Compressed payload is what crosses the DCN;
        here the quantize->reduce->dequantize round trip is modeled locally
        and the residual carries the quantization error to the next step.
        """
        def one(g, r):
            g_fb = g.astype(jnp.float32) + r
            c = compress(g_fb)
            g_hat = decompress(c, g.shape, jnp.float32)
            return g_hat, g_fb - g_hat

        out = jax.tree.map(one, grads, residual)
        g_hat = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_r = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return g_hat, new_r
