"""Data-parallel scale-out of the app axis.

The paper's fleet (§3, Fig. 5) spans eight orders of magnitude of per-app
invocation rates, but every app's simulation is independent — the app axis
is embarrassingly parallel. This module is the thin layer that lets the
sweep engines (:mod:`repro.core.simulator`) and the cluster policy-window
scan (:mod:`repro.serving.cluster_vector`) partition each device chunk's
app rows across a 1-D ``("apps",)`` mesh via the version-portable
:func:`repro.distributed.compat.shard_map`.

Bit-identity contract (asserted by ``tests/test_scaleout_conformance.py``):

  * the per-shard program is exactly the single-device program on a row
    slice — no collectives, no cross-app reductions inside any engine scan
    (per-config totals are accumulated host-side in float64, unchanged);
  * shard outputs are concatenated in fixed device order (the mesh order),
    so the assembled arrays are the single-device arrays element for
    element;
  * app counts not divisible by the device count are handled by
    :func:`pad_app_rows`: padded rows carry ``+inf`` timestamps — the same
    padding convention every scan already masks with ``isfinite`` — so
    they provably contribute zero to every accumulator and are sliced off
    the outputs.

The knob rides on ``EngineOptions(devices=...)``: ``None`` keeps the
engines exactly as they were, an int always routes through the sharded
path (``devices=1`` exercises it on one device), ``"auto"`` shards over
every local device. To emulate a multi-device host on CPU, set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in the environment
*before the first jax import* (the recipe ``benchmarks/scaleout.py`` uses
via a subprocess).
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import compat

__all__ = ["APP_AXIS", "mesh_for", "pad_app_rows", "app_sharding",
           "shard_along_apps"]

#: The one mesh axis the reproduction engines shard over.
APP_AXIS = "apps"


def mesh_for(devices: Union[None, int, str]) -> Optional[Mesh]:
    """Resolve an ``EngineOptions.devices`` knob into an app mesh (or None).

    ``None`` (the default) keeps the single-device code paths untouched;
    ``"auto"`` shards over every local device, collapsing to the
    single-device path when only one exists; an int *always* builds a mesh
    over that many devices — ``devices=1`` runs the full sharded machinery
    on one device (how ordinary CI covers this layer) — and raises with the
    forced-host-device recipe when more are requested than exist.
    """
    if devices is None:
        return None
    from ..launch.mesh import make_app_mesh
    if isinstance(devices, str):
        if devices != "auto":
            raise ValueError(f"devices must be None, an int, or 'auto'; "
                             f"got {devices!r}")
        return make_app_mesh() if jax.device_count() > 1 else None
    return make_app_mesh(int(devices))


def pad_app_rows(arr: np.ndarray, multiple: int,
                 fill: float = np.inf) -> np.ndarray:
    """Pad the leading app axis up to a multiple of ``multiple``.

    Padding rows are filled with ``+inf`` timestamps — never finite, so
    every engine step's ``valid``/``isfinite`` mask excludes them and they
    contribute exactly zero to every accumulator (cold counts, waste, OOB,
    histogram state). Callers slice the rows back off the outputs.
    """
    pad = (-arr.shape[0]) % multiple
    if not pad:
        return arr
    return np.concatenate(
        [arr, np.full((pad,) + arr.shape[1:], fill, arr.dtype)])


def app_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """Row sharding for a rank-``ndim`` array with apps on axis 0.

    ``jax.device_put`` with this sharding enqueues one host→device transfer
    per shard — which is what turns the engines' one-chunk-lookahead
    transfer into *per-device* double buffering: every device overlaps its
    next chunk slice's transfer with the current chunk's scan.
    """
    return NamedSharding(mesh, P(APP_AXIS, *([None] * (ndim - 1))))


def shard_along_apps(fn, mesh: Mesh, in_axes, out_axes: int):
    """Partition ``fn`` along the app axis of a 1-D mesh, vmap-style.

    ``in_axes`` has one entry per positional argument — an int naming the
    app axis of every array leaf of that argument, or ``None`` for
    replicated arguments (config blocks, policy knobs, scalars).
    ``out_axes`` is one int naming the app axis of every output leaf
    (negative indices count from the back). Rank-0 leaves are always
    replicated. Output shapes/specs come from ``jax.eval_shape``, so any
    pytree-returning engine scan wraps without per-call bookkeeping.

    There are no collectives inside the engines, so the old-API shim path
    (full-manual shard_map) and the new ``jax.shard_map`` spelling compute
    the same concatenated-in-device-order values — bit-identical to the
    unsharded call on row counts divisible by the mesh (see
    :func:`pad_app_rows` for the remainder).
    """
    axis = mesh.axis_names[0]

    def spec_of(ax):
        def leaf(x):
            nd = np.ndim(x)
            if ax is None or nd == 0:
                return P()
            return P(*([None] * (ax % nd) + [axis]))
        return leaf

    def call(*args):
        if len(args) != len(in_axes):
            raise ValueError(
                f"shard_along_apps: {len(in_axes)} in_axes for "
                f"{len(args)} arguments")
        in_specs = tuple(jax.tree.map(spec_of(ax), arg)
                         for arg, ax in zip(args, in_axes))
        out_specs = jax.tree.map(spec_of(out_axes),
                                 jax.eval_shape(fn, *args))
        return compat.shard_map(fn, mesh, in_specs, out_specs)(*args)

    return call
