"""Sharding rules: parameter, optimizer-state, and input PartitionSpecs.

Parallelism map (DESIGN.md §5):
  * DP  — batch over ("pod", "data")
  * TP  — attention heads / FFN / vocab over "model"
  * EP  — MoE experts over "model"
  * SP  — KV-cache sequence over "model" when KV heads don't divide the axis
  * ZeRO — optimizer state (and fp32 master params) additionally sharded
    over the data axes (first divisible dim), turning the gradient
    all-reduce into reduce-scatter + update + all-gather.

Rules are path-regex driven so every architecture family resolves through
one table; any dim not divisible by the mesh axis size falls back to
replication (never a compile error).
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..launch.mesh import data_axes, model_axis

MP = "model"

# (path regex, spec for the *unstacked* leaf). `mp` marks the TP dim.
_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/table$",              (MP, None)),
    (r"(attn|xattn)/w[qkv]/w$",    (None, MP)),
    (r"(attn|xattn)/w[qkv]/b$",    (MP,)),
    (r"(attn|xattn)/wo/w$",        (MP, None)),
    (r"(mlp|ffn)/(wi|wg)/w$",      (None, MP)),
    (r"(mlp|ffn)/wo/w$",           (MP, None)),
    (r"head/w$",                   (None, MP)),
    # MoE: experts over the model axis (EP)
    (r"moe/router/w$",             (None, None)),
    (r"moe/(wi|wg|wo)$",           (MP, None, None)),
    # Mamba-2
    (r"in_proj/w$",                (None, MP)),
    (r"out_proj/w$",               (MP, None)),
    (r"conv_w$",                   (None, MP)),
    (r"(A_log|dt_bias|D)$",        (MP,)),
    # RG-LRU
    (r"(wx|wy|wa|wi)/w$",          (None, MP)),
    (r"(wx|wy|wa|wi)/b$",          (MP,)),
    (r"out/w$",                    (MP, None)),
    (r"lam$",                      (MP,)),
)

_STACKED = re.compile(r"(^|/)(layers|blocks)/")


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _fit(spec: Tuple[Optional[str], ...], shape, mesh: Mesh) -> P:
    """Drop axes whose dim isn't divisible by the mesh axis size."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
        else:
            size = int(np.prod([mesh.shape[a] for a in
                                ((ax,) if isinstance(ax, str) else ax)]))
            out.append(ax if dim % size == 0 else None)
    return P(*out)


def param_spec(path_str: str, shape, mesh: Mesh,
               cfg: Optional[ModelConfig] = None) -> P:
    stacked = bool(_STACKED.search(path_str))
    # GQA: if the KV heads don't divide the model axis, shard-slicing wk/wv
    # would cut across head boundaries — replicate them instead (K/V
    # projections are small; this is the Megatron KV-replication scheme).
    if cfg is not None and re.search(r"(attn|xattn)/w[kv]/(w|b)$", path_str):
        if cfg.n_kv_heads % mesh.shape[MP] != 0:
            return P()
    for pat, spec in _RULES:
        if re.search(pat, path_str):
            if stacked:
                spec = (None,) + tuple(spec)
            spec = spec[: len(shape)]
            spec = tuple(spec) + (None,) * (len(shape) - len(spec))
            return _fit(spec, shape, mesh)
    return P()  # replicate (norms, biases, scalars)


def param_specs(params_or_specs, mesh: Mesh, cfg: Optional[ModelConfig] = None):
    """Pytree of PartitionSpec for a parameter pytree (arrays or SDS)."""
    def fn(path, leaf):
        return param_spec(_path_str(path), leaf.shape, mesh, cfg)
    return jax.tree_util.tree_map_with_path(fn, params_or_specs)


ZERO_SKIP_STACKED_DIM = True


def zero_spec(spec: P, shape, mesh: Mesh, stacked: bool = False) -> P:
    """Add data-axis sharding (ZeRO) to the first divisible unsharded dim.

    For layer-stacked leaves the leading (layer) dim is skipped by default:
    sharding it puts each layer's optimizer state wholly on one data shard,
    which forces the per-layer gradient reduction inside the backward scan
    to be a full all-reduce (2x the bytes of a reduce-scatter, in f32).
    Sharding an inner dim lets SPMD emit reduce-scatters instead.
    """
    daxes = data_axes(mesh)
    if not daxes:
        return spec
    dsize = int(np.prod([mesh.shape[a] for a in daxes]))
    out = list(spec) + [None] * (len(shape) - len(spec))
    start = 1 if (stacked and ZERO_SKIP_STACKED_DIM and len(shape) > 1) else 0
    for i in range(start, len(shape)):
        dim, ax = shape[i], out[i]
        if ax is None and dim % dsize == 0 and dim >= dsize:
            out[i] = daxes if len(daxes) > 1 else daxes[0]
            return P(*out)
    if start == 1 and shape[0] % dsize == 0 and out[0] is None:
        out[0] = daxes if len(daxes) > 1 else daxes[0]  # fallback: layer dim
        return P(*out)
    return spec


def opt_specs(params_or_specs, mesh: Mesh, cfg: Optional[ModelConfig] = None):
    """ZeRO-sharded specs for optimizer state / fp32 master params."""
    def fn(path, leaf):
        ps = _path_str(path)
        base = param_spec(ps, leaf.shape, mesh, cfg)
        return zero_spec(base, leaf.shape, mesh,
                         stacked=bool(_STACKED.search(ps)))
    return jax.tree_util.tree_map_with_path(fn, params_or_specs)


# ---------------------------------------------------------------------------
# Input / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, specs: Dict[str, Any], mesh: Mesh):
    """PartitionSpecs for input_specs() structures (divisibility-guarded:
    a batch of 1 — long_500k — simply drops the data axis)."""
    da = data_axes(mesh)
    dp = da if len(da) > 1 else (da[0] if da else None)
    out: Dict[str, Any] = {}
    for name, leaf in specs.items():
        if name == "cache":
            out[name] = cache_specs_tree(cfg, leaf, mesh)
        elif name == "token":
            out[name] = _fit((dp,), leaf.shape, mesh)
        elif name in ("tokens", "labels", "mask"):
            out[name] = _fit((dp, None), leaf.shape, mesh)
        elif name == "embeds":
            out[name] = _fit((dp, None, None), leaf.shape, mesh)
        else:
            out[name] = P()
    return out


def cache_specs_tree(cfg: ModelConfig, cache_tree, mesh: Mesh):
    """Decode-cache shardings.

    KV caches: batch over data; heads over model when divisible, else the
    sequence dim (SP). SSM states: heads over model. Ring buffers follow the
    KV rule. Scalars replicated.
    """
    da = data_axes(mesh)
    dp = da if len(da) > 1 else (da[0] if da else None)
    msize = mesh.shape[MP]

    def fn(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        if ps.endswith("pos"):
            return P()
        if ps.endswith("enc"):
            return P(dp, None, None)
        if re.search(r"(^|/)(k|v|ring_k|ring_v)$", ps):
            # [L, B, S, Hkv, hd]. Preference order:
            #  0. distributed flash-decode enabled -> sequence-sharded (the
            #     shard_map path owns the update + lse-merge);
            #  1. KV heads over model (clean TP);
            #  2. head_dim over model — keeps the decode cache update
            #     (dynamic_update_slice at `pos`) fully local, avoiding the
            #     involuntary resharding a sequence-sharded cache causes;
            #  3. sequence (SP) as a last resort.
            from . import dist_decode
            heads, hd = shape[3], shape[4]
            if (dist_decode.ENABLED and "ring" not in ps
                    and shape[2] % msize == 0):
                return P(None, dp, MP, None, None)
            if heads % msize == 0:
                return P(None, dp, None, MP, None)
            if hd % msize == 0:
                return P(None, dp, None, None, MP)
            if shape[2] % msize == 0:
                return P(None, dp, MP, None, None)
            return P(None, dp, None, None, None)
        if ps.endswith("ssm"):        # [L, B, H, N, Pdim]
            return P(None, dp, MP if shape[2] % msize == 0 else None, None, None)
        if re.search(r"conv\d?$", ps):  # [L, B, W-1, C]
            return P(None, dp, None, MP if shape[3] % msize == 0 else None)
        if re.search(r"(^|/)h\d?$", ps):
            return P(dp, MP if shape[-1] % msize == 0 else None)
        return P()

    def fn_wrap(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        # hybrid cache leaves live under blocks/: [n_super, B, ...]
        if re.search(r"(^|/)(h1|h2)$", ps):
            spec = P(None, dp, MP if shape[2] % msize == 0 else None)
        elif re.search(r"(^|/)(conv1|conv2)$", ps):
            spec = P(None, dp, None, MP if shape[3] % msize == 0 else None)
        elif re.search(r"(^|/)tail\d+/h$", ps):
            spec = P(dp, MP if shape[1] % msize == 0 else None)
        elif re.search(r"(^|/)tail\d+/conv$", ps):
            spec = P(dp, None, MP if shape[2] % msize == 0 else None)
        elif re.search(r"(^|/)conv$", ps):  # ssm conv: [L, B, W-1, C]
            spec = P(None, dp, None, MP if shape[3] % msize == 0 else None)
        else:
            spec = fn(path, leaf)
        return _fit(tuple(spec) + (None,) * (len(shape) - len(spec)),
                    shape, mesh)

    return jax.tree_util.tree_map_with_path(fn_wrap, cache_tree)


def named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
