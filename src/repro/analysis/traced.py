"""Traced-context discovery: which functions run under JAX tracing.

Three syntactic sources, matching how this repo actually enters tracing:

  * functions decorated with ``jax.jit`` / ``jit`` / ``partial(jax.jit,
    static_argnums=...)`` (the ``functools.partial`` spelling too);
  * functions (or lambdas, or ``partial(fn, ...)`` wrappers) passed as the
    first argument of ``jax.lax.scan`` — scan step bodies are the hot path
    every engine lives in;
  * kernel bodies passed to ``pl.pallas_call`` (directly or via partial).

For jitted functions the ``static_argnums`` / ``static_argnames`` are
resolved to parameter names: a python ``if`` on a *static* argument is
standard jit practice, not a tracer leak. Everything is intraprocedural —
this is a linter, not an abstract interpreter — so helpers *called from*
traced code are not visited (the single-source policy_math helpers keep
their host/traced polymorphism without noise).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Set, Tuple, Union

from .framework import dotted_name

__all__ = ["TracedContext", "find_traced_contexts"]

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]

_JIT_NAMES = {"jax.jit", "jit"}
_SCAN_NAMES = {"jax.lax.scan", "lax.scan"}
_PALLAS_NAMES = {"pl.pallas_call", "pallas.pallas_call",
                 "pltpu.pallas_call"}
_PARTIAL_NAMES = {"partial", "functools.partial"}


@dataclasses.dataclass
class TracedContext:
    func: FuncNode
    kind: str                    # "jit" | "scan-body" | "pallas-kernel"
    static_params: Set[str]      # params known static under jit

    @property
    def params(self) -> List[str]:
        a = self.func.args
        names = [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names

    @property
    def traced_params(self) -> Set[str]:
        return set(self.params) - self.static_params


def _param_names_positional(func: FuncNode) -> List[str]:
    a = func.args
    return [p.arg for p in (*a.posonlyargs, *a.args)]


def _static_from_jit_call(call: ast.Call, func: FuncNode) -> Set[str]:
    """Resolve static_argnums/static_argnames of a ``partial(jax.jit, ...)``
    or ``jax.jit(...)`` decorator call to parameter names."""
    statics: Set[str] = set()
    pos = _param_names_positional(func)
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            for idx in _int_elements(kw.value):
                if 0 <= idx < len(pos):
                    statics.add(pos[idx])
        elif kw.arg == "static_argnames":
            statics |= set(_str_elements(kw.value))
    return statics


def _int_elements(node: ast.AST) -> List[int]:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for el in node.elts:
            if isinstance(el, ast.Constant) and isinstance(el.value, int):
                out.append(el.value)
        return out
    return []


def _str_elements(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        return [el.value for el in node.elts
                if isinstance(el, ast.Constant) and isinstance(el.value, str)]
    return []


def _is_jit_decorator(dec: ast.AST, func: FuncNode
                      ) -> Optional[Set[str]]:
    """None if not a jit decorator, else the set of static param names."""
    if dotted_name(dec) in _JIT_NAMES:
        return set()
    if isinstance(dec, ast.Call):
        name = dotted_name(dec.func)
        if name in _JIT_NAMES:
            return _static_from_jit_call(dec, func)
        if name in _PARTIAL_NAMES and dec.args:
            if dotted_name(dec.args[0]) in _JIT_NAMES:
                return _static_from_jit_call(dec, func)
    return None


def _callable_target(node: ast.AST,
                     by_name: Dict[str, FuncNode]) -> Optional[FuncNode]:
    """Resolve a callable expression to a local function/lambda node."""
    if isinstance(node, ast.Lambda):
        return node
    name = dotted_name(node)
    if name is not None:
        return by_name.get(name)
    if isinstance(node, ast.Call) and \
            dotted_name(node.func) in _PARTIAL_NAMES and node.args:
        return _callable_target(node.args[0], by_name)
    return None


def find_traced_contexts(tree: ast.Module) -> List[TracedContext]:
    by_name: Dict[str, FuncNode] = {}
    funcs: List[FuncNode] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, node)
            funcs.append(node)
        elif isinstance(node, ast.Assign) and isinstance(node.value,
                                                         ast.Lambda):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    by_name.setdefault(tgt.id, node.value)

    seen: Dict[int, TracedContext] = {}

    def add(func: Optional[FuncNode], kind: str,
            statics: Optional[Set[str]] = None) -> None:
        if func is None or id(func) in seen:
            return
        seen[id(func)] = TracedContext(func=func, kind=kind,
                                       static_params=statics or set())

    for func in funcs:
        if isinstance(func, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in func.decorator_list:
                statics = _is_jit_decorator(dec, func)
                if statics is not None:
                    add(func, "jit", statics)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = dotted_name(node.func)
        if name in _SCAN_NAMES and node.args:
            add(_callable_target(node.args[0], by_name), "scan-body")
        elif name in _PALLAS_NAMES and node.args:
            add(_callable_target(node.args[0], by_name), "pallas-kernel")
        elif name in _JIT_NAMES and node.args:
            target = _callable_target(node.args[0], by_name)
            if target is not None:
                statics = _static_from_jit_call(node, target)
                add(target, "jit", statics)

    return list(seen.values())
