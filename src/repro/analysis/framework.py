"""Pass framework: findings, per-rule config, suppressions, file driving.

Design (mirrors the sanitizer/conformance philosophy of the test suite):

  * a :class:`Rule` is a named pass with a ``check(module, config)``
    generator — rules are pure functions of one module's AST, so the whole
    suite is trivially parallel-safe and fixture-testable on virtual paths;
  * :class:`Finding` records are stable, sortable, and JSON-serializable —
    the ``--json`` schema (``version`` 1) is pinned by ``tests/test_lint.py``;
  * suppressions are *inline and reasoned*: ``# repro-lint: ignore[rule]
    -- reason``. A directive without a reason does not suppress and is
    itself reported (rule ``lint-directive``) — the point of the linter is
    that every exception to a contract is written down next to the code.

Scope matching uses the module's ``relkey`` — its path from the last
``repro`` package segment (``repro/kernels/histogram.py``) — so the same
rules fire identically from the repo root, from ``src/``, and on the
in-memory fixture snippets the tests feed through :func:`run_source`.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import subprocess
import tokenize
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "Finding", "LintConfig", "Module", "Rule", "Suppression",
    "changed_files", "dotted_name", "iter_python_files",
    "parse_suppressions", "render_human", "render_json", "run_paths",
    "run_source",
]

JSON_SCHEMA_VERSION = 1

#: Sentinel rule name for malformed / reasonless suppression directives.
DIRECTIVE_RULE = "lint-directive"
#: Sentinel rule name for files the parser rejects.
PARSE_RULE = "parse-error"

_SUPPRESS_RE = re.compile(
    r"#\s*repro-lint:\s*ignore\[(?P<rules>[^\]]*)\]"
    r"(?:\s*--\s*(?P<reason>\S.*))?")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a source location."""
    file: str
    line: int
    col: int
    rule: str
    message: str

    def to_json(self) -> dict:
        return {"file": self.file, "line": self.line, "col": self.col,
                "rule": self.rule, "message": self.message}

    def render(self) -> str:
        return f"{self.file}:{self.line}:{self.col}: [{self.rule}] " \
               f"{self.message}"


@dataclasses.dataclass(frozen=True)
class Suppression:
    """A parsed ``# repro-lint: ignore[...]`` directive."""
    line: int                 # 1-based line the directive sits on
    rules: Tuple[str, ...]    # rule names, or ("*",)
    reason: Optional[str]     # None => invalid (reasons are mandatory)
    standalone: bool          # comment-only line: covers the next CODE line
    target: Optional[int] = None   # resolved covered line (parse-time)

    def covers(self, rule: str, line: int) -> bool:
        if self.reason is None:
            return False
        target = self.target if self.target is not None else (
            self.line + 1 if self.standalone else self.line)
        return line == target and ("*" in self.rules or rule in self.rules)


@dataclasses.dataclass(frozen=True)
class LintConfig:
    """Per-rule knobs with repo-contract defaults.

    Everything is overridable so the fixture tests can point rules at
    virtual trees, but the defaults ARE the contract this repository
    enforces in CI.
    """
    # single-source-decision-math: the one file allowed to spell the math.
    policy_math_relkey: str = "repro/core/policy_math.py"
    # x64-discipline: files that lower through Mosaic (no f64 on TPU).
    kernel_scopes: Tuple[str, ...] = ("repro/kernels/",)
    # x64-discipline: names that smell like absolute-time columns. A direct
    # float32 cast of one of these (outside a function that also rebases)
    # is exactly the PR-2 parity bug class.
    time_name_pattern: str = \
        r"(?:^|_)(?:t|ts|time|times|timestamp|timestamps)(?:64|_abs|_min)?$"
    # determinism: packages whose outputs must be seed-deterministic.
    determinism_scopes: Tuple[str, ...] = (
        "repro/core/", "repro/serving/", "repro/kernels/",
        "repro/forecast/")
    # determinism: np.random attributes that are fine (counter/seeded RNG
    # construction rather than global-state draws).
    rng_allowed: Tuple[str, ...] = (
        "default_rng", "Generator", "SeedSequence", "PCG64", "Philox",
        "BitGenerator")
    # deprecation-hygiene: removed name -> replacement hint.
    removed_calls: Tuple[Tuple[str, str], ...] = (
        ("simulate", "experiment.run(trace, spec)"),
        ("simulate_fixed_batch", "experiment.run(trace, FixedSpec(ka))"),
        ("simulate_hybrid_batch", "experiment.run(trace, HybridSpec(...))"),
        ("simulate_hybrid_batch_reference",
         'experiment.run(trace, spec, engine="reference")'),
    )
    removed_attrs: Tuple[Tuple[str, str], ...] = (
        ("synthesize", "WorkloadSpec.uniform(...).materialize()"),
    )
    # pytree-completeness: the registration helper every spec family uses.
    register_helpers: Tuple[str, ...] = ("_register_pytree",)
    # conformance-coverage: per-module public entry points that must appear
    # (as calls) in some conformance test file.
    conformance_entry_points: Tuple[Tuple[str, Tuple[str, ...]], ...] = (
        ("repro/core/experiment.py", ("run", "sweep")),
        ("repro/serving/cluster_vector.py", ("run_cluster",
                                             "sweep_cluster")),
        ("repro/forecast/arima_batched.py", ("fit_arima_grid",)),
    )
    # conformance-coverage: test tree location (resolved by walking up from
    # the linted file; absolute paths are honored as-is) and file pattern.
    conformance_test_dir: str = "tests"
    conformance_test_glob: str = "test_*conformance*.py"


@dataclasses.dataclass
class Module:
    """One parsed source file plus the metadata rules key off."""
    path: str                  # path as given (display / finding key)
    relkey: str                # normalized repro-package-relative posix key
    source: str
    tree: ast.Module
    suppressions: List[Suppression]

    def in_scope(self, scopes: Sequence[str]) -> bool:
        return any(self.relkey.startswith(s) for s in scopes)


class Rule:
    """Base class for passes. Subclasses set ``name``/``description`` and
    implement :meth:`check` as a generator of findings."""

    name: str = "base"
    description: str = ""

    def check(self, module: Module,
              config: LintConfig) -> Iterator[Finding]:  # pragma: no cover
        raise NotImplementedError

    def finding(self, module: Module, node: ast.AST, message: str) -> Finding:
        return Finding(file=module.path, line=getattr(node, "lineno", 1),
                       col=getattr(node, "col_offset", 0) + 1,
                       rule=self.name, message=message)


# ---------------------------------------------------------------------------
# Shared AST utilities
# ---------------------------------------------------------------------------


def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return None if base is None else f"{base}.{node.attr}"
    return None


def terminal_name(node: ast.AST) -> Optional[str]:
    """The last identifier of a Name/Attribute chain (``a.b.c`` -> ``c``)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def relkey_for(path: str) -> str:
    """Path from the last ``repro`` package segment, posix-separated.

    Makes scope matching invariant to where the tree is rooted (repo root,
    ``src/``, a tmp fixture dir, or a virtual test path).
    """
    parts = [p for p in re.split(r"[\\/]+", path) if p not in ("", ".")]
    if "repro" in parts:
        idx = len(parts) - 1 - parts[::-1].index("repro")
        parts = parts[idx:]
    return "/".join(parts)


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------


def parse_suppressions(source: str) -> List[Suppression]:
    """Directives from real COMMENT tokens only — a docstring that *talks
    about* the syntax (like this package's own docs) is not a directive."""
    out: List[Suppression] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [t for t in tokens if t.type == tokenize.COMMENT]
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return out
    lines = source.splitlines()
    for tok in comments:
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        standalone = tok.line[:tok.start[1]].strip() == ""
        target = tok.start[0]
        if standalone:
            # cover the next code line, skipping the rest of the comment
            # block (multi-line reasons) and blank lines
            target += 1
            while target <= len(lines) and (
                    not lines[target - 1].strip()
                    or lines[target - 1].lstrip().startswith("#")):
                target += 1
        out.append(Suppression(line=tok.start[0], rules=rules or ("*",),
                               reason=m.group("reason"),
                               standalone=standalone, target=target))
    return out


def _directive_findings(module: Module, known_rules: Sequence[str]
                        ) -> List[Finding]:
    """Malformed directives are findings themselves: a suppression without
    a reason (or naming an unknown rule) silently rots the contract it was
    meant to document."""
    out = []
    known = set(known_rules) | {"*", DIRECTIVE_RULE, PARSE_RULE}
    for s in module.suppressions:
        if s.reason is None:
            out.append(Finding(
                module.path, s.line, 1, DIRECTIVE_RULE,
                "suppression without a reason: write "
                "'# repro-lint: ignore[rule] -- why this is safe'"))
        for r in s.rules:
            if r not in known:
                out.append(Finding(
                    module.path, s.line, 1, DIRECTIVE_RULE,
                    f"suppression names unknown rule {r!r}"))
    return out


# ---------------------------------------------------------------------------
# Driving
# ---------------------------------------------------------------------------


def run_source(source: str, path: str, rules: Sequence[Rule],
               config: Optional[LintConfig] = None
               ) -> Tuple[List[Finding], int]:
    """Lint one in-memory module. Returns (findings, n_suppressed)."""
    config = config or LintConfig()
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding(path, e.lineno or 1, (e.offset or 0) + 1, PARSE_RULE,
                        f"cannot parse: {e.msg}")], 0
    module = Module(path=path, relkey=relkey_for(path), source=source,
                    tree=tree, suppressions=parse_suppressions(source))
    raw: List[Finding] = []
    for rule in rules:
        raw.extend(rule.check(module, config))
    kept, suppressed = [], 0
    for f in raw:
        if any(s.covers(f.rule, f.line) for s in module.suppressions):
            suppressed += 1
        else:
            kept.append(f)
    kept.extend(_directive_findings(module, [r.name for r in rules]))
    return sorted(kept), suppressed


def iter_python_files(paths: Sequence[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        elif os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(d for d in dirnames
                                     if not d.startswith((".", "__pycache__")))
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        yield os.path.join(dirpath, fn)


def changed_files(paths: Sequence[str]) -> List[str]:
    """The ``--changed`` working set: files touched vs HEAD plus untracked,
    intersected with ``paths``. Requires a git checkout."""
    def git(*args: str) -> List[str]:
        out = subprocess.run(["git", *args], check=True,
                             capture_output=True, text=True).stdout
        return [l for l in out.splitlines() if l]

    names = set(git("diff", "--name-only", "HEAD", "--"))
    names |= set(git("ls-files", "--others", "--exclude-standard"))
    wanted = []
    roots = [os.path.normpath(p) for p in paths]
    for name in sorted(names):
        if not name.endswith(".py") or not os.path.exists(name):
            continue
        norm = os.path.normpath(name)
        if any(norm == r or norm.startswith(r + os.sep) for r in roots):
            wanted.append(name)
    return wanted


def run_paths(paths: Sequence[str], rules: Sequence[Rule],
              config: Optional[LintConfig] = None,
              changed: bool = False) -> dict:
    """Lint files under ``paths``; returns the report dict the CLI renders
    (the same object ``--json`` serializes)."""
    config = config or LintConfig()
    files = changed_files(paths) if changed else list(iter_python_files(paths))
    findings: List[Finding] = []
    suppressed = 0
    for fp in files:
        with open(fp, "r", encoding="utf-8") as fh:
            src = fh.read()
        got, n_sup = run_source(src, fp, rules, config)
        findings.extend(got)
        suppressed += n_sup
    return {
        "version": JSON_SCHEMA_VERSION,
        "counts": {"files": len(files), "findings": len(findings),
                   "suppressed": suppressed},
        "findings": sorted(findings),
    }


def render_json(report: dict) -> str:
    out = dict(report)
    out["findings"] = [f.to_json() for f in report["findings"]]
    return json.dumps(out, indent=2, sort_keys=True)


def render_human(report: dict) -> str:
    lines = [f.render() for f in report["findings"]]
    c = report["counts"]
    lines.append(f"{c['findings']} finding(s) in {c['files']} file(s) "
                 f"({c['suppressed']} suppressed)")
    return "\n".join(lines)
