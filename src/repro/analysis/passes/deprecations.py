"""Pass 6 — deprecation hygiene: removed entry points stay removed.

PR 5 deleted the ``simulate*`` free functions and ``Trace.synthesize``
in favor of the declarative ``experiment.run(trace, spec)`` /
``WorkloadSpec`` API; ``core.simulator.__getattr__`` turns old imports
into loud errors at *runtime*. This pass moves that error to lint time:
calling or importing a removed name (or touching ``.synthesize`` on
anything) is flagged with the replacement spelled out. A module that
*defines* one of these names locally (the fixtures, or the tombstone
table itself) is of course free to mention it.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, Set

from ..framework import Finding, LintConfig, Module, Rule, dotted_name


def _locally_defined(tree: ast.Module) -> Set[str]:
    defined: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            defined.add(node.name)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    defined.add(tgt.id)
    return defined


class DeprecationHygiene(Rule):
    name = "deprecation-hygiene"
    description = "use of removed simulate*/Trace.synthesize entry points"

    def check(self, module: Module, config: LintConfig) -> Iterator[Finding]:
        removed: Dict[str, str] = dict(config.removed_calls)
        removed_attrs: Dict[str, str] = dict(config.removed_attrs)
        defined = _locally_defined(module.tree)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name is None:
                    continue
                tail = name.rpartition(".")[2]
                if tail in removed and tail not in defined:
                    yield self.finding(
                        module, node,
                        f"{tail}() was removed; use {removed[tail]}")
            elif isinstance(node, ast.ImportFrom):
                for alias in node.names:
                    if alias.name in removed:
                        yield self.finding(
                            module, node,
                            f"import of removed {alias.name!r}; use "
                            f"{removed[alias.name]}")
            elif isinstance(node, ast.Attribute):
                if node.attr in removed_attrs and node.attr not in defined:
                    yield self.finding(
                        module, node,
                        f".{node.attr} was removed; use "
                        f"{removed_attrs[node.attr]}")
