"""Pass 1 — single-sourced decision math (the PR 2 "grep invariant").

Every engine reproduces the paper's §4 policy bit-exactly because the
percentile/margin/verdict arithmetic is written ONCE, in
:mod:`repro.core.policy_math`. Re-deriving any of it elsewhere (even
"equivalently") reintroduces the float-rounding parity bugs PRs 1-2 fixed.
Outside that module this pass flags:

  * ``PCT_SCALE`` used in arithmetic or comparisons — scaled-percentile
    math belongs behind ``percentile_threshold_scaled*`` /
    ``first_bin_ge_scaled``;
  * ``1 ± margin`` expressions — callers must use ``margin_factors`` (the
    host-side single rounding is what makes traced margin axes bit-equal);
  * inline warm-verdict conjunctions (``it >= load & it <= unload``) —
    callers must use ``warm_from_bounds`` / ``idle_from_bounds``.

Passing ``PCT_SCALE`` around as an opaque value (imports, function
arguments) is fine; only *doing math* with it is flagged.
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Optional, Tuple

from ..framework import Finding, LintConfig, Module, Rule, terminal_name

_MARGIN_RE = re.compile(r"margin", re.IGNORECASE)
# Dtype casts are transparent when deciding whether PCT_SCALE feeds
# arithmetic: ``x * jnp.int32(PCT_SCALE)`` is still scaled-threshold math.
_CAST_NAMES = {"int8", "int16", "int32", "int64", "uint8", "uint16",
               "uint32", "uint64", "float32", "float64", "astype",
               "asarray", "array"}
_LOAD_RE = re.compile(r"(?:^|_)(?:load|prewarm|pre_warm)", re.IGNORECASE)
_UNLOAD_RE = re.compile(r"(?:^|_)(?:unload|keep)", re.IGNORECASE)


def _build_parents(tree: ast.AST) -> dict:
    parents = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[id(child)] = node
    return parents


class SingleSourceDecisionMath(Rule):
    name = "single-source-decision-math"
    description = ("percentile/margin/verdict/PCT_SCALE arithmetic outside "
                   "core/policy_math.py")

    def check(self, module: Module, config: LintConfig) -> Iterator[Finding]:
        if module.relkey == config.policy_math_relkey:
            return
        parents = _build_parents(module.tree)
        for node in ast.walk(module.tree):
            yield from self._check_pct_scale(module, node, parents)
            yield from self._check_margin(module, node)
            yield from self._check_verdict(module, node)

    # -- PCT_SCALE arithmetic ------------------------------------------------

    def _check_pct_scale(self, module: Module, node: ast.AST,
                         parents: dict) -> Iterator[Finding]:
        if terminal_name(node) != "PCT_SCALE":
            return
        cur = parents.get(id(node))
        # skip the attribute chain the name sits in (policy_math.PCT_SCALE)
        while isinstance(cur, ast.Attribute):
            cur = parents.get(id(cur))
        while cur is not None:
            if isinstance(cur, (ast.BinOp, ast.Compare, ast.UnaryOp,
                                ast.BoolOp)):
                yield self.finding(
                    module, node,
                    "PCT_SCALE arithmetic outside core/policy_math.py; use "
                    "percentile_threshold_scaled*/first_bin_ge_scaled (or "
                    "add a policy_math helper)")
                return
            if isinstance(cur, ast.Call) and \
                    terminal_name(cur.func) in _CAST_NAMES:
                cur = parents.get(id(cur))   # see through dtype casts
                continue
            if isinstance(cur, (ast.stmt, ast.Call)):
                return        # opaque use: argument / assignment / import
            cur = parents.get(id(cur))

    # -- 1 +/- margin --------------------------------------------------------

    def _check_margin(self, module: Module,
                      node: ast.AST) -> Iterator[Finding]:
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, (ast.Add, ast.Sub))):
            return
        sides = (node.left, node.right)
        has_one = any(isinstance(s, ast.Constant) and s.value in (1, 1.0)
                      for s in sides)
        margin = any(
            (t := terminal_name(s)) is not None and _MARGIN_RE.search(t)
            for s in sides)
        if has_one and margin:
            yield self.finding(
                module, node,
                "inline '1 +/- margin' arithmetic; use "
                "policy_math.margin_factors (one host-side rounding keeps "
                "traced margin axes bit-identical)")

    # -- warm-verdict conjunction -------------------------------------------

    def _check_verdict(self, module: Module,
                       node: ast.AST) -> Iterator[Finding]:
        if isinstance(node, ast.BoolOp) and isinstance(node.op, ast.And):
            operands = node.values
        elif isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitAnd):
            operands = [node.left, node.right]
        else:
            return
        lower: dict = {}
        upper: dict = {}
        for op in operands:
            cmp = self._normalized_compare(op)
            if cmp is None:
                continue
            subject, bound, kind = cmp
            (lower if kind == "lower" else upper)[subject] = bound
        for subject in set(lower) & set(upper):
            if _LOAD_RE.search(lower[subject]) and \
                    _UNLOAD_RE.search(upper[subject]):
                yield self.finding(
                    module, node,
                    f"inline warm-verdict conjunction on {subject!r}; use "
                    "policy_math.warm_from_bounds / idle_from_bounds")
                return

    @staticmethod
    def _normalized_compare(node: ast.AST
                            ) -> Optional[Tuple[str, str, str]]:
        """``x >= load`` / ``load <= x`` -> ("x", "load", "lower")."""
        if not (isinstance(node, ast.Compare) and len(node.ops) == 1):
            return None
        left = terminal_name(node.left)
        right = terminal_name(node.comparators[0])
        if left is None or right is None:
            return None
        op = node.ops[0]
        if isinstance(op, (ast.GtE, ast.Gt)):     # x >= bound
            subject, bound, kind = left, right, "lower"
        elif isinstance(op, (ast.LtE, ast.Lt)):   # x <= bound
            subject, bound, kind = left, right, "upper"
        else:
            return None
        if _LOAD_RE.search(subject) or _UNLOAD_RE.search(subject):
            # reversed spelling: bound on the left ("load <= x")
            subject, bound = bound, subject
            kind = "upper" if kind == "lower" else "lower"
        return subject, bound, kind
