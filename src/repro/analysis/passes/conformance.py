"""Pass 7 — conformance coverage: public engine entry points stay pinned.

The repo's correctness story is the conformance suite: every engine
(scalar oracle, fused scan, pallas, reference, cluster, batched forecast)
is pinned bit-exactly against an independent implementation by a
``tests/test_*conformance*.py`` file. That only works if new public entry
points actually *enter* that suite — a subsystem that ships with its own
private tests can silently drift from the oracle contract.

This pass closes the loop: for each configured entry-point module, every
listed public function must be mentioned (as a call, ``name(...)``) in at
least one conformance test file. The test tree is found by walking up
from the linted file toward the filesystem root until a directory named
``config.conformance_test_dir`` appears — so the rule fires identically
from the repo root, from ``src/``, and on fixture trees the lint tests
point at a temp directory.
"""
from __future__ import annotations

import ast
import glob
import os
import re
from typing import Iterator, List, Optional

from ..framework import Finding, LintConfig, Module, Rule


def _resolve_test_dir(module_path: str, test_dir: str) -> Optional[str]:
    """Nearest ancestor of ``module_path`` containing ``test_dir``.

    ``test_dir`` may also be an absolute path (fixture trees), which is
    returned as-is when it exists.
    """
    if os.path.isabs(test_dir):
        return test_dir if os.path.isdir(test_dir) else None
    cur = os.path.dirname(os.path.abspath(module_path))
    while True:
        cand = os.path.join(cur, test_dir)
        if os.path.isdir(cand):
            return cand
        parent = os.path.dirname(cur)
        if parent == cur:
            return None
        cur = parent


def _conformance_sources(test_dir: str, pattern: str) -> List[str]:
    out = []
    for fp in sorted(glob.glob(os.path.join(test_dir, pattern))):
        try:
            with open(fp, "r", encoding="utf-8") as fh:
                out.append(fh.read())
        except OSError:
            continue
    return out


class ConformanceCoverage(Rule):
    name = "conformance-coverage"
    description = ("public engine entry points must be exercised by a "
                   "tests/test_*conformance* file")

    def check(self, module: Module, config: LintConfig) -> Iterator[Finding]:
        wanted = dict(config.conformance_entry_points).get(module.relkey)
        if not wanted:
            return
        test_dir = _resolve_test_dir(module.path,
                                     config.conformance_test_dir)
        defs = {node.name: node for node in module.tree.body
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))}
        if test_dir is None:
            for name in wanted:
                if name in defs:
                    yield self.finding(
                        module, defs[name],
                        f"cannot verify conformance coverage of {name}(): "
                        f"no {config.conformance_test_dir!r} directory on "
                        f"the path to the filesystem root")
            return
        sources = _conformance_sources(test_dir,
                                       config.conformance_test_glob)
        for name in wanted:
            node = defs.get(name)
            if node is None:
                continue  # entry point moved/renamed: nothing to anchor
            called = re.compile(rf"\b{re.escape(name)}\s*\(")
            if not any(called.search(src) for src in sources):
                yield self.finding(
                    module, node,
                    f"public entry point {name}() is not exercised by any "
                    f"{config.conformance_test_glob} file under "
                    f"{config.conformance_test_dir}/ — pin it against an "
                    f"independent oracle in the conformance suite")
