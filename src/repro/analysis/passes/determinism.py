"""Pass 4 — nondeterminism sources in seed-deterministic packages.

Every result in ``repro/{core,serving,kernels}`` must be a pure function
of ``(trace, spec, seed)`` — that is what lets conformance tests pin
engine outputs bit-exactly and lets sweeps be resumed/sharded without
drift. Inside those scopes this pass flags:

  * global NumPy RNG draws (``np.random.rand`` etc.) — constructing
    seeded generators (``np.random.default_rng``, ``Generator``,
    ``SeedSequence``, ...) is the sanctioned pattern and stays allowed;
  * stdlib ``random.*`` module-level draws (when ``import random`` is in
    the module — a local variable named ``random`` is not the module);
  * wall-clock reads: ``time.time/time_ns/monotonic/perf_counter``,
    ``datetime.now/utcnow/today``;
  * entropy taps: ``os.urandom``, ``uuid.uuid4``, ``secrets.*``.

Measured wall-clock *latency reporting* is a legitimate exception (the
serving engine's deliverable is the measurement) — suppress those sites
with a reasoned directive.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..framework import Finding, LintConfig, Module, Rule, dotted_name

_CLOCK_CALLS = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.datetime.today", "datetime.date.today",
}
_ENTROPY_CALLS = {"os.urandom", "uuid.uuid4", "uuid.uuid1"}


def _stdlib_random_imported(tree: ast.Module) -> Set[str]:
    """Local names bound to the stdlib ``random``/``secrets`` modules."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name in ("random", "secrets"):
                    names.add(alias.asname or alias.name)
    return names


class Nondeterminism(Rule):
    name = "nondeterminism"
    description = ("global RNG / wall-clock / entropy use in "
                   "seed-deterministic packages")

    def check(self, module: Module, config: LintConfig) -> Iterator[Finding]:
        if not module.in_scope(config.determinism_scopes):
            return
        rng_modules = _stdlib_random_imported(module.tree)
        allowed = set(config.rng_allowed)
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name is None:
                continue
            head, _, tail = name.rpartition(".")
            if head in ("np.random", "numpy.random", "jax.numpy.random"):
                if tail not in allowed:
                    yield self.finding(
                        module, node,
                        f"global NumPy RNG draw {name}(): breaks "
                        "(trace, spec, seed) determinism — thread a "
                        "np.random.default_rng(seed) Generator instead")
            elif head in rng_modules:
                yield self.finding(
                    module, node,
                    f"stdlib {name}(): module-global entropy in a "
                    "seed-deterministic package — use a keyed "
                    "np.random.default_rng(seed)")
            elif name in _CLOCK_CALLS:
                yield self.finding(
                    module, node,
                    f"wall-clock read {name}(): simulated results must not "
                    "depend on host time (suppress with a reason if this "
                    "is latency *measurement*, not simulation state)")
            elif name in _ENTROPY_CALLS:
                yield self.finding(
                    module, node,
                    f"entropy tap {name}(): derive identifiers from the "
                    "seed/spec instead")
