"""The concrete pass suite — one rule per cross-cutting repo contract."""
from __future__ import annotations

from typing import Dict, List

from ..framework import Rule
from .conformance import ConformanceCoverage
from .decision_math import SingleSourceDecisionMath
from .deprecations import DeprecationHygiene
from .determinism import Nondeterminism
from .pytree import PytreeCompleteness
from .tracer import TracerLeak
from .x64 import X64Discipline

__all__ = ["ALL_RULES", "rule_by_name"]

ALL_RULES: List[Rule] = [
    SingleSourceDecisionMath(),
    X64Discipline(),
    TracerLeak(),
    Nondeterminism(),
    PytreeCompleteness(),
    DeprecationHygiene(),
    ConformanceCoverage(),
]

_BY_NAME: Dict[str, Rule] = {r.name: r for r in ALL_RULES}


def rule_by_name(name: str) -> Rule:
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"unknown rule {name!r}; known: {sorted(_BY_NAME)}") from None
