"""Pass 5 — pytree registration completeness.

The spec dataclasses (PolicySpec family, WorkloadSpec/Cohort, ...) are
registered as pytrees so grids of them can ride through ``jax.vmap`` and
``tree_map``. A field that lands in *neither* the children nor the
aux_data silently disappears on every flatten/unflatten roundtrip — specs
come back with defaults and sweeps quietly run the wrong experiment.
Three registration spellings are audited:

  * ``_register_pytree(Cls, meta=(...))`` — the repo helper flattens
    "every dataclass field not named in ``meta``", so the only failure
    mode is a typo'd meta name: every meta entry must be a real field;
  * raw ``register_pytree_node(Cls, flatten, unflatten)`` — the flatten
    callable must mention every dataclass field (attribute access or
    string key); ``dataclasses.fields/astuple/asdict`` counts as full
    coverage;
  * ``@register_pytree_node_class`` — same coverage check against the
    class's ``tree_flatten`` method.

Only classes defined (as dataclasses) in the same module are checked —
cross-module registration is rare here and out of reach for an
intraprocedural pass.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set

from ..framework import (Finding, LintConfig, Module, Rule, dotted_name,
                         terminal_name)

_FULL_COVERAGE_CALLS = {"fields", "astuple", "asdict"}


def _dataclass_fields(cls: ast.ClassDef) -> Optional[List[str]]:
    """Field names if ``cls`` is a dataclass we can read, else None."""
    is_dc = False
    for dec in cls.decorator_list:
        name = dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
        if name in ("dataclass", "dataclasses.dataclass"):
            is_dc = True
    if not is_dc:
        return None
    fields: List[str] = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            ann = stmt.annotation
            ann_txt = ast.dump(ann)
            if "ClassVar" in ann_txt:
                continue
            fields.append(stmt.target.id)
    return fields


def _str_tuple(node: ast.AST) -> List[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        return [el.value for el in node.elts
                if isinstance(el, ast.Constant) and isinstance(el.value, str)]
    return []


def _mentioned_fields(func: ast.AST) -> Optional[Set[str]]:
    """Field-ish names a flatten body touches; None => full coverage
    (iterates ``dataclasses.fields``/``astuple``/``asdict``)."""
    mentioned: Set[str] = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            tn = terminal_name(node.func)
            if tn in _FULL_COVERAGE_CALLS:
                return None
        if isinstance(node, ast.Attribute):
            mentioned.add(node.attr)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            mentioned.add(node.value)
    return mentioned


class PytreeCompleteness(Rule):
    name = "pytree-completeness"
    description = ("registered dataclasses whose flatten drops fields "
                   "(neither children nor aux_data)")

    def check(self, module: Module, config: LintConfig) -> Iterator[Finding]:
        classes: Dict[str, ast.ClassDef] = {}
        funcs: Dict[str, ast.AST] = {}
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef):
                classes.setdefault(node.name, node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                funcs.setdefault(node.name, node)
            elif isinstance(node, ast.Assign) and isinstance(node.value,
                                                             ast.Lambda):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        funcs.setdefault(tgt.id, node.value)

        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                tn = terminal_name(node.func)
                if tn in config.register_helpers:
                    yield from self._check_helper(module, node, classes)
                elif tn == "register_pytree_node":
                    yield from self._check_raw(module, node, classes, funcs)
        for cls in classes.values():
            if any(terminal_name(d.func if isinstance(d, ast.Call) else d)
                   == "register_pytree_node_class"
                   for d in cls.decorator_list):
                yield from self._check_node_class(module, cls)

    # -- _register_pytree(Cls, meta=(...)) -----------------------------------

    def _check_helper(self, module: Module, call: ast.Call,
                      classes: Dict[str, ast.ClassDef]) -> Iterator[Finding]:
        if not call.args:
            return
        cls_name = dotted_name(call.args[0])
        cls = classes.get(cls_name or "")
        if cls is None:
            return
        fields = _dataclass_fields(cls)
        if fields is None:
            return
        meta_node = call.args[1] if len(call.args) > 1 else next(
            (kw.value for kw in call.keywords if kw.arg == "meta"), None)
        meta = _str_tuple(meta_node) if meta_node is not None else []
        for name in meta:
            if name not in fields:
                yield self.finding(
                    module, call,
                    f"meta field {name!r} is not a field of {cls_name}: the "
                    "typo'd entry never moves to aux_data and getattr will "
                    "fail (or silently mis-flatten) at trace time")

    # -- register_pytree_node(Cls, flatten, unflatten) -----------------------

    def _check_raw(self, module: Module, call: ast.Call,
                   classes: Dict[str, ast.ClassDef],
                   funcs: Dict[str, ast.AST]) -> Iterator[Finding]:
        if len(call.args) < 2:
            return
        cls_name = dotted_name(call.args[0])
        cls = classes.get(cls_name or "")
        if cls is None:
            return
        fields = _dataclass_fields(cls)
        if not fields:
            return
        flat = call.args[1]
        func = flat if isinstance(flat, ast.Lambda) \
            else funcs.get(dotted_name(flat) or "")
        if func is None:
            return
        yield from self._coverage(module, call, cls_name, fields, func)

    # -- @register_pytree_node_class -----------------------------------------

    def _check_node_class(self, module: Module,
                          cls: ast.ClassDef) -> Iterator[Finding]:
        fields = _dataclass_fields(cls)
        if not fields:
            return
        flatten = next((n for n in cls.body
                        if isinstance(n, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))
                        and n.name == "tree_flatten"), None)
        if flatten is None:
            return
        yield from self._coverage(module, flatten, cls.name, fields, flatten)

    def _coverage(self, module: Module, site: ast.AST, cls_name: str,
                  fields: List[str], func: ast.AST) -> Iterator[Finding]:
        mentioned = _mentioned_fields(func)
        if mentioned is None:
            return
        missing = [f for f in fields if f not in mentioned]
        if missing:
            yield self.finding(
                module, site,
                f"flatten for {cls_name} drops field(s) {missing}: values "
                "land in neither children nor aux_data and reset to "
                "defaults on every unflatten")
