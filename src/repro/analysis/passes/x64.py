"""Pass 2 — x64 discipline.

Two sub-checks, both guarding the float32-exactness story (TPUs have no
float64, so the Pallas engines must be *correct* in float32, not quietly
promoted):

  * **kernel f64**: inside ``repro/kernels/`` any ``float64`` dtype
    spelling (``jnp.float64``, ``astype("float64")``) or ``enable_x64``
    escape (context manager, ``jax.config.update("jax_enable_x64", ...)``)
    is flagged — Mosaic cannot lower it, and interpret-mode tests would
    silently diverge from real-TPU behavior;
  * **un-rebased absolute time**: anywhere, casting a variable that *names
    itself* an absolute-time column (``times``, ``t_abs``, ...) straight to
    float32 is flagged unless the enclosing function also rebases (calls a
    ``*rebase*`` helper). Multi-week absolute clocks do not fit float32 —
    per-chunk float64 rebasing before the cast is the PR-2 contract
    (``simulator._rebase_chunk``).
"""
from __future__ import annotations

import ast
import re
from typing import Iterator, Optional

from ..framework import (Finding, LintConfig, Module, Rule, dotted_name,
                         terminal_name)

_F32_CASTERS = {"np.float32", "jnp.float32", "numpy.float32",
                "jax.numpy.float32"}
_ARRAY_CTORS = {"np.asarray", "np.array", "jnp.asarray", "jnp.array",
                "numpy.asarray", "numpy.array", "jax.numpy.asarray",
                "jax.numpy.array", "np.ascontiguousarray",
                "numpy.ascontiguousarray"}


def _is_f32_dtype(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return node.value == "float32"
    return terminal_name(node) == "float32"


def _is_f64_dtype(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant):
        return node.value == "float64"
    return terminal_name(node) == "float64"


class X64Discipline(Rule):
    name = "x64-discipline"
    description = ("float64/enable_x64 in Pallas kernels; float32 casts of "
                   "un-rebased absolute-time columns")

    def check(self, module: Module, config: LintConfig) -> Iterator[Finding]:
        time_re = re.compile(config.time_name_pattern)
        in_kernels = module.in_scope(config.kernel_scopes)
        # map every node to its nearest enclosing function (for the rebase
        # exemption) in one pre-pass
        enclosing = {}
        rebasing_funcs = set()

        def tag(node: ast.AST, func: Optional[ast.AST]) -> None:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                func = node
            enclosing[id(node)] = func
            for child in ast.iter_child_nodes(node):
                tag(child, func)

        tag(module.tree, None)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                tn = terminal_name(node.func)
                if tn is not None and "rebase" in tn:
                    func = enclosing.get(id(node))
                    if func is not None:
                        rebasing_funcs.add(id(func))

        for node in ast.walk(module.tree):
            if in_kernels:
                yield from self._check_kernel_f64(module, node)
            yield from self._check_unrebased_cast(module, node, time_re,
                                                  enclosing, rebasing_funcs)

    # -- kernels: no f64, no enable_x64 -------------------------------------

    def _check_kernel_f64(self, module: Module,
                          node: ast.AST) -> Iterator[Finding]:
        if terminal_name(node) == "float64":
            yield self.finding(
                module, node,
                "float64 dtype in a Pallas kernel module: TPUs have no f64 "
                "and Mosaic cannot lower it; carry float32 + the rebased "
                "decision layer instead")
        elif isinstance(node, ast.Call):
            name = dotted_name(node.func) or ""
            if name.endswith("enable_x64"):
                yield self.finding(
                    module, node,
                    "enable_x64 escape inside a kernel module: interpret-"
                    "mode tests would silently diverge from real TPUs")
            elif name.endswith("config.update") and node.args and \
                    isinstance(node.args[0], ast.Constant) and \
                    node.args[0].value == "jax_enable_x64":
                yield self.finding(
                    module, node,
                    "jax_enable_x64 toggle inside a kernel module")
        elif isinstance(node, ast.Constant) and node.value == "float64":
            yield self.finding(
                module, node, "float64 dtype string in a Pallas kernel "
                              "module (TPUs have no f64)")
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for alias in node.names:
                if alias.name.endswith("enable_x64"):
                    yield self.finding(
                        module, node,
                        "enable_x64 import inside a kernel module")

    # -- float32 cast of absolute time --------------------------------------

    def _check_unrebased_cast(self, module: Module, node: ast.AST,
                              time_re, enclosing: dict,
                              rebasing_funcs: set) -> Iterator[Finding]:
        if not isinstance(node, ast.Call):
            return
        subject = self._cast_subject(node)
        if subject is None:
            return
        tn = terminal_name(subject)
        if tn is None or not time_re.search(tn):
            return
        func = enclosing.get(id(node))
        if func is not None and id(func) in rebasing_funcs:
            return       # the function rebases; trust its data flow
        yield self.finding(
            module, node,
            f"float32 cast of absolute-time column {tn!r} without per-chunk "
            "rebasing: multi-week clocks lose sub-minute IAT structure in "
            "float32 (see simulator._rebase_chunk)")

    @staticmethod
    def _cast_subject(node: ast.Call) -> Optional[ast.AST]:
        """The value being cast to float32, if this call is such a cast."""
        func = node.func
        # X.astype(float32)
        if isinstance(func, ast.Attribute) and func.attr == "astype":
            dtype = node.args[0] if node.args else next(
                (kw.value for kw in node.keywords if kw.arg == "dtype"), None)
            if dtype is not None and _is_f32_dtype(dtype):
                return func.value
            return None
        name = dotted_name(func)
        if name in _F32_CASTERS and node.args:
            return node.args[0]
        if name in _ARRAY_CTORS and node.args:
            dtype = node.args[1] if len(node.args) > 1 else next(
                (kw.value for kw in node.keywords if kw.arg == "dtype"), None)
            if dtype is not None and _is_f32_dtype(dtype):
                return node.args[0]
        return None
