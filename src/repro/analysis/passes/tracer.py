"""Pass 3 — tracer leaks in scan bodies / jitted functions.

``lax.scan`` step bodies and jitted functions are the simulator's hot
path; a Python-level branch or host conversion on a traced value either
raises a ``TracerBoolConversionError`` at trace time or — worse — silently
moves work to the host and serializes the whole pipeline (``np.asarray``
inside a step body synchronizes every step). Inside contexts discovered by
:mod:`repro.analysis.traced` this pass flags:

  * ``if``/``while`` whose condition reads a *traced* parameter (static
    jit args are resolved from ``static_argnums``/``static_argnames`` and
    exempt; so are shape/dtype/ndim/size probes and ``isinstance`` tests,
    which are trace-time constants);
  * ``float()`` / ``int()`` / ``bool()`` of a traced parameter;
  * ``.item()`` / ``np.asarray`` / ``np.array`` / ``jax.device_get`` —
    host syncs regardless of argument.

The analysis is intraprocedural: helpers *called from* a traced context
(e.g. the host/traced-polymorphic policy_math functions) are not entered.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from ..framework import Finding, LintConfig, Module, Rule, dotted_name
from ..traced import find_traced_contexts

_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}
_PY_CASTS = {"float", "int", "bool"}
_HOST_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                    "jax.device_get", "device_get"}


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _static_probe_names(node: ast.AST) -> Set[str]:
    """Names only used under shape/ndim/dtype/size probes or isinstance —
    trace-time constants, safe to branch on."""
    exempt: Set[str] = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Attribute) and sub.attr in _STATIC_ATTRS:
            exempt |= _names_in(sub.value)
        elif isinstance(sub, ast.Call) and \
                dotted_name(sub.func) == "isinstance":
            exempt |= _names_in(sub)
    return exempt


class TracerLeak(Rule):
    name = "tracer-leak"
    description = ("python control flow / host conversions on traced values "
                   "inside scan bodies and jitted functions")

    def check(self, module: Module, config: LintConfig) -> Iterator[Finding]:
        for ctx in find_traced_contexts(module.tree):
            traced = ctx.traced_params
            body = ctx.func.body
            nodes = body if isinstance(body, list) else [body]
            for stmt in nodes:
                for node in ast.walk(stmt):
                    yield from self._check_node(module, node, traced,
                                                ctx.kind)

    def _check_node(self, module: Module, node: ast.AST,
                    traced: Set[str], kind: str) -> Iterator[Finding]:
        if isinstance(node, (ast.If, ast.While)):
            hot = (_names_in(node.test) - _static_probe_names(node.test)) \
                & traced
            if hot:
                kw = "while" if isinstance(node, ast.While) else "if"
                yield self.finding(
                    module, node,
                    f"python '{kw}' on traced value(s) {sorted(hot)} inside "
                    f"a {kind}: branch at trace time (host sync) — use "
                    "jnp.where / lax.cond, or mark the argument static")
        elif isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name in _PY_CASTS and node.args:
                hot = _names_in(node.args[0]) & traced
                if hot:
                    yield self.finding(
                        module, node,
                        f"{name}() of traced value(s) {sorted(hot)} inside "
                        f"a {kind}: forces a host sync — keep it as an "
                        "array (astype) or compute it outside the traced "
                        "region")
            elif name in _HOST_SYNC_CALLS:
                yield self.finding(
                    module, node,
                    f"{name}() inside a {kind}: device->host transfer "
                    "serializes the scan — move result assembly outside "
                    "the traced region")
            elif isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                yield self.finding(
                    module, node,
                    f".item() inside a {kind}: forces a host sync per step")
