"""CLI: ``python -m repro.analysis [paths] [--json] [--changed]``.

Exit codes: 0 clean, 1 findings, 2 usage/environment error. Stdlib-only —
the CI lint job runs this without jax installed.
"""
from __future__ import annotations

import argparse
import subprocess
import sys
from typing import List, Optional

from .framework import LintConfig, render_human, render_json, run_paths
from .passes import ALL_RULES, rule_by_name


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Invariant linter for the repro codebase: mechanizes "
                    "the cross-cutting contracts (single-source decision "
                    "math, x64 discipline, tracer hygiene, determinism, "
                    "pytree completeness, deprecations).")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files/directories to lint (default: src)")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit the machine-readable report (schema v1)")
    p.add_argument("--changed", action="store_true",
                   help="lint only files changed vs HEAD (plus untracked) "
                        "under the given paths — pre-commit mode")
    p.add_argument("--select", action="append", metavar="RULE",
                   help="run only the named rule(s); repeatable")
    p.add_argument("--list-rules", action="store_true",
                   help="print rule names + descriptions and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.name}: {rule.description}")
        return 0
    rules = ALL_RULES
    if args.select:
        try:
            rules = [rule_by_name(name) for name in args.select]
        except KeyError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            return 2
    try:
        report = run_paths(args.paths or ["src"], rules, LintConfig(),
                           changed=args.changed)
    except (subprocess.CalledProcessError, FileNotFoundError) as e:
        print(f"error: --changed needs a git checkout ({e})", file=sys.stderr)
        return 2
    print(render_json(report) if args.as_json else render_human(report))
    return 1 if report["findings"] else 0


if __name__ == "__main__":
    sys.exit(main())
