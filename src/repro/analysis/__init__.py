"""`repro.analysis` — AST-based invariant linter for this repository.

The engines only reproduce the paper's hybrid-histogram policy bit-exactly
because of cross-cutting contracts that no type checker sees:

  * all decision math (percentiles, margins, warm/cold verdicts,
    ``PCT_SCALE`` arithmetic) lives in :mod:`repro.core.policy_math`;
  * Pallas kernel bodies never touch float64 (TPUs have none) and float32
    engines never difference un-rebased absolute timestamps;
  * ``lax.scan`` step bodies and jitted functions never host-sync traced
    values (``float()``/``.item()``/``np.asarray``/python ``if``);
  * trace generation and the simulators are seed-deterministic — no global
    RNG or wall-clock reads;
  * registered ``*Spec`` pytrees flatten every dataclass field;
  * removed ``simulate*`` / ``Trace.synthesize`` entry points stay removed.

This package mechanizes those conventions as a small static-analysis pass
suite over the stdlib ``ast`` module (no third-party dependencies — the CI
lint job runs without installing jax). Each contract is a :class:`Rule`
producing :class:`Finding` records; false positives are silenced inline:

    x = risky_thing()  # repro-lint: ignore[rule-name] -- why this is fine

Run it as ``python -m repro.analysis [paths] [--json] [--changed]``; see
``README.md`` ("Invariants & static analysis") for the rule catalogue.
"""
from .framework import (Finding, LintConfig, Module, Rule, Suppression,
                        changed_files, dotted_name, parse_suppressions,
                        render_human, render_json, run_paths, run_source)
from .passes import ALL_RULES, rule_by_name

__all__ = [
    "ALL_RULES", "Finding", "LintConfig", "Module", "Rule", "Suppression",
    "changed_files", "dotted_name", "parse_suppressions", "render_human",
    "render_json", "rule_by_name", "run_paths", "run_source",
]
