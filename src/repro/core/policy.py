"""Cold-start management policies (Section 4 of the paper).

A *policy* governs, per application, two windows measured from the end of the
last function execution:

  * ``prewarm``  — time to wait before (re)loading the application image.
    0 means "do not unload at all after an execution".
  * ``keep_alive`` — time the image stays loaded after it was (re)loaded
    (or after the execution end if ``prewarm == 0``).

An invocation with idle time IT is a **warm start** iff
``prewarm <= IT <= prewarm + keep_alive`` (with the convention that
``prewarm == 0`` covers ``IT <= keep_alive``). Loaded-but-idle time is the
**wasted memory time** the provider pays.

Policies implemented:

  * :class:`FixedKeepAlivePolicy` — the provider state of practice (AWS 10 min
    / Azure 20 min / OpenWhisk 10 min): ``prewarm = 0``,
    ``keep_alive = const`` for every app.
  * :class:`NoUnloadingPolicy` — infinite keep-alive (lower bound on cold
    starts, upper bound on waste).
  * :class:`HybridHistogramPolicy` — the paper's contribution: per-app
    range-limited IT histogram (head/tail percentile windows), a CV-based
    representativeness check falling back to a *standard keep-alive*
    (``prewarm=0, keep_alive=range``), and an ARIMA forecast path for apps
    whose ITs are mostly out of histogram bounds.

All three expose the same scalar control-plane interface
(``on_invocation(app_id, idle_time) -> windows for next gap``) used by the
serving warm pool. The declarative counterparts — ``FixedSpec`` /
``NoUnloadSpec`` / ``HybridSpec`` in :mod:`repro.core.experiment` — build
these stateful objects via ``spec.build()`` and drive the vectorized sweep
engines (`repro.core.simulator`) directly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from . import policy_math
from ..forecast.forecaster import ArimaForecaster
from .histogram import AppHistogram, HistogramConfig

__all__ = [
    "PolicyWindows",
    "Policy",
    "FixedKeepAlivePolicy",
    "NoUnloadingPolicy",
    "HybridConfig",
    "HybridHistogramPolicy",
    "SpesConfig",
    "SpesPolicy",
    "is_warm",
    "loaded_idle_time",
]

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class PolicyWindows:
    prewarm: float       # minutes
    keep_alive: float    # minutes


def is_warm(it: float, w: PolicyWindows) -> bool:
    """Whether an invocation with idle time ``it`` (minutes) hits warm."""
    load_at, unload_at = policy_math.window_bounds(w.prewarm, w.keep_alive)
    return bool(policy_math.warm_from_bounds(it, load_at, unload_at))


def loaded_idle_time(it: float, w: PolicyWindows) -> float:
    """Memory-time (minutes) the image sat loaded-but-idle during a gap of
    length ``it`` under windows ``w`` (exec time treated as 0, worst case,
    exactly as the paper's simulator does)."""
    load_at, unload_at = policy_math.window_bounds(w.prewarm, w.keep_alive)
    return float(policy_math.idle_from_bounds(it, load_at, unload_at))


class Policy:
    """Scalar policy interface (one instance manages the whole fleet)."""

    name = "base"

    def windows(self, app_id: str) -> PolicyWindows:
        raise NotImplementedError

    def on_invocation(self, app_id: str, idle_time: Optional[float]) -> PolicyWindows:
        """Record an invocation (``idle_time`` None for the first ever) and
        return the windows that govern the *next* gap."""
        raise NotImplementedError


class FixedKeepAlivePolicy(Policy):
    def __init__(self, keep_alive_minutes: float = 10.0):
        self.keep_alive = float(keep_alive_minutes)
        self.name = f"fixed-{keep_alive_minutes:g}m"

    def windows(self, app_id: str) -> PolicyWindows:
        return PolicyWindows(0.0, self.keep_alive)

    def on_invocation(self, app_id: str, idle_time: Optional[float]) -> PolicyWindows:
        return self.windows(app_id)


class NoUnloadingPolicy(Policy):
    name = "no-unloading"

    def windows(self, app_id: str) -> PolicyWindows:
        return PolicyWindows(0.0, INF)

    def on_invocation(self, app_id: str, idle_time: Optional[float]) -> PolicyWindows:
        return self.windows(app_id)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    histogram: HistogramConfig = HistogramConfig()
    cv_threshold: float = 2.0        # paper: CV=2 default (Fig. 17)
    min_samples: int = 5             # "not enough ITs" -> standard keep-alive
    oob_fraction_threshold: float = 0.5   # "most ITs OOB" -> ARIMA
    arima_min_samples: int = 4       # need a few ITs before ARIMA can fit
    arima_margin: float = 0.15       # paper: 15% margin
    use_arima: bool = True

    @property
    def standard_keep_alive(self) -> float:
        # Paper: fall back to prewarm=0, keep-alive = histogram range.
        return self.histogram.range_minutes


@dataclasses.dataclass(frozen=True)
class SpesConfig:
    """Knobs of the SPES-style next-idle predictor policy.

    A streaming point forecast of each app's next idle interval
    (exponentially-weighted mean of observed ITs) with a confidence band
    that widens with the EW residual variance — the paper's §4.3 idea of
    pre-warming just before the predicted arrival, without the histogram
    machinery: regular apps earn tight (prewarm, keep-alive) windows,
    erratic apps keep a wide net.
    """
    alpha: float = 0.3               # EW smoothing weight per observation
    band_margin: float = 0.10        # relative half-band around the forecast
    band_sigma: float = 1.0          # residual-std multiplier for the band
    min_samples: int = 4             # ITs before the forecast governs
    standard_keep_alive: float = 240.0   # fallback until warmed up


class SpesPolicy(Policy):
    """SPES-style next-idle predictor (scalar control-plane path).

    State per app is the float32 triple ``(mean, var, n_obs)`` maintained
    by :func:`repro.core.policy_math.spes_update`; windows come from
    :func:`repro.core.policy_math.spes_window_from_counts` — the same
    single-source helpers the vectorized sweep engines scan, so verdicts
    are bit-identical across engines.
    """

    def __init__(self, cfg: SpesConfig = SpesConfig()):
        self.cfg = cfg
        self.name = f"spes-{cfg.alpha:g}"
        self._knobs = policy_math.SpesStepConfig.from_host(
            alpha=cfg.alpha, band_margin=cfg.band_margin,
            band_sigma=cfg.band_sigma, min_samples=cfg.min_samples,
            standard_keep=cfg.standard_keep_alive)
        self._state: Dict[str, Tuple[np.float32, np.float32, int]] = {}
        self._windows: Dict[str, PolicyWindows] = {}

    def _standard(self) -> PolicyWindows:
        return PolicyWindows(0.0, float(self.cfg.standard_keep_alive))

    def windows(self, app_id: str) -> PolicyWindows:
        w = self._windows.get(app_id)
        return w if w is not None else self._standard()

    def on_invocation(self, app_id: str, idle_time: Optional[float]) -> PolicyWindows:
        k = self._knobs
        mean, var, n_obs = self._state.get(
            app_id, (np.float32(0.0), np.float32(0.0), 0))
        if idle_time is not None and idle_time >= 0:
            mean, var, n_obs = policy_math.spes_update(
                # repro-lint: ignore[x64-discipline] -- idle_time is an
                # inter-arrival gap, not an absolute clock; the single f32
                # quantization IS the cross-engine decision contract
                mean, var, n_obs, np.float32(idle_time), True,
                k.alpha, k.om_alpha)
            self._state[app_id] = (np.float32(mean), np.float32(var),
                                   int(n_obs))
        lo, hi = policy_math.spes_window_from_counts(
            mean, var, n_obs, k.min_samples, k.band_margin, k.band_sigma,
            k.standard_keep)
        # keep-alive as the float64 bound difference — exactly how the
        # engines' _absolute_results recovers it.
        w = PolicyWindows(float(lo), float(hi) - float(lo))
        self._windows[app_id] = w
        return w

    # -- checkpointing -------------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "cfg": dataclasses.asdict(self.cfg),
            "state": {k: (float(m), float(v), int(n))
                      for k, (m, v, n) in self._state.items()},
            "windows": {k: (w.prewarm, w.keep_alive)
                        for k, w in self._windows.items()},
        }

    def load_state_dict(self, state: dict) -> None:
        for k, (m, v, n) in state.get("state", {}).items():
            self._state[k] = (np.float32(m), np.float32(v), int(n))
        for k, (p, ka) in state.get("windows", {}).items():
            self._windows[k] = PolicyWindows(p, ka)


class HybridHistogramPolicy(Policy):
    """The paper's hybrid histogram policy (scalar control-plane path).

    Decision per app (Figure 10):
      1. too few ITs, or CV of bin counts < threshold  -> standard keep-alive
         (prewarm 0, keep-alive = histogram range);
      2. most ITs out-of-bounds                        -> ARIMA forecast of the
         next IT, prewarm = 0.85*pred, keep-alive = 0.30*pred;
      3. otherwise                                     -> histogram head/tail
         percentile windows with a 10% margin.
    """

    def __init__(self, cfg: HybridConfig = HybridConfig()):
        self.cfg = cfg
        self.name = f"hybrid-{cfg.histogram.range_minutes:g}m"
        self._hist: Dict[str, AppHistogram] = {}
        self._arima: Dict[str, ArimaForecaster] = {}
        self._windows: Dict[str, PolicyWindows] = {}

    # -- decision logic ------------------------------------------------------

    def _standard(self) -> PolicyWindows:
        return PolicyWindows(0.0, self.cfg.standard_keep_alive)

    def _decide(self, app_id: str) -> PolicyWindows:
        cfg = self.cfg
        h = self._hist.get(app_id)
        if h is None or (h.total + h.oob) < cfg.min_samples:
            return self._standard()
        if policy_math.oob_heavy(h.total, h.oob, cfg.oob_fraction_threshold):
            # Histogram cannot represent this app (most ITs out of bounds):
            # time-series path (or standard keep-alive if ARIMA is disabled
            # or not warmed up yet — matching the batched engine).
            if cfg.use_arima:
                fc = self._arima.get(app_id)
                if fc is not None and fc.n_obs >= cfg.arima_min_samples:
                    pred = fc.forecast()
                    if pred is not None and math.isfinite(pred) and pred > 0:
                        return PolicyWindows(*policy_math.arima_window(
                            pred, cfg.arima_margin))
            return self._standard()
        if not policy_math.use_histogram_gate(
                h.total, h.oob, h._cv_sum, h._cv_sum_sq, cfg.histogram.n_bins,
                cfg.min_samples, cfg.cv_threshold, cfg.oob_fraction_threshold):
            # Histogram not representative (bin counts too uniform / too new).
            return self._standard()
        return PolicyWindows(*h.windows())

    # -- Policy interface ------------------------------------------------------

    def windows(self, app_id: str) -> PolicyWindows:
        w = self._windows.get(app_id)
        return w if w is not None else self._standard()

    def on_invocation(self, app_id: str, idle_time: Optional[float]) -> PolicyWindows:
        cfg = self.cfg
        if app_id not in self._hist:
            self._hist[app_id] = AppHistogram(cfg.histogram)
            if cfg.use_arima:
                self._arima[app_id] = ArimaForecaster()
        if idle_time is not None and idle_time >= 0:
            self._hist[app_id].record(idle_time)
            if cfg.use_arima:
                self._arima[app_id].observe(idle_time)
        w = self._decide(app_id)
        self._windows[app_id] = w
        return w

    # -- checkpointing (the serving fleet persists learned windows) ----------

    def state_dict(self) -> dict:
        return {
            "cfg": dataclasses.asdict(self.cfg),
            "hist": {
                k: {
                    "counts": h.counts.tolist(),
                    "oob": h.oob,
                    "total": h.total,
                    "cv_sum": h._cv_sum,
                    "cv_sum_sq": h._cv_sum_sq,
                }
                for k, h in self._hist.items()
            },
            "arima": {k: f.state_dict() for k, f in self._arima.items()},
            "windows": {k: (w.prewarm, w.keep_alive) for k, w in self._windows.items()},
        }

    def load_state_dict(self, state: dict) -> None:
        for k, hs in state["hist"].items():
            h = AppHistogram(self.cfg.histogram)
            h.counts = np.asarray(hs["counts"], np.int64)
            h.oob = int(hs["oob"])
            h.total = int(hs["total"])
            h._cv_sum = float(hs["cv_sum"])
            h._cv_sum_sq = float(hs["cv_sum_sq"])
            self._hist[k] = h
        for k, fs in state.get("arima", {}).items():
            f = ArimaForecaster()
            f.load_state_dict(fs)
            self._arima[k] = f
        for k, (p, ka) in state.get("windows", {}).items():
            self._windows[k] = PolicyWindows(p, ka)
