"""Range-limited idle-time (IT) histograms, batched over applications.

The center-piece of the paper's hybrid policy (Section 4.2): for each
application we keep a compact histogram of observed idle times with 1-minute
bins up to a configurable range (default 4 hours = 240 bins). ITs beyond the
range are counted as out-of-bounds (OOB). From the in-bounds distribution the
policy derives:

  * pre-warming window  = head percentile (default 5th), *rounded down* to the
    bin lower edge, then reduced by a margin (default 10%);
  * keep-alive window   = tail percentile (default 99th), *rounded up* to the
    bin upper edge, then increased by the margin. The keep-alive window is the
    length of time the image stays loaded *after pre-warming*, i.e. it covers
    [prewarm, tail].

State is stored as JAX arrays shaped ``[n_apps, n_bins]`` so the entire fleet
updates in one vectorized op (and, at scale, in the Pallas kernel in
``repro.kernels.histogram``). A scalar host-side twin (`AppHistogram`) mirrors
the semantics for the control-plane path and for differential testing.

All decision formulas (binning, percentile thresholds, window margins, CV)
live in :mod:`repro.core.policy_math`; this module only holds the state
containers and representation-specific glue.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import policy_math

__all__ = [
    "HistogramConfig",
    "HistogramState",
    "init_state",
    "record_idle_times",
    "percentile_windows",
    "find_first_ge",
    "cum_record_idle_times",
    "AppHistogram",
]


@dataclasses.dataclass(frozen=True)
class HistogramConfig:
    """Configuration of the range-limited histogram policy component."""

    bin_minutes: float = 1.0          # paper: 1-minute bins
    range_minutes: float = 240.0      # paper: 4-hour default range
    head_percentile: float = 5.0      # paper: 5th percentile -> pre-warm
    tail_percentile: float = 99.0     # paper: 99th percentile -> keep-alive
    margin: float = 0.10              # paper: 10% margin both sides

    @property
    def n_bins(self) -> int:
        return int(round(self.range_minutes / self.bin_minutes))


class HistogramState(NamedTuple):
    """Batched per-app histogram state (all arrays have leading dim n_apps)."""

    counts: jnp.ndarray        # [n_apps, n_bins] int32 in-bounds IT counts
    oob: jnp.ndarray           # [n_apps] int32 count of out-of-bounds ITs
    total: jnp.ndarray         # [n_apps] int32 count of in-bounds ITs
    cv_sum: jnp.ndarray        # [n_apps] f32 Welford sum of bin counts
    cv_sum_sq: jnp.ndarray     # [n_apps] f32 Welford sum of squared bin counts


def init_state(n_apps: int, cfg: HistogramConfig) -> HistogramState:
    return HistogramState(
        counts=jnp.zeros((n_apps, cfg.n_bins), jnp.int32),
        oob=jnp.zeros((n_apps,), jnp.int32),
        total=jnp.zeros((n_apps,), jnp.int32),
        cv_sum=jnp.zeros((n_apps,), jnp.float32),
        cv_sum_sq=jnp.zeros((n_apps,), jnp.float32),
    )


def record_idle_times(
    state: HistogramState,
    it_minutes: jnp.ndarray,
    active: jnp.ndarray,
    cfg: HistogramConfig,
) -> HistogramState:
    """Record one idle time per app (vectorized).

    Args:
      state: current batched histogram state.
      it_minutes: [n_apps] float idle times in minutes.
      active: [n_apps] bool; apps that actually observed an IT this step.
      cfg: histogram configuration.
    """
    n_bins = cfg.n_bins
    safe_idx, in_bounds, oob_hit = policy_math.classify_idle_time(
        it_minutes, active, cfg.bin_minutes, n_bins)

    one_hot = jax.nn.one_hot(safe_idx, n_bins, dtype=jnp.int32)
    one_hot = one_hot * in_bounds.astype(jnp.int32)[:, None]
    old_count = jnp.take_along_axis(state.counts, safe_idx[:, None], axis=1)[:, 0]

    cv_sum, cv_sum_sq = policy_math.welford_update(
        state.cv_sum, state.cv_sum_sq, in_bounds, old_count)
    return HistogramState(
        counts=state.counts + one_hot,
        oob=state.oob + oob_hit.astype(jnp.int32),
        total=state.total + in_bounds.astype(jnp.int32),
        cv_sum=cv_sum,
        cv_sum_sq=cv_sum_sq,
    )


def _weighted_percentile_bins(
    counts: jnp.ndarray, total: jnp.ndarray, pct: float, round_up: bool
) -> jnp.ndarray:
    """Smallest bin b such that cumsum(counts)[b] >= pct% of total.

    Returns the bin *lower edge index* when ``round_up`` is False (paper rounds
    the head "to the next lower value") and index+1 (upper edge) when True
    (tail rounds "to the next higher value"). Result is in bin units;
    ``n_bins`` (+1 for round_up) when total == 0 — callers mask on total > 0.
    """
    cum = jnp.cumsum(counts, axis=-1)
    thr = policy_math.percentile_threshold_scaled(total, pct)
    idx = policy_math.first_bin_ge_scaled(cum, thr, gather=True)
    return idx + (1 if round_up else 0)


def percentile_windows(
    state: HistogramState, cfg: HistogramConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Compute (pre-warm, keep-alive) windows in minutes for every app.

    pre-warm  = head_pct bin lower edge * (1 - margin)
    keep-alive covers [prewarm, tail_pct bin upper edge * (1 + margin)], i.e.
    the window *length* is tail*(1+margin) - prewarm (>= 0).
    Apps with no in-bounds samples get (0, range) — callers normally override
    via the representativeness check anyway.
    """
    head_bin = _weighted_percentile_bins(
        state.counts, state.total, cfg.head_percentile, round_up=False
    )
    tail_bin = _weighted_percentile_bins(
        state.counts, state.total, cfg.tail_percentile, round_up=True
    )
    load_at, unload_at = policy_math.window_values(
        head_bin, tail_bin, cfg.bin_minutes, cfg.range_minutes, cfg.margin)
    keep_alive = unload_at - load_at
    has_data = state.total > 0
    prewarm = jnp.where(has_data, load_at, 0.0)
    keep_alive = jnp.where(has_data, keep_alive, cfg.range_minutes)
    return prewarm, keep_alive


# --- Incremental cumulative-count representation -----------------------------
#
# The fused simulator (repro.core.simulator / repro.kernels.histogram) carries
# *cumulative* bin counts instead of raw counts: recording an idle time in bin
# b is a suffix add over [b, n_bins), after which the percentile windows read
# straight off the maintained prefix sums — no per-step fleet-wide cumsum.


def cum_record_idle_times(
    cum: jnp.ndarray, it_minutes: jnp.ndarray, active: jnp.ndarray,
    cfg: HistogramConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Record one IT per app into cumulative counts ``cum`` [n_apps, n_bins].

    Returns (new_cum, old_count_at_bin, in_bounds, oob_hit); ``old_count``
    is the pre-update raw count of the hit bin (Welford CV update input).
    """
    safe, in_bounds, oob_hit = policy_math.classify_idle_time(
        it_minutes, active, cfg.bin_minutes, cum.shape[-1])
    old = policy_math.raw_count_at(cum, safe, gather=True)
    new_cum = policy_math.suffix_add(cum, safe, in_bounds)
    return new_cum, old, in_bounds, oob_hit


def find_first_ge(cum: jnp.ndarray, threshold: jnp.ndarray) -> jnp.ndarray:
    """First bin index where row-wise nondecreasing ``cum`` >= ``threshold``.

    Vectorized binary search: O(log n_bins) gathers per app instead of an
    O(n_bins) masked reduction. Returns n_bins when no bin qualifies.
    """
    return policy_math.first_bin_ge_scaled(
        cum, policy_math.scale_raw_threshold(threshold), gather=True)


# --- Scalar host-side twin ---------------------------------------------------


class AppHistogram:
    """Scalar per-application histogram (control-plane / reference path)."""

    def __init__(self, cfg: HistogramConfig):
        self.cfg = cfg
        self.counts = np.zeros(cfg.n_bins, np.int64)
        self.oob = 0
        self.total = 0
        self._cv_sum = 0.0
        self._cv_sum_sq = 0.0

    def record(self, it_minutes: float) -> None:
        safe, in_b, oob_hit = policy_math.classify_idle_time(
            float(it_minutes), True, self.cfg.bin_minutes, self.cfg.n_bins)
        if oob_hit:
            self.oob += 1
            return
        if not in_b:
            return
        b = int(safe)
        old = self.counts[b]
        self.counts[b] += 1
        self.total += 1
        cvs, cvss = policy_math.welford_update(
            self._cv_sum, self._cv_sum_sq, True, old)
        self._cv_sum, self._cv_sum_sq = float(cvs), float(cvss)

    @property
    def cv(self) -> float:
        # float64 for reporting; the decision gate re-derives the float32
        # value through policy_math.use_histogram_gate.
        return float(policy_math.bin_count_cv(
            self._cv_sum, self._cv_sum_sq, self.cfg.n_bins, np.float64))

    @property
    def oob_fraction(self) -> float:
        seen = self.total + self.oob
        return self.oob / seen if seen else 0.0

    def windows(self) -> Tuple[float, float]:
        """(prewarm, keep_alive) from the head/tail percentile bins.

        The bounds come out of policy_math in float32 (dtype-invariant
        across engines); the keep-alive *length* is their exact float64
        difference, so ``prewarm + keep_alive`` reconstructs the float32
        unload bound bit-for-bit.
        """
        cfg = self.cfg
        if self.total == 0:
            return 0.0, cfg.range_minutes
        cum = np.cumsum(self.counts)
        head_bin = int(policy_math.first_bin_ge_scaled(
            cum, policy_math.percentile_threshold_scaled(
                self.total, cfg.head_percentile), gather=False))
        tail_bin = int(policy_math.first_bin_ge_scaled(
            cum, policy_math.percentile_threshold_scaled(
                self.total, cfg.tail_percentile), gather=False)) + 1
        load_at, unload_at = policy_math.window_values(
            head_bin, tail_bin, cfg.bin_minutes, cfg.range_minutes, cfg.margin)
        return float(load_at), float(unload_at) - float(load_at)
