"""Declarative workload scenarios: ``WorkloadSpec`` -> ONE vectorized engine.

The paper's §3 characterization is a *population*: invocation rates spanning
8 orders of magnitude (Fig. 5), arrival patterns from clockwork timers to
CV >> 1 bursts (Fig. 6), a diurnal cycle over a ~50% constant baseline
(Fig. 4), and trigger/memory/exec-time marginals (Figs. 2/3/7/8). This
module makes that population a first-class, declarative experiment input:

    from repro.core.workload_spec import azure_like, bursty, timer_heavy
    from repro.core.experiment import FixedSpec, HybridSpec, sweep

    grid = [FixedSpec(10.0), HybridSpec(use_arima=False)]
    traces = [azure_like(50_000, seed=0), bursty(50_000), timer_heavy(50_000)]
    result = sweep(traces=traces, specs=grid)     # (T, S) grid, one call

A :class:`WorkloadSpec` is a frozen dataclass (registered as a JAX pytree)
composed of :class:`Cohort` population components — each cohort is a
rate-band/pattern/trigger slice of the fleet with §3-anchored samplers —
plus scenario-level modulation knobs (diurnal amplitude, weekend dip, flash
crowd). ``WorkloadSpec.mix([...])`` composes cohorts; the scenario library
(:func:`azure_like`, :func:`diurnal`, :func:`bursty`, :func:`timer_heavy`,
:func:`flash_crowd`, :func:`weekend_dip`) names the common regimes.

One engine materializes any spec (``spec.materialize()``):

  * **padded mode** (default): events are sampled directly into the chunked
    padded ``[n_apps, max_events]`` form the batched simulators consume —
    batched numpy sampling per cohort block, no per-app Python objects, so
    a ~1M-app pattern-faithful trace costs one array, not a million lists.
  * **eager mode** (``eager=True``): additionally materializes per-app
    ``AppSpec`` objects and float64 time lists — the form the cluster sim,
    the dataset exporter, and the workload figures need.
    ``repro.core.workload.generate_trace`` is now a thin wrapper over this
    mode. (The old ``Trace.synthesize`` shim is gone — use
    :meth:`WorkloadSpec.uniform` directly.)

Generation is **seed-deterministic and chunk-size-invariant**: apps are
generated in fixed index blocks, each with an independent counter-style RNG
keyed on ``(seed, block_start, cohort)``, so the trace depends only on the
spec — never on materialization batch sizes. Event counts are *allowed to
be zero* (the paper's dataset guarantees >= 1 invocation per app;
``min_events=1`` restores that guarantee where it is part of the scenario).

Fidelity bounds (documented, not silent): ``max_events`` caps the per-app
event budget; apps whose expected count exceeds it are *rate-capped*
(periods stretched) so the pattern SHAPE is preserved over the window while
the count fits the budget. Pattern-mode events are capped at one per
minute-bin — the released dataset's granularity (see
``repro.core.workload``); any app above 1/minute is permanently warm under
every policy considered, so this changes no simulation result.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from . import workload as _wl
from .workload import MINUTES_PER_DAY, PATTERNS, AppSpec, Trace

__all__ = [
    "Cohort", "WorkloadSpec", "SCENARIOS", "scenario", "azure_like",
    "diurnal", "bursty", "timer_heavy", "flash_crowd", "weekend_dip",
    "materialize_loop", "population_columns",
]

GENERATORS = ("patterns", "uniform")

# Pattern indices (into workload.PATTERNS): timers are wall-clock and are
# never modulated; poisson/bursty traffic is human/event driven and gets the
# diurnal/weekly/flash intensity warp (matching the legacy generator, which
# thinned exactly these two classes).
_PERIODIC, _MULTI_TIMER, _REGULAR, _POISSON, _BURSTY = range(5)
_WARPED = (_POISSON, _BURSTY)

_PATTERN_MATRIX = np.asarray([_wl._PATTERN_PROBS_LOW, _wl._PATTERN_PROBS_MID,
                              _wl._PATTERN_PROBS_HIGH], np.float64)

# Fixed generation-block sizing: blocks are a pure memory knob (frame is
# ~[block, max_events] floats); the block GRID is aligned to absolute app
# indices so materialization batching can never change the trace.
_EVENT_BUDGET = 1 << 21
_MIN_BLOCK, _MAX_BLOCK = 256, 32768
# Domain-separation tag for the per-block counter RNG.
_RNG_TAG = 0x57F1


def _block_size(max_ev: int) -> int:
    return int(np.clip(_EVENT_BUDGET // max_ev, _MIN_BLOCK, _MAX_BLOCK))


def _register_pytree(cls, meta=()):
    """Register a frozen spec dataclass as a JAX pytree (numeric knobs are
    leaves, so specs flow through ``tree_map``/``jit``; ``meta`` fields are
    static aux data selecting python-level code paths). The single shared
    helper for BOTH spec families — the ``PolicySpec`` classes in
    :mod:`repro.core.experiment` import it from here."""
    names = [f.name for f in dataclasses.fields(cls)]
    data = tuple(n for n in names if n not in meta)

    def flatten(x):
        return (tuple(getattr(x, n) for n in data),
                tuple(getattr(x, n) for n in meta))

    def unflatten(aux, leaves):
        kw = dict(zip(data, leaves))
        kw.update(dict(zip(meta, aux)))
        return cls(**kw)

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


@dataclasses.dataclass(frozen=True)
class Cohort:
    """One population component: a rate-band/pattern/trigger slice of the
    fleet, sampled from the paper's §3 distributions (optionally truncated
    or re-weighted).

    ``pattern_probs=None`` uses the paper's rate-conditioned pattern mix
    (low-rate apps are mostly bursty HTTP, high-rate apps are machine
    traffic — Sections 3.2-3.3); ``trigger_probs=None`` uses the Fig. 3(b)
    trigger-combination shares. Rates come from the Fig. 5(a) CDF restricted
    to ``[10**rate_log10_min, 10**rate_log10_max]`` invocations/day and
    scaled by ``rate_scale``; memory/exec-time/function-count marginals are
    always the paper's fits (Burr XII / lognormal / Fig. 1 CDF).
    """
    name: str = "azure"
    weight: float = 1.0
    rate_log10_min: float = -1.0
    rate_log10_max: float = 7.0
    rate_scale: float = 1.0
    pattern_probs: Optional[Tuple[float, ...]] = None
    trigger_probs: Optional[Tuple[float, ...]] = None


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """A declarative workload scenario: population mix + modulation knobs.

    ``materialize()`` runs the vectorized engine (see module docstring);
    ``run()``/``sweep()`` in :mod:`repro.core.experiment` accept a spec
    anywhere a :class:`~repro.core.workload.Trace` is accepted, and
    ``sweep(traces=[...], specs=[...])`` makes scenarios a sweep axis.

    ``max_events=None`` means "uncapped": the budget falls back to the
    minute-bin bound (one event per minute of the window) — the right
    setting for eager/cluster-sim traces; fleet-scale padded traces should
    keep an explicit cap (64-256) to bound device memory.
    """
    n_apps: int = 1000
    days: float = 7.0
    seed: int = 0
    cohorts: Tuple[Cohort, ...] = (Cohort(),)
    max_events: Optional[int] = 64
    min_events: int = 0             # 1 => every app has >= 1 invocation
    diurnal_amplitude: float = 0.45  # Fig. 4: ~55% baseline + day cycle
    weekend_factor: float = 1.0      # intensity multiplier on days 5-6
    flash_start: Optional[float] = None   # flash-crowd window start (min)
    flash_duration: float = 120.0
    flash_factor: float = 1.0
    generator: str = "patterns"      # "patterns" | "uniform" (legacy)
    label: Optional[str] = None

    # -- identity ------------------------------------------------------------

    @property
    def name(self) -> str:
        return self.label or (f"{self.generator}-{self.n_apps}apps-"
                              f"{self.days:g}d-seed{self.seed}")

    @property
    def duration_minutes(self) -> float:
        return self.days * MINUTES_PER_DAY

    # -- constructors --------------------------------------------------------

    @classmethod
    def mix(cls, cohorts: Sequence[Cohort], **kw) -> "WorkloadSpec":
        """Compose population components into one scenario. Cohort weights
        are relative; apps are allocated by largest remainder, so the
        realized split is exact to +-1 app."""
        return cls(cohorts=tuple(cohorts), **kw)

    @classmethod
    def uniform(cls, n_apps: int, days: float = 1.0, seed: int = 0,
                max_events: int = 64, min_events: int = 0,
                label: Optional[str] = None) -> "WorkloadSpec":
        """The legacy scaling workload (formerly ``Trace.synthesize``):
        Fig. 5(a) rates, Poisson event counts, sorted-uniform times, float32,
        no patterns or modulation. Kept for throughput benchmarking
        continuity; prefer :func:`azure_like` for anything that should look
        like §3."""
        return cls(n_apps=n_apps, days=days, seed=seed, max_events=max_events,
                   min_events=min_events, diurnal_amplitude=0.0,
                   generator="uniform",
                   label=label or f"uniform-{n_apps}apps-{days:g}d")

    # -- validation ----------------------------------------------------------

    def validate(self) -> None:
        if self.n_apps < 0:
            raise ValueError(f"n_apps must be >= 0, got {self.n_apps}")
        if not self.days > 0:
            raise ValueError(f"days must be > 0, got {self.days}")
        if self.seed < 0:
            raise ValueError(f"seed must be >= 0, got {self.seed}")
        if self.max_events is not None and self.max_events < 1:
            raise ValueError(f"max_events must be >= 1, got {self.max_events}")
        if self.min_events not in (0, 1):
            raise ValueError(f"min_events must be 0 or 1, got {self.min_events}")
        if self.generator not in GENERATORS:
            raise ValueError(f"unknown generator {self.generator!r}; expected "
                             f"one of {GENERATORS}")
        if not self.cohorts:
            raise ValueError("a WorkloadSpec needs at least one Cohort")
        if not 0.0 <= self.diurnal_amplitude <= 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1], got "
                             f"{self.diurnal_amplitude}")
        if not self.weekend_factor > 0 or not self.flash_factor > 0:
            raise ValueError("weekend_factor/flash_factor must be > 0")
        for c in self.cohorts:
            if not c.weight > 0:
                raise ValueError(f"cohort {c.name!r}: weight must be > 0")
            if not c.rate_log10_min < c.rate_log10_max:
                raise ValueError(f"cohort {c.name!r}: empty rate band")
            for probs, m in ((c.pattern_probs, len(PATTERNS)),
                             (c.trigger_probs, len(_wl._TRIGGER_COMBOS))):
                if probs is not None and (len(probs) != m
                                          or min(probs) < 0
                                          or sum(probs) <= 0):
                    raise ValueError(
                        f"cohort {c.name!r}: probability vector must have "
                        f"{m} non-negative entries with positive sum")

    # -- the engine ----------------------------------------------------------

    def materialize(self, eager: bool = False) -> Trace:
        """Generate the trace. ``eager=False`` (default) returns the padded
        fleet-scale form; ``eager=True`` also builds per-app ``AppSpec``
        objects and float64 time lists (cluster sim / dataset export)."""
        return _materialize(self, eager)


_register_pytree(Cohort, meta=("name", "pattern_probs", "trigger_probs"))
_register_pytree(WorkloadSpec, meta=("generator", "label", "max_events",
                                     "min_events", "n_apps", "seed"))


# ---------------------------------------------------------------------------
# Population sampling (vectorized §3-anchored samplers)
# ---------------------------------------------------------------------------


def _sample_rates_banded(rng, n: int, cohort: Cohort) -> np.ndarray:
    """Fig. 5(a) inverse-CDF sampling restricted to the cohort's band."""
    anchors = _wl._RATE_CDF
    u_lo = float(np.interp(cohort.rate_log10_min, anchors[:, 1], anchors[:, 0]))
    u_hi = float(np.interp(cohort.rate_log10_max, anchors[:, 1], anchors[:, 0]))
    u = rng.uniform(u_lo, u_hi, n)
    return 10.0 ** np.interp(u, anchors[:, 0], anchors[:, 1]) * cohort.rate_scale


def _sample_patterns(rng, rates: np.ndarray, cohort: Cohort) -> np.ndarray:
    n = len(rates)
    if cohort.pattern_probs is not None:
        p = np.asarray(cohort.pattern_probs, np.float64)
        cdf = np.broadcast_to(np.cumsum(p / p.sum()), (n, len(PATTERNS)))
    else:
        cls = np.digitize(rates, (24.0, MINUTES_PER_DAY), right=True)
        cdf = np.cumsum(_PATTERN_MATRIX, axis=1)[cls]
    u = rng.uniform(0.0, 1.0, n)
    return np.sum(u[:, None] > cdf[:, :-1], axis=1).astype(np.int32)


def _snap_timer_rates(rates: np.ndarray, pattern: np.ndarray) -> np.ndarray:
    """Timer apps fire at most 1/minute on round periods (Sec. 3.2)."""
    timer = pattern <= _MULTI_TIMER
    if not timer.any():
        return rates
    r = np.minimum(rates, MINUTES_PER_DAY)
    raw = MINUTES_PER_DAY / np.maximum(r, 1e-9)
    logp = np.log(_wl._ROUND_PERIODS)
    j = np.argmin(np.abs(logp[None, :] - np.log(raw)[:, None]), axis=1)
    return np.where(timer, MINUTES_PER_DAY / _wl._ROUND_PERIODS[j], rates)


def _sample_triggers(rng, n: int, cohort: Cohort) -> np.ndarray:
    p = np.asarray(cohort.trigger_probs if cohort.trigger_probs is not None
                   else _wl._TRIGGER_PROBS, np.float64)
    return rng.choice(len(_wl._TRIGGER_COMBOS), n, p=p / p.sum())


def _sample_population(rng, n: int, cohort: Cohort) -> Dict[str, np.ndarray]:
    """One cohort block's population arrays — no per-app objects."""
    rates = _sample_rates_banded(rng, n, cohort)
    pattern = _sample_patterns(rng, rates, cohort)
    rates = _snap_timer_rates(rates, pattern)
    period = np.maximum(MINUTES_PER_DAY / np.maximum(rates, 1e-9), 1.0)
    return dict(
        rates=rates, pattern=pattern, period=period,
        memory=_wl._sample_memory_mb(rng, n),
        execs=_wl._sample_exec_s(rng, n),
        nfunc=_wl._sample_n_functions(rng, n),
        trig=_sample_triggers(rng, n, cohort),
    )


# ---------------------------------------------------------------------------
# Modulation: inhomogeneous intensity via an inverse-CDF time warp
# ---------------------------------------------------------------------------


def _build_warp(spec: WorkloadSpec, duration: float):
    """Cumulative-intensity warp grid, or None when intensity is flat.

    Non-timer events are generated in operational (flat-intensity) time and
    mapped through the inverse cumulative intensity — the exact inverse
    transform for (conditioned) Poisson arrivals, and the standard
    time-change for renewal streams. Event counts are preserved (unlike the
    legacy thinning, which silently cut rates by the mean acceptance)."""
    flat = (spec.diurnal_amplitude == 0.0 and spec.weekend_factor == 1.0
            and (spec.flash_start is None or spec.flash_factor == 1.0))
    if flat:
        return None
    grid_t = np.linspace(0.0, duration, max(int(np.ceil(duration)) + 1, 2))
    a = spec.diurnal_amplitude
    phase = 2.0 * np.pi * (grid_t % MINUTES_PER_DAY) / MINUTES_PER_DAY
    intensity = (1.0 - a) + a * 0.5 * (1.0 + np.sin(phase - 0.5 * np.pi))
    if spec.weekend_factor != 1.0:
        day = (grid_t // MINUTES_PER_DAY).astype(np.int64) % 7
        intensity = intensity * np.where(day >= 5, spec.weekend_factor, 1.0)
    if spec.flash_start is not None and spec.flash_factor != 1.0:
        hot = ((grid_t >= spec.flash_start)
               & (grid_t < spec.flash_start + spec.flash_duration))
        intensity = intensity * np.where(hot, spec.flash_factor, 1.0)
    intensity = np.maximum(intensity, 1e-3)
    cum = np.concatenate([[0.0],
                          np.cumsum(0.5 * (intensity[1:] + intensity[:-1]))])
    return cum / cum[-1], grid_t


def _warp_rows(frame: np.ndarray, rows: np.ndarray, duration: float, warp):
    if warp is None or not len(rows):
        return
    cnorm, grid_t = warp
    sub = frame[rows]
    finite = np.isfinite(sub)
    x = np.clip(np.where(finite, sub, 0.0) / duration, 0.0, 1.0)
    frame[rows] = np.where(finite, np.interp(x, cnorm, grid_t), np.inf)


# ---------------------------------------------------------------------------
# Vectorized per-pattern event generation (one block)
# ---------------------------------------------------------------------------


def _minute_cap(frame: np.ndarray) -> None:
    """Greedy one-event-per-minute-bin cap, vectorized over apps.

    Column scan over the (sorted, +inf-padded) frame: an event survives iff
    it is >= 1 minute after the previously surviving one — the dataset's
    1-minute binning (see :mod:`repro.core.workload`). Dropped events become
    +inf; rows are re-sorted (compacted) in place."""
    w = frame.shape[1]
    if w <= 1:
        return
    last = frame[:, 0].copy()
    for j in range(1, w):
        col = frame[:, j]
        keep = col >= last + 1.0          # inf rides through without NaNs
        frame[:, j] = np.where(keep, col, np.inf)
        last = np.where(keep, col, last)
    frame.sort(axis=1)


def _gen_patterns_block(rng, pop: Dict[str, np.ndarray], duration: float,
                        max_ev: int, warp, min_events: int):
    """Events for one block, every pattern vectorized over its group.

    Returns (frame [m, max_ev] float64 sorted +inf-padded, counts [m]).
    Expected counts above ``max_ev`` are rate-capped by period stretching so
    the pattern shape survives the event budget. RNG draw order is fixed
    (pattern groups in PATTERNS order, then the min_events fill) — the
    determinism tests pin it.
    """
    m = len(pop["rates"])
    days = duration / MINUTES_PER_DAY
    frame = np.full((m, max_ev), np.inf, np.float64)
    pattern, period = pop["pattern"], pop["period"]
    warp_rows = np.zeros(m, bool)

    for pid in range(len(PATTERNS)):
        idx = np.where(pattern == pid)[0]
        g = len(idx)
        if not g:
            continue
        per = period[idx]
        if pid == _PERIODIC:
            stretch = np.maximum(np.ceil((duration / per + 1.0) / max_ev), 1.0)
            per = per * stretch
            phase = rng.uniform(0.0, per)
            t = phase[:, None] + np.arange(max_ev)[None, :] * per[:, None]
            t[t >= duration] = np.inf
            frame[idx] = t
        elif pid == _MULTI_TIMER:
            per1 = 2.0 * per
            per2 = per1 * rng.uniform(1.2, 3.0, g)
            half = max_ev // 2 + 1
            # EACH timer owns `half` slots, so the stretch must fit the
            # FASTER timer's own count into its slot budget — guarding only
            # the combined estimate lets an asymmetric fast timer overrun
            # its half and silently go dark for the tail of the window.
            need = np.maximum(duration / per1, duration / per2) + 1.0
            stretch = np.maximum(np.ceil(need / half), 1.0)
            per1, per2 = per1 * stretch, per2 * stretch
            j = np.arange(half)[None, :]
            t = np.concatenate(
                [rng.uniform(0.0, per1)[:, None] + j * per1[:, None],
                 rng.uniform(0.0, per2)[:, None] + j * per2[:, None]], axis=1)
            t[t >= duration] = np.inf
            t.sort(axis=1)
            frame[idx] = t[:, :max_ev]
        elif pid == _REGULAR:
            # Erlang-4 IATs: CV = 0.5 machine traffic with jitter (Fig. 6)
            per = np.maximum(per, duration / max_ev)
            width = min(max_ev,
                        int(np.ceil(duration / per.min() * 1.5)) + 8)
            iats = rng.gamma(4.0, 1.0, (g, width)) * (per[:, None] / 4.0)
            t = np.cumsum(iats, axis=1)
            t[t >= duration] = np.inf
            frame[idx, :width] = t
        elif pid == _POISSON:
            lam = np.minimum(pop["rates"][idx] * days, float(max_ev))
            cnt = np.minimum(rng.poisson(lam), max_ev).astype(np.int64)
            width = max(int(cnt.max()), 1)
            t = rng.uniform(0.0, duration, (g, width))
            t[np.arange(width)[None, :] >= cnt[:, None]] = np.inf
            t.sort(axis=1)
            frame[idx, :width] = t
            warp_rows[idx] = True
        else:  # _BURSTY
            # Hyperexponential IAT mixture: runs of ~burst_mean closely
            # spaced calls separated by long gaps — CV >> 1 (Fig. 6) and the
            # ~1-cold-start-per-burst profile the paper observes. The gap
            # mean solves the mixture for the app's average rate.
            per = np.maximum(per, duration / max_ev)
            burst_mean = rng.uniform(6.0, 30.0, g)
            intra = rng.uniform(0.8, 2.5, g)
            dense = per <= 2.0            # continuous traffic: no bursts
            p_intra = np.where(dense, 0.0, 1.0 - 1.0 / burst_mean)
            gap = np.where(
                dense, per,
                (per - p_intra * intra) / np.maximum(1.0 - p_intra, 1e-9))
            gap = np.maximum(gap, per)
            width = min(max_ev, int(np.ceil(duration / per.min() * 1.6)) + 16)
            short = rng.uniform(0.0, 1.0, (g, width)) < p_intra[:, None]
            iats = (rng.exponential(1.0, (g, width))
                    * np.where(short, intra[:, None], gap[:, None]))
            t = (rng.uniform(0.0, gap)[:, None]
                 + np.cumsum(iats, axis=1) - iats[:, :1])
            t[t >= duration] = np.inf
            frame[idx, :width] = t
            warp_rows[idx] = True

    _warp_rows(frame, np.where(warp_rows)[0], duration, warp)
    _minute_cap(frame)
    counts = np.isfinite(frame).sum(axis=1).astype(np.int32)
    if min_events > 0:
        empty = np.where(counts == 0)[0]
        if len(empty):
            frame[empty, 0] = rng.uniform(0.0, duration, len(empty))
            counts[empty] = 1
    return frame, counts


def _gen_uniform_block(rng, m: int, duration: float, max_ev: int,
                       min_events: int, cohort: Cohort):
    """Legacy scaling workload: Poisson counts, sorted-uniform float32 times
    (the pre-spec scaling-trace semantics, minus the >=1 clamp)."""
    days = duration / MINUTES_PER_DAY
    rates = _sample_rates_banded(rng, m, cohort)
    lam = np.minimum(rates * days, float(max_ev))
    cnt = np.clip(rng.poisson(lam), min_events, max_ev).astype(np.int32)
    t = rng.uniform(0.0, duration, (m, max_ev)).astype(np.float32)
    t[np.arange(max_ev)[None, :] >= cnt[:, None]] = np.inf
    t.sort(axis=1)
    return t, cnt


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def _cohort_segments(n_apps: int, cohorts: Sequence[Cohort]):
    """Largest-remainder allocation of contiguous app-index segments."""
    w = np.asarray([c.weight for c in cohorts], np.float64)
    share = w / w.sum() * n_apps
    alloc = np.floor(share).astype(np.int64)
    for k in np.argsort(-(share - alloc))[: n_apps - int(alloc.sum())]:
        alloc[k] += 1
    segs, lo = [], 0
    for ci, cnt in enumerate(alloc):
        if cnt:
            segs.append((ci, lo, lo + int(cnt)))
        lo += int(cnt)
    return segs


def _block_rng(seed: int, block_lo: int, cohort_idx: int):
    return np.random.default_rng([_RNG_TAG, seed, block_lo, cohort_idx])


def _resolved_max_events(spec: WorkloadSpec, duration: float) -> int:
    if spec.max_events is not None:
        return int(spec.max_events)
    # uncapped: the minute-bin bound (at most one event per minute)
    return int(np.ceil(duration)) + 1


def _gen_blocks(spec: WorkloadSpec, duration: float):
    """Yield ``(cohort_idx, lo, hi, rng)`` for every generation block.

    One definition of the block walk (cohort segments, absolute-index block
    alignment, counter RNG per block) shared by :func:`_materialize` and
    :func:`population_columns` — the block boundaries and RNG streams are
    what make generation chunk-size-invariant and make the population
    columns replayable without generating any events.
    """
    block = _block_size(_resolved_max_events(spec, duration))
    for ci, s_lo, s_hi in _cohort_segments(spec.n_apps, spec.cohorts):
        for blo in range((s_lo // block) * block, s_hi, block):
            lo, hi = max(blo, s_lo), min(blo + block, s_hi)
            if hi <= lo:
                continue
            yield ci, lo, hi, _block_rng(spec.seed, blo, ci)


def population_columns(spec: WorkloadSpec) -> Dict[str, np.ndarray]:
    """Per-app population columns for a ``'patterns'`` spec, WITHOUT
    generating any events.

    Returns the dict of :func:`_sample_population` columns (``rates``,
    ``pattern``, ``period``, ``memory``, ``execs``, ``nfunc``, ``trig``)
    assembled over the whole fleet. Each block draws its population BEFORE
    its events from the block's counter RNG, so replaying only the
    population draw yields values bit-identical to what an eager
    ``materialize(eager=True)`` writes into its ``AppSpec`` objects — this
    is what lets the columnar cluster ``AppTable`` skip the per-app Python
    object loop entirely.
    """
    spec.validate()
    if spec.generator != "patterns":
        raise ValueError(
            "population_columns needs a 'patterns' spec (the 'uniform' "
            "generator draws no population; pass exec/memory columns to "
            "AppTable explicitly for uniform traces)")
    n = spec.n_apps
    out: Dict[str, np.ndarray] = {}
    for ci, lo, hi, rng in _gen_blocks(spec, spec.duration_minutes):
        pop = _sample_population(rng, hi - lo, spec.cohorts[ci])
        if not out:
            out = {k: np.empty(n, v.dtype) for k, v in pop.items()}
        for k, v in pop.items():
            out[k][lo:hi] = v
    return out


def _materialize(spec: WorkloadSpec, eager: bool) -> Trace:
    spec.validate()
    if eager and spec.generator == "uniform":
        raise ValueError(
            "generator='uniform' traces are padded-only (no patterns or "
            "AppSpecs to materialize); use a 'patterns' scenario such as "
            "azure_like() for eager traces")
    duration = spec.duration_minutes
    max_ev = _resolved_max_events(spec, duration)
    n = spec.n_apps
    warp = _build_warp(spec, duration) if spec.generator == "patterns" else None

    if eager:
        times: List[np.ndarray] = [None] * n
        specs: List[AppSpec] = [None] * n
    else:
        dtype = np.float32
        padded = np.full((n, max_ev), np.inf, dtype)
        counts_all = np.empty(n, np.int32)

    for ci, lo, hi, rng in _gen_blocks(spec, duration):
        cohort = spec.cohorts[ci]
        m = hi - lo
        if spec.generator == "uniform":
            frame, cnt = _gen_uniform_block(rng, m, duration, max_ev,
                                            spec.min_events, cohort)
            pop = None
        else:
            pop = _sample_population(rng, m, cohort)
            frame, cnt = _gen_patterns_block(rng, pop, duration, max_ev,
                                             warp, spec.min_events)
        if eager:
            for i in range(m):
                times[lo + i] = frame[i, : cnt[i]].astype(np.float64)
                specs[lo + i] = AppSpec(
                    app_id=f"app-{lo + i:06d}",
                    pattern=PATTERNS[int(pop["pattern"][i])],
                    rate_per_day=float(pop["rates"][i]),
                    period_minutes=float(pop["period"][i]),
                    exec_time_s=float(pop["execs"][i]),
                    memory_mb=float(pop["memory"][i]),
                    n_functions=int(pop["nfunc"][i]),
                    triggers=_wl._TRIGGER_COMBOS[int(pop["trig"][i])])
        else:
            padded[lo:hi, : frame.shape[1]] = frame.astype(dtype)
            counts_all[lo:hi] = cnt

    if eager:
        return Trace(specs=specs, times=times, duration_minutes=duration)
    width = max(int(counts_all.max()), 1) if n else 1
    return Trace(specs=None, times=None, duration_minutes=duration,
                 _padded=(np.ascontiguousarray(padded[:, :width]), counts_all))


def materialize_loop(spec: WorkloadSpec) -> Trace:
    """The pre-spec architecture: one Python iteration per app (per-app
    sampling, per-app pattern generators from :mod:`repro.core.workload`,
    per-event minute cap). Kept as the ``benchmarks/trace_gen.py`` baseline
    and as a distributional cross-check for the vectorized engine — NOT a
    production path. Implements the default (azure-like) diurnal modulation
    only; scenario warp knobs are engine-only."""
    spec.validate()
    if spec.generator != "patterns":
        raise ValueError("materialize_loop only implements the 'patterns' "
                         "generator (the uniform path was never per-app)")
    duration = spec.duration_minutes
    max_ev = _resolved_max_events(spec, duration)
    n = spec.n_apps
    rng = np.random.default_rng([_RNG_TAG, spec.seed])
    padded = np.full((n, max_ev), np.inf, np.float32)
    counts = np.zeros(n, np.int32)
    for ci, s_lo, s_hi in _cohort_segments(n, spec.cohorts):
        cohort = spec.cohorts[ci]
        for i in range(s_lo, s_hi):
            pop = _sample_population(rng, 1, cohort)
            period = float(max(pop["period"][0], duration / max_ev))
            app = AppSpec(
                app_id=f"app-{i:06d}", pattern=PATTERNS[int(pop["pattern"][0])],
                rate_per_day=MINUTES_PER_DAY / period, period_minutes=period,
                exec_time_s=float(pop["execs"][0]),
                memory_mb=float(pop["memory"][0]),
                n_functions=int(pop["nfunc"][0]),
                triggers=_wl._TRIGGER_COMBOS[int(pop["trig"][0])])
            t = _wl.generate_invocations(app, duration, rng)[:max_ev]
            if len(t) == 0 and spec.min_events > 0:
                t = np.asarray([rng.uniform(0.0, duration)])
            padded[i, : len(t)] = t
            counts[i] = len(t)
    width = max(int(counts.max()), 1) if n else 1
    return Trace(specs=None, times=None, duration_minutes=duration,
                 _padded=(np.ascontiguousarray(padded[:, :width]), counts))


# ---------------------------------------------------------------------------
# Scenario library
# ---------------------------------------------------------------------------


def azure_like(n_apps: int = 100_000, days: float = 7.0, seed: int = 0,
               **kw) -> WorkloadSpec:
    """The paper's §3 fleet: full rate CDF, rate-conditioned pattern mix,
    Fig. 3(b) triggers, Fig. 4 diurnal cycle."""
    kw.setdefault("label", f"azure-like-{n_apps}")
    return WorkloadSpec(n_apps=n_apps, days=days, seed=seed, **kw)


def diurnal(n_apps: int = 100_000, days: float = 7.0, seed: int = 0,
            **kw) -> WorkloadSpec:
    """Strongly day-cycled human traffic (deep overnight trough)."""
    kw.setdefault("label", f"diurnal-{n_apps}")
    kw.setdefault("diurnal_amplitude", 0.9)
    kw.setdefault("cohorts", (Cohort(
        name="diurnal-http", pattern_probs=(0.05, 0.03, 0.07, 0.35, 0.50)),))
    return WorkloadSpec(n_apps=n_apps, days=days, seed=seed, **kw)


def bursty(n_apps: int = 100_000, days: float = 7.0, seed: int = 0,
           **kw) -> WorkloadSpec:
    """CV >> 1 dominated: the hardest regime for fixed keep-alives (every
    burst head is a cold start unless the histogram learns the gaps)."""
    kw.setdefault("label", f"bursty-{n_apps}")
    kw.setdefault("cohorts", (Cohort(
        name="bursty", pattern_probs=(0.04, 0.02, 0.04, 0.10, 0.80)),))
    return WorkloadSpec(n_apps=n_apps, days=days, seed=seed, **kw)


def timer_heavy(n_apps: int = 100_000, days: float = 7.0, seed: int = 0,
                **kw) -> WorkloadSpec:
    """Timer-triggered machine traffic (CV ~ 0): histograms should learn
    near-exact windows and pre-warming should eliminate most cold starts."""
    kw.setdefault("label", f"timer-heavy-{n_apps}")
    kw.setdefault("cohorts", (Cohort(
        name="timers", pattern_probs=(0.50, 0.20, 0.15, 0.10, 0.05),
        trigger_probs=(10.0, 45.0, 5.0, 15.0, 2.0, 2.0, 2.0, 10.0, 5.0,
                       1.0, 2.0, 1.0)),))
    kw.setdefault("diurnal_amplitude", 0.1)
    return WorkloadSpec(n_apps=n_apps, days=days, seed=seed, **kw)


def flash_crowd(n_apps: int = 100_000, days: float = 7.0, seed: int = 0,
                **kw) -> WorkloadSpec:
    """Azure-like fleet with a mid-trace flash crowd (12x intensity for two
    hours): stresses pre-warm scheduling and warm-pool churn."""
    kw.setdefault("label", f"flash-crowd-{n_apps}")
    kw.setdefault("flash_start", 0.5 * days * MINUTES_PER_DAY)
    kw.setdefault("flash_duration", 120.0)
    kw.setdefault("flash_factor", 12.0)
    return WorkloadSpec(n_apps=n_apps, days=days, seed=seed, **kw)


def weekend_dip(n_apps: int = 100_000, days: float = 14.0, seed: int = 0,
                **kw) -> WorkloadSpec:
    """Two business weeks with weekend traffic at 25%: keep-alive policies
    tuned on weekday gaps misfire across the weekend regime shift."""
    kw.setdefault("label", f"weekend-dip-{n_apps}")
    kw.setdefault("weekend_factor", 0.25)
    return WorkloadSpec(n_apps=n_apps, days=days, seed=seed, **kw)


SCENARIOS = {
    "azure_like": azure_like,
    "diurnal": diurnal,
    "bursty": bursty,
    "timer_heavy": timer_heavy,
    "flash_crowd": flash_crowd,
    "weekend_dip": weekend_dip,
}


def scenario(name: str, n_apps: int = 100_000, **kw) -> WorkloadSpec:
    """Look up a named scenario: ``scenario("bursty", 50_000, days=3.0)``."""
    try:
        builder = SCENARIOS[name]
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; expected one of "
                         f"{sorted(SCENARIOS)}") from None
    return builder(n_apps, **kw)
