"""Single source of truth for the hybrid keep-alive policy math (paper §4).

Every engine in the repo — the scalar control-plane policy
(:class:`repro.core.policy.HybridHistogramPolicy` / ``AppHistogram``), the
vectorized ``lax.scan`` engines in :mod:`repro.core.simulator`, and the
Pallas TPU kernels in :mod:`repro.kernels.histogram` — computes its
decisions through the helpers below. A policy-formula change is a one-file
edit here; the conformance suite (``tests/test_engine_conformance.py``)
asserts the engines stay in exact agreement.

Mapping to the paper's §4 hybrid-policy description:

  * :func:`classify_idle_time`       — §4.2 range-limited IT histogram:
    1-minute bins up to a 4-hour range, beyond-range ITs counted as
    out-of-bounds (OOB).
  * :func:`suffix_add` / :func:`raw_count_at` — the fused engines' cumulative
    bin-count representation of that histogram (recording bin *b* is a
    suffix add over ``[b, n_bins)``, so percentiles read straight off the
    maintained prefix sums).
  * :func:`welford_update` / :func:`bin_count_cv` — §4.2 representativeness:
    coefficient of variation of the bin counts, maintained incrementally.
  * :func:`percentile_threshold_scaled` / :func:`first_bin_ge_scaled` /
    :func:`window_values` — §4.2 head/tail percentile windows: pre-warm =
    5th-percentile bin lower edge minus a 10% margin, keep-alive up to the
    99th-percentile bin upper edge plus the margin.
  * :func:`use_histogram_gate` / :func:`oob_heavy` — Fig. 10 decision tree:
    too few ITs or a too-uniform histogram (CV below threshold) falls back
    to the *standard keep-alive* (pre-warm 0, keep-alive = range); mostly
    OOB apps go to the time-series (ARIMA) path.
  * :func:`arima_window`             — §4.3 ARIMA windows: pre-warm just
    below the forecast IT, keep-alive covering a band around it.
  * :func:`warm_from_bounds` / :func:`idle_from_bounds` — §4.1 semantics:
    an invocation is warm iff it lands while the image is resident
    (``load_at <= IT <= unload_at``); loaded-but-idle time is the wasted
    memory the provider pays.

Dtype discipline (what makes the float32/TPU engines bit-match the float64
oracle):

  * The *decision layer* is dtype-invariant by construction: percentile
    thresholds are exact integer arithmetic (no float ``ceil``), CV and the
    window values (``load_at`` / ``unload_at``) are always computed in
    float32 from exactly-representable integer state. Engines carry the
    resulting bounds in their own time dtype (a float32 value widens to
    float64 exactly), so warm/cold verdicts compare identical reals in
    every engine.
  * The *time layer* (inter-arrival times, waste accumulation) stays in the
    engine's dtype. The float32 engines recover exact ITs via per-chunk
    time rebasing (see ``simulator.simulate_hybrid_batch``).
  * Integer state must stay below 2**24 for the float32 casts to be exact
    and below 2**31 / PCT_SCALE for the scaled threshold compare; both hold
    for any trace this repo produces (per-app event counts are bounded by
    the 1-minute dataset granularity).

Helpers are polymorphic over numpy and jnp (host scalars stay numpy — the
scalar policy pays no jax dispatch overhead) and trace identically inside
``jax.lax.scan`` bodies and Pallas TPU kernel bodies. Helpers that need a
row-wise lookup take a ``gather`` flag: gathers are fast under XLA but not
Mosaic-lowerable, so Pallas bodies use the reduction forms (both forms are
asserted equivalent by the property suite).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PCT_SCALE",
    "pct_numer",
    "window_bounds",
    "warm_from_bounds",
    "idle_from_bounds",
    "classify_idle_time",
    "suffix_add",
    "raw_count_at",
    "welford_update",
    "bin_count_cv",
    "percentile_threshold_scaled",
    "first_bin_ge_scaled",
    "window_values",
    "standard_window_bounds",
    "use_histogram_gate",
    "oob_heavy",
    "arima_window",
    "fused_hybrid_step_math",
]

# Percentiles are quantized to 1/100 of a percent and compared in exact
# integer arithmetic: ``cum >= ceil(total*pct/100)`` iff
# ``cum*PCT_SCALE >= total*pct_numer`` — no float rounding, so every engine
# derives the same percentile bin in any dtype.
PCT_SCALE = 10_000


def _ns(*xs):
    """numpy for host values, jnp for traced/device values."""
    for x in xs:
        if isinstance(x, (jax.Array, jax.core.Tracer)):
            return jnp
    return np


# --------------------------------------------------------------------------
# Warm/cold + waste verdicts (§4.1)
# --------------------------------------------------------------------------


def window_bounds(prewarm, keep_alive):
    """(load_at, unload_at) residency offsets from the last execution end.

    ``prewarm <= 0`` means the image is never unloaded after the execution:
    it is resident on ``[0, keep_alive]``. Otherwise it is unloaded
    immediately, re-loaded at ``prewarm`` and kept until
    ``prewarm + keep_alive``.
    """
    if _both_float(prewarm, keep_alive):   # scalar control-plane fast path
        load_at = prewarm if prewarm > 0.0 else 0.0
        return load_at, load_at + keep_alive
    xp = _ns(prewarm, keep_alive)
    load_at = xp.where(prewarm > 0.0, prewarm, 0.0)
    return load_at, load_at + keep_alive


def _both_float(a, b) -> bool:
    return isinstance(a, (float, int)) and isinstance(b, (float, int))


def warm_from_bounds(it, load_at, unload_at):
    """Warm iff the invocation arrives while the image is resident."""
    return (it >= load_at) & (it <= unload_at)


def idle_from_bounds(it, load_at, unload_at):
    """Loaded-but-idle memory time during a gap of length ``it`` (>= 0).

    The image sits idle from ``load_at`` until the arrival (or until
    ``unload_at`` if the gap outlives the keep-alive); arrivals before
    ``load_at`` never paid for a resident image.
    """
    if _both_float(it, load_at) and _both_float(it, unload_at):
        return max(min(it, unload_at) - load_at, 0.0)
    xp = _ns(it, load_at, unload_at)
    return xp.maximum(xp.minimum(it, unload_at) - load_at, 0.0)


# --------------------------------------------------------------------------
# Histogram update (§4.2)
# --------------------------------------------------------------------------


def classify_idle_time(it, active, bin_minutes: float, n_bins: int):
    """Bin an idle time: (clipped_bin, in_bounds, oob_hit)."""
    if isinstance(it, float):          # scalar control-plane fast path
        bin_idx = math.floor(it / bin_minutes)
        in_bounds = bool(active) and 0 <= bin_idx < n_bins
        oob_hit = bool(active) and bin_idx >= n_bins
        return min(max(bin_idx, 0), n_bins - 1), in_bounds, oob_hit
    xp = _ns(it, active)
    bin_idx = xp.floor(it / bin_minutes).astype(xp.int32)
    in_bounds = active & (bin_idx >= 0) & (bin_idx < n_bins)
    oob_hit = active & (bin_idx >= n_bins)
    safe = xp.clip(bin_idx, 0, n_bins - 1)
    return safe, in_bounds, oob_hit


def suffix_add(cum, safe_bin, in_bounds):
    """Record a hit at ``safe_bin`` into cumulative counts ``cum``.

    ``cum`` is [n_apps, n_bins] maintained prefix sums; one observation is
    a +1 over the suffix ``[safe_bin, n_bins)``. Traced-only (rank 2).
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, cum.shape, cum.ndim - 1)
    return cum + ((iota >= safe_bin[..., None])
                  & in_bounds[..., None]).astype(cum.dtype)


def raw_count_at(cum, safe_bin, *, gather: bool):
    """Pre-update raw count of ``safe_bin`` read off cumulative counts.

    ``gather=True`` uses row-wise dynamic indexing (fast under XLA);
    ``gather=False`` uses masked reductions (Mosaic/Pallas-lowerable).
    Both return the same int32 values.
    """
    if gather:
        rows = jnp.arange(cum.shape[0])
        cum_at = cum[rows, safe_bin].astype(jnp.int32)
        cum_below = jnp.where(
            safe_bin > 0,
            cum[rows, jnp.maximum(safe_bin - 1, 0)].astype(jnp.int32), 0)
        return cum_at - cum_below
    iota = jax.lax.broadcasted_iota(jnp.int32, cum.shape, cum.ndim - 1)
    cum_at = jnp.sum(jnp.where(iota == safe_bin[..., None], cum, 0), axis=-1)
    cum_below = jnp.sum(
        jnp.where(iota == (safe_bin - 1)[..., None], cum, 0), axis=-1)
    return (cum_at - cum_below).astype(jnp.int32)


def welford_update(cv_sum, cv_sum_sq, in_bounds, old_count):
    """O(1) update of the bin-count sum / sum-of-squares accumulators.

    A bin going ``old -> old+1`` changes the sum of squared counts by
    ``2*old + 1``. Accumulator dtype is preserved (float64 oracle, float32
    kernels); values are exact integers while below the dtype's mantissa.
    """
    if isinstance(cv_sum, float):      # scalar control-plane fast path
        inb = 1.0 if in_bounds else 0.0
        return cv_sum + inb, cv_sum_sq + inb * (2.0 * float(old_count) + 1.0)
    xp = _ns(cv_sum, cv_sum_sq)
    dt = cv_sum.dtype if hasattr(cv_sum, "dtype") else xp.float64
    inb = xp.asarray(in_bounds, dt) if xp is np else in_bounds.astype(dt)
    old = xp.asarray(old_count, dt) if xp is np else old_count.astype(dt)
    return cv_sum + inb, cv_sum_sq + inb * (2.0 * old + 1.0)


# --------------------------------------------------------------------------
# Representativeness (CV of bin counts, §4.2)
# --------------------------------------------------------------------------


def bin_count_cv(cv_sum, cv_sum_sq, n_bins: int, dtype=np.float32):
    """Coefficient of variation of the bin counts from the accumulators.

    The gate evaluates this in float32 in every engine (``dtype`` is only
    widened for host-side reporting); the inputs are exact integers, so the
    float32 value is identical across engines.
    """
    if isinstance(cv_sum, float):              # scalar control-plane paths
        if dtype is np.float64:
            mean = cv_sum / n_bins
            if mean <= 0.0:
                return 0.0
            var = max(cv_sum_sq / n_bins - mean * mean, 0.0)
            return math.sqrt(var) / max(mean, 1e-9)
        # float32 gate semantics: every op rounds to float32, exactly the
        # sequence the batched engines trace
        mean = np.float32(cv_sum) / np.float32(n_bins)
        if not mean > 0:
            return np.float32(0.0)
        var = np.float32(cv_sum_sq) / np.float32(n_bins) - mean * mean
        if var < 0:
            var = np.float32(0.0)
        return np.sqrt(var) / max(mean, np.float32(1e-9))
    xp = _ns(cv_sum, cv_sum_sq)
    cvs = xp.asarray(cv_sum, dtype) if xp is np else cv_sum.astype(dtype)
    cvss = xp.asarray(cv_sum_sq, dtype) if xp is np else cv_sum_sq.astype(dtype)
    mean = cvs / n_bins
    var = xp.maximum(cvss / n_bins - mean * mean, dtype(0.0))
    return xp.where(mean > 0, xp.sqrt(var) / xp.maximum(mean, dtype(1e-9)),
                    dtype(0.0))


# --------------------------------------------------------------------------
# Percentile windows (§4.2)
# --------------------------------------------------------------------------


def pct_numer(pct: float) -> int:
    """Percentile as an exact integer numerator over PCT_SCALE."""
    return int(round(pct * (PCT_SCALE / 100.0)))


def percentile_threshold_scaled(total, pct: float):
    """Scaled percentile threshold: ``cum`` hits the pct-percentile iff
    ``cum * PCT_SCALE >= threshold`` (with the paper's floor of one
    sample). Pure integer math — dtype-invariant by construction."""
    numer = pct_numer(pct)
    if isinstance(total, int):
        return max(total * numer, PCT_SCALE)
    xp = _ns(total)
    if xp is np:
        return np.maximum(np.int64(total) * numer, PCT_SCALE)
    return jnp.maximum(total.astype(jnp.int32) * jnp.int32(numer),
                       jnp.int32(PCT_SCALE))


def first_bin_ge_scaled(cum, thr_scaled, *, gather: bool):
    """First bin index where ``cum * PCT_SCALE >= thr_scaled``; ``n_bins``
    when no bin qualifies (only possible with zero in-bounds samples —
    callers gate on ``total > 0``).

    ``gather=True`` runs an O(log n_bins) binary search (XLA scan bodies);
    ``gather=False`` a masked min over the bin iota (Pallas bodies, numpy
    host path). Identical results.
    """
    xp = _ns(cum, thr_scaled)
    n_bins = cum.shape[-1]
    if xp is np:
        cum = np.asarray(cum, np.int64)
        if cum.ndim == 1 and np.ndim(thr_scaled) == 0:
            # host fast path: cum is nondecreasing, so the masked min is a
            # binary search — cum*S >= thr iff cum >= ceil(thr/S)
            need = -(-int(thr_scaled) // PCT_SCALE)
            return int(np.searchsorted(cum, need, side="left"))
        iota = np.broadcast_to(np.arange(n_bins), cum.shape)
        hit = cum * PCT_SCALE >= np.asarray(thr_scaled)[..., None]
        return np.min(np.where(hit, iota, n_bins), axis=-1)
    if not gather:
        iota = jax.lax.broadcasted_iota(jnp.int32, cum.shape, cum.ndim - 1)
        hit = cum.astype(jnp.int32) * jnp.int32(PCT_SCALE) >= \
            thr_scaled[..., None]
        return jnp.min(jnp.where(hit, iota, n_bins), axis=-1)
    n_apps = cum.shape[0]
    rows = jnp.arange(n_apps)
    lo = jnp.zeros((n_apps,), jnp.int32)
    hi = jnp.full((n_apps,), n_bins, jnp.int32)
    # search space is [0, n_bins] — n_bins + 1 candidate answers
    for _ in range(int(np.ceil(np.log2(n_bins + 1)))):
        mid = (lo + hi) // 2
        v = cum[rows, jnp.minimum(mid, n_bins - 1)].astype(jnp.int32)
        ge = (v * jnp.int32(PCT_SCALE) >= thr_scaled) & (mid < n_bins)
        hi = jnp.where(ge, mid, hi)
        lo = jnp.where(ge, lo, jnp.minimum(mid + 1, hi))
    return hi


def window_values(head_bin, tail_bin, bin_minutes: float,
                  range_minutes: float, margin: float):
    """(load_at, unload_at) in minutes from percentile bin indices.

    load_at   = head bin lower edge, reduced by the margin;
    unload_at = tail bin upper edge (clamped to the range), increased by
                the margin — never below load_at.
    Always computed AND returned in float32: window values are decisions,
    and float32 keeps them identical across engines (they widen to float64
    exactly).
    """
    xp = _ns(head_bin, tail_bin)
    f = np.float32
    head = xp.asarray(head_bin, f) if xp is np else head_bin.astype(f)
    tail = xp.asarray(tail_bin, f) if xp is np else tail_bin.astype(f)
    load_at = head * f(bin_minutes) * f(1.0 - margin)
    unload_at = xp.minimum(tail * f(bin_minutes), f(range_minutes)) \
        * f(1.0 + margin)
    return load_at, xp.maximum(unload_at, load_at)


def standard_window_bounds(standard_keep: float) -> Tuple[float, float]:
    """The fallback windows: never unload early, keep for the full range."""
    return np.float32(0.0), np.float32(standard_keep)


# --------------------------------------------------------------------------
# Decision gates (Fig. 10)
# --------------------------------------------------------------------------


def oob_heavy(total, oob, oob_fraction_threshold: float):
    """Mostly-out-of-bounds check routing an app to the time-series path."""
    f = np.float32
    if isinstance(total, int):             # scalar control-plane fast path
        return bool(f(oob) > f(oob_fraction_threshold) * f(max(total + oob, 1)))
    return oob.astype(f) > f(oob_fraction_threshold) * \
        jnp.maximum(total + oob, 1).astype(f)


def use_histogram_gate(total, oob, cv_sum, cv_sum_sq, n_bins: int,
                       min_samples: int, cv_threshold: float,
                       oob_fraction_threshold: float):
    """Whether the histogram windows govern the next gap (else fall back to
    the standard keep-alive / time-series path). Evaluated in int/float32
    so every engine takes the same branch."""
    if isinstance(total, int):             # scalar control-plane fast path
        return bool(
            total + oob >= min_samples and total > 0
            and not oob_heavy(total, oob, oob_fraction_threshold)
            and bin_count_cv(float(cv_sum), float(cv_sum_sq), n_bins,
                             np.float32) >= np.float32(cv_threshold))
    cv = bin_count_cv(cv_sum, cv_sum_sq, n_bins, np.float32)
    seen = total + oob
    return (seen >= min_samples) & (cv >= np.float32(cv_threshold)) \
        & (total > 0) & ~oob_heavy(total, oob, oob_fraction_threshold)


def arima_window(predicted_it: float, margin: float) -> Tuple[float, float]:
    """§4.3: (prewarm, keep_alive) around a forecast idle time — pre-warm
    just before the prediction, keep alive across a 2-margin band."""
    return predicted_it * (1.0 - margin), 2.0 * margin * predicted_it


# --------------------------------------------------------------------------
# The fused simulator step (one invocation column for the whole fleet)
# --------------------------------------------------------------------------


def fused_hybrid_step_math(t_now, prev_t, cum, oob, cv_sum, cv_sum_sq,
                           prewarm, unload_at, cold, waste, *, n_bins: int,
                           head_pct: float, tail_pct: float, margin: float,
                           bin_minutes: float, range_minutes: float,
                           cv_threshold: float, min_samples: int,
                           oob_threshold: float, standard_keep: float,
                           gather: bool):
    """One fused hybrid-policy step: warm/cold + waste verdict under the
    previously decided windows, histogram suffix-add update, Welford CV
    accumulation, and the percentile-window decision for the next gap.

    Carries (prewarm, unload_at) residency *bounds* — not (prewarm, keep)
    — so no engine ever re-derives ``prewarm + keep`` in its own dtype.
    Works identically inside ``lax.scan`` bodies (``gather=True``) and
    Pallas kernel bodies (``gather=False``); the time dtype (float64 on
    CPU, float32 on TPU) is taken from ``t_now``.
    """
    wdtype = t_now.dtype
    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    # Verdict for the gap that just closed.
    is_cold = valid & (first | ~warm_from_bounds(it, prewarm, unload_at))
    gap_waste = jnp.where(valid & ~first,
                          idle_from_bounds(it, prewarm, unload_at),
                          jnp.zeros((), wdtype))

    # Histogram + CV update on the cumulative representation.
    rec = valid & ~first
    safe, in_b, oob_hit = classify_idle_time(it, rec, bin_minutes, n_bins)
    old = raw_count_at(cum, safe, gather=gather)
    new_cum = suffix_add(cum, safe, in_b)
    # last prefix sum == total in-bounds count (cum is nondecreasing; the
    # reduction form avoids a lane slice inside Pallas)
    total = (new_cum[:, -1] if gather else jnp.max(new_cum, axis=-1)) \
        .astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    cv_sum, cv_sum_sq = welford_update(cv_sum, cv_sum_sq, in_b, old)

    # Decision layer (int/float32 — dtype-invariant across engines).
    head_thr = percentile_threshold_scaled(total, head_pct)
    tail_thr = percentile_threshold_scaled(total, tail_pct)
    head_bin = first_bin_ge_scaled(new_cum, head_thr, gather=gather)
    tail_bin = first_bin_ge_scaled(new_cum, tail_thr, gather=gather) + 1
    new_load, new_unload = window_values(head_bin, tail_bin, bin_minutes,
                                         range_minutes, margin)
    use_hist = use_histogram_gate(total, oob, cv_sum, cv_sum_sq, n_bins,
                                  min_samples, cv_threshold, oob_threshold)
    std_load, std_unload = standard_window_bounds(standard_keep)
    new_load = jnp.where(use_hist, new_load, std_load).astype(wdtype)
    new_unload = jnp.where(use_hist, new_unload, std_unload).astype(wdtype)

    # Windows decided now govern the next gap of apps that saw an event.
    prewarm = jnp.where(valid, new_load, prewarm)
    unload_at = jnp.where(valid, new_unload, unload_at)
    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, new_cum, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
            cold + is_cold, waste + gap_waste)
