"""Single source of truth for the hybrid keep-alive policy math (paper §4).

Every engine in the repo — the scalar control-plane policy
(:class:`repro.core.policy.HybridHistogramPolicy` / ``AppHistogram``), the
vectorized ``lax.scan`` engines in :mod:`repro.core.simulator`, and the
Pallas TPU kernels in :mod:`repro.kernels.histogram` — computes its
decisions through the helpers below. A policy-formula change is a one-file
edit here; the conformance suite (``tests/test_engine_conformance.py``)
asserts the engines stay in exact agreement.

Mapping to the paper's §4 hybrid-policy description:

  * :func:`classify_idle_time`       — §4.2 range-limited IT histogram:
    1-minute bins up to a 4-hour range, beyond-range ITs counted as
    out-of-bounds (OOB).
  * :func:`suffix_add` / :func:`raw_count_at` — the fused engines' cumulative
    bin-count representation of that histogram (recording bin *b* is a
    suffix add over ``[b, n_bins)``, so percentiles read straight off the
    maintained prefix sums).
  * :func:`welford_update` / :func:`bin_count_cv` — §4.2 representativeness:
    coefficient of variation of the bin counts, maintained incrementally.
  * :func:`percentile_threshold_scaled` / :func:`first_bin_ge_scaled` /
    :func:`window_values` — §4.2 head/tail percentile windows: pre-warm =
    5th-percentile bin lower edge minus a 10% margin, keep-alive up to the
    99th-percentile bin upper edge plus the margin.
  * :func:`use_histogram_gate` / :func:`oob_heavy` — Fig. 10 decision tree:
    too few ITs or a too-uniform histogram (CV below threshold) falls back
    to the *standard keep-alive* (pre-warm 0, keep-alive = range); mostly
    OOB apps go to the time-series (ARIMA) path.
  * :func:`arima_window`             — §4.3 ARIMA windows: pre-warm just
    below the forecast IT, keep-alive covering a band around it.
  * :func:`warm_from_bounds` / :func:`idle_from_bounds` — §4.1 semantics:
    an invocation is warm iff it lands while the image is resident
    (``load_at <= IT <= unload_at``); loaded-but-idle time is the wasted
    memory the provider pays.

Dtype discipline (what makes the float32/TPU engines bit-match the float64
oracle):

  * The *decision layer* is dtype-invariant by construction: percentile
    thresholds are exact integer arithmetic (no float ``ceil``), CV and the
    window values (``load_at`` / ``unload_at``) are always computed in
    float32 from exactly-representable integer state. Engines carry the
    resulting bounds in their own time dtype (a float32 value widens to
    float64 exactly), so warm/cold verdicts compare identical reals in
    every engine.
  * The *time layer* (inter-arrival times, waste accumulation) stays in the
    engine's dtype. The float32 engines recover exact ITs via per-chunk
    time rebasing (see ``simulator._run_hybrid_sweep``).
  * Integer state must stay below 2**24 for the float32 casts to be exact
    and below 2**31 / PCT_SCALE for the scaled threshold compare; both hold
    for any trace this repo produces (per-app event counts are bounded by
    the 1-minute dataset granularity).

Helpers are polymorphic over numpy and jnp (host scalars stay numpy — the
scalar policy pays no jax dispatch overhead) and trace identically inside
``jax.lax.scan`` bodies and Pallas TPU kernel bodies. Helpers that need a
row-wise lookup take a ``gather`` flag: gathers are fast under XLA but not
Mosaic-lowerable, so Pallas bodies use the reduction forms (both forms are
asserted equivalent by the property suite).

Config knobs are *data*, not trace constants: :class:`HybridStepConfig`
packages one policy configuration into the exact dtypes the decision layer
consumes (integer percentile numerators, float32 margin factors, ...). Its
leaves may be python/numpy scalars (the scalar policy and single-config
paths) or traced arrays broadcast against the app axis — which is what lets
``repro.core.experiment.sweep`` stack S configurations into one traced
config axis and scan the trace once for the whole grid.
:func:`fused_hybrid_sweep_step_math` is that sweep step: the histogram
sufficient statistics are carried once per *distinct histogram shape*
(group layer), percentile windows once per distinct window variant, the
CV/min-samples gate once per distinct gate variant, and each of the S
configs just selects its (window, gate) pair — so a 16-point CV-threshold
grid pays for one histogram update per step, not 16.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PCT_SCALE",
    "MAX_SCALED_COUNT",
    "pct_numer",
    "scale_raw_threshold",
    "margin_factors",
    "window_bounds",
    "warm_from_bounds",
    "idle_from_bounds",
    "classify_idle_time",
    "suffix_add",
    "raw_count_at",
    "welford_update",
    "bin_count_cv",
    "percentile_threshold_scaled",
    "percentile_threshold_scaled_numer",
    "first_bin_ge_scaled",
    "first_bin_ge_scaled_grouped",
    "window_values",
    "window_values_from_factors",
    "standard_window_bounds",
    "use_histogram_gate",
    "use_histogram_gate_from_cv",
    "oob_heavy",
    "arima_window",
    "SpesStepConfig",
    "spes_update",
    "spes_window_from_counts",
    "fused_spes_step_math",
    "HybridStepConfig",
    "HybridSweepBlock",
    "SweepIdentities",
    "fused_hybrid_step_math",
    "hybrid_sweep_decide",
    "fused_hybrid_sweep_step_math",
]

# Percentiles are quantized to 1/100 of a percent and compared in exact
# integer arithmetic: ``cum >= ceil(total*pct/100)`` iff
# ``cum*PCT_SCALE >= total*pct_numer`` — no float rounding, so every engine
# derives the same percentile bin in any dtype.
PCT_SCALE = 10_000

#: Largest per-app cumulative count whose scaled compare (``cum *
#: PCT_SCALE``) still fits int32. Engines reject wider scans up front
#: (``simulator._check_scan_width``) instead of overflowing silently.
MAX_SCALED_COUNT = (2 ** 31 - 1) // PCT_SCALE


def _ns(*xs):
    """numpy for host values, jnp for traced/device values."""
    for x in xs:
        if isinstance(x, (jax.Array, jax.core.Tracer)):
            return jnp
    return np


def _f32(x):
    """Exact float32 view of a config knob, host or traced.

    Python/numpy scalars go through ``np.float32`` (the value every engine's
    decision layer compares against); traced arrays are cast — equal values
    by construction because config blocks are built host-side from the same
    python floats."""
    if isinstance(x, (jax.Array, jax.core.Tracer)):
        return x.astype(jnp.float32)
    return np.float32(x)


def _i32(x):
    """int32 view of a config knob, host or traced."""
    if isinstance(x, (jax.Array, jax.core.Tracer)):
        return x.astype(jnp.int32)
    return np.int32(x)


def _f64(x):
    """float64 view of a value, host or traced (traced callers run under
    x64 — every float64 engine scan does)."""
    if isinstance(x, (jax.Array, jax.core.Tracer)):
        return x.astype(jnp.float64)
    return np.float64(x)


# --------------------------------------------------------------------------
# Warm/cold + waste verdicts (§4.1)
# --------------------------------------------------------------------------


def window_bounds(prewarm, keep_alive):
    """(load_at, unload_at) residency offsets from the last execution end.

    ``prewarm <= 0`` means the image is never unloaded after the execution:
    it is resident on ``[0, keep_alive]``. Otherwise it is unloaded
    immediately, re-loaded at ``prewarm`` and kept until
    ``prewarm + keep_alive``.
    """
    if _both_float(prewarm, keep_alive):   # scalar control-plane fast path
        load_at = prewarm if prewarm > 0.0 else 0.0
        return load_at, load_at + keep_alive
    xp = _ns(prewarm, keep_alive)
    load_at = xp.where(prewarm > 0.0, prewarm, 0.0)
    return load_at, load_at + keep_alive


def _both_float(a, b) -> bool:
    return isinstance(a, (float, int)) and isinstance(b, (float, int))


def warm_from_bounds(it, load_at, unload_at):
    """Warm iff the invocation arrives while the image is resident."""
    return (it >= load_at) & (it <= unload_at)


def idle_from_bounds(it, load_at, unload_at):
    """Loaded-but-idle memory time during a gap of length ``it`` (>= 0).

    The image sits idle from ``load_at`` until the arrival (or until
    ``unload_at`` if the gap outlives the keep-alive); arrivals before
    ``load_at`` never paid for a resident image.
    """
    if _both_float(it, load_at) and _both_float(it, unload_at):
        return max(min(it, unload_at) - load_at, 0.0)
    xp = _ns(it, load_at, unload_at)
    return xp.maximum(xp.minimum(it, unload_at) - load_at, 0.0)


# --------------------------------------------------------------------------
# Histogram update (§4.2)
# --------------------------------------------------------------------------


def classify_idle_time(it, active, bin_minutes: float, n_bins: int):
    """Bin an idle time: (clipped_bin, in_bounds, oob_hit)."""
    if isinstance(it, float):          # scalar control-plane fast path
        bin_idx = math.floor(it / bin_minutes)
        in_bounds = bool(active) and 0 <= bin_idx < n_bins
        oob_hit = bool(active) and bin_idx >= n_bins
        return min(max(bin_idx, 0), n_bins - 1), in_bounds, oob_hit
    xp = _ns(it, active)
    bin_idx = xp.floor(it / bin_minutes).astype(xp.int32)
    in_bounds = active & (bin_idx >= 0) & (bin_idx < n_bins)
    oob_hit = active & (bin_idx >= n_bins)
    safe = xp.clip(bin_idx, 0, n_bins - 1)
    return safe, in_bounds, oob_hit


def suffix_add(cum, safe_bin, in_bounds):
    """Record a hit at ``safe_bin`` into cumulative counts ``cum``.

    ``cum`` is [n_apps, n_bins] maintained prefix sums; one observation is
    a +1 over the suffix ``[safe_bin, n_bins)``. Traced-only (rank 2).
    """
    iota = jax.lax.broadcasted_iota(jnp.int32, cum.shape, cum.ndim - 1)
    return cum + ((iota >= safe_bin[..., None])
                  & in_bounds[..., None]).astype(cum.dtype)


def raw_count_at(cum, safe_bin, *, gather: bool):
    """Pre-update raw count of ``safe_bin`` read off cumulative counts.

    ``gather=True`` uses row-wise dynamic indexing (fast under XLA);
    ``gather=False`` uses masked reductions (Mosaic/Pallas-lowerable).
    Both return the same int32 values.
    """
    if gather:
        take = lambda idx: jnp.take_along_axis(
            cum, idx[..., None], axis=-1)[..., 0].astype(jnp.int32)
        cum_at = take(safe_bin)
        cum_below = jnp.where(safe_bin > 0,
                              take(jnp.maximum(safe_bin - 1, 0)), 0)
        return cum_at - cum_below
    iota = jax.lax.broadcasted_iota(jnp.int32, cum.shape, cum.ndim - 1)
    cum_at = jnp.sum(jnp.where(iota == safe_bin[..., None], cum, 0), axis=-1)
    cum_below = jnp.sum(
        jnp.where(iota == (safe_bin - 1)[..., None], cum, 0), axis=-1)
    return (cum_at - cum_below).astype(jnp.int32)


def welford_update(cv_sum, cv_sum_sq, in_bounds, old_count):
    """O(1) update of the bin-count sum / sum-of-squares accumulators.

    A bin going ``old -> old+1`` changes the sum of squared counts by
    ``2*old + 1``. Accumulator dtype is preserved (float64 oracle, float32
    kernels); values are exact integers while below the dtype's mantissa.
    """
    if isinstance(cv_sum, float):      # scalar control-plane fast path
        inb = 1.0 if in_bounds else 0.0
        return cv_sum + inb, cv_sum_sq + inb * (2.0 * float(old_count) + 1.0)
    xp = _ns(cv_sum, cv_sum_sq)
    dt = cv_sum.dtype if hasattr(cv_sum, "dtype") else xp.float64
    inb = xp.asarray(in_bounds, dt) if xp is np else in_bounds.astype(dt)
    old = xp.asarray(old_count, dt) if xp is np else old_count.astype(dt)
    return cv_sum + inb, cv_sum_sq + inb * (2.0 * old + 1.0)


# --------------------------------------------------------------------------
# Representativeness (CV of bin counts, §4.2)
# --------------------------------------------------------------------------


def bin_count_cv(cv_sum, cv_sum_sq, n_bins: int, dtype=np.float32):
    """Coefficient of variation of the bin counts from the accumulators.

    The gate evaluates this in float32 in every engine (``dtype`` is only
    widened for host-side reporting); the inputs are exact integers, so the
    float32 value is identical across engines.
    """
    if isinstance(cv_sum, float):              # scalar control-plane paths
        if dtype is np.float64:
            mean = cv_sum / n_bins
            if mean <= 0.0:
                return 0.0
            var = max(cv_sum_sq / n_bins - mean * mean, 0.0)
            return math.sqrt(var) / max(mean, 1e-9)
        # float32 gate semantics: every op rounds to float32, exactly the
        # sequence the batched engines trace
        mean = np.float32(cv_sum) / np.float32(n_bins)
        if not mean > 0:
            return np.float32(0.0)
        var = np.float32(cv_sum_sq) / np.float32(n_bins) - mean * mean
        if var < 0:
            var = np.float32(0.0)
        return np.sqrt(var) / max(mean, np.float32(1e-9))
    xp = _ns(cv_sum, cv_sum_sq)
    cvs = xp.asarray(cv_sum, dtype) if xp is np else cv_sum.astype(dtype)
    cvss = xp.asarray(cv_sum_sq, dtype) if xp is np else cv_sum_sq.astype(dtype)
    mean = cvs / n_bins
    var = xp.maximum(cvss / n_bins - mean * mean, dtype(0.0))
    return xp.where(mean > 0, xp.sqrt(var) / xp.maximum(mean, dtype(1e-9)),
                    dtype(0.0))


# --------------------------------------------------------------------------
# Percentile windows (§4.2)
# --------------------------------------------------------------------------


def pct_numer(pct: float) -> int:
    """Percentile as an exact integer numerator over PCT_SCALE."""
    return int(round(pct * (PCT_SCALE / 100.0)))


def percentile_threshold_scaled(total, pct: float):
    """Scaled percentile threshold: ``cum`` hits the pct-percentile iff
    ``cum * PCT_SCALE >= threshold`` (with the paper's floor of one
    sample). Pure integer math — dtype-invariant by construction."""
    return percentile_threshold_scaled_numer(total, pct_numer(pct))


def percentile_threshold_scaled_numer(total, numer):
    """:func:`percentile_threshold_scaled` from a precomputed integer
    numerator (``pct_numer``); ``numer`` may be a traced int32 per-config
    knob — the sweep engine's percentile axis."""
    if isinstance(total, int) and isinstance(numer, (int, np.integer)):
        return max(total * int(numer), PCT_SCALE)
    xp = _ns(total, numer)
    if xp is np:
        return np.maximum(np.int64(total) * numer, PCT_SCALE)
    return jnp.maximum(_i32(total) * _i32(numer), jnp.int32(PCT_SCALE))


def first_bin_ge_scaled(cum, thr_scaled, *, gather: bool):
    """First bin index where ``cum * PCT_SCALE >= thr_scaled``; ``n_bins``
    when no bin qualifies (only possible with zero in-bounds samples —
    callers gate on ``total > 0``).

    ``gather=True`` runs an O(log n_bins) binary search (XLA scan bodies);
    ``gather=False`` a masked min over the bin iota (Pallas bodies, numpy
    host path). Identical results.
    """
    xp = _ns(cum, thr_scaled)
    n_bins = cum.shape[-1]
    if xp is np:
        cum = np.asarray(cum, np.int64)
        if cum.ndim == 1 and np.ndim(thr_scaled) == 0:
            # host fast path: cum is nondecreasing, so the masked min is a
            # binary search — cum*S >= thr iff cum >= ceil(thr/S)
            need = -(-int(thr_scaled) // PCT_SCALE)
            return int(np.searchsorted(cum, need, side="left"))
        iota = np.broadcast_to(np.arange(n_bins), cum.shape)
        hit = cum * PCT_SCALE >= np.asarray(thr_scaled)[..., None]
        return np.min(np.where(hit, iota, n_bins), axis=-1)
    if not gather:
        iota = jax.lax.broadcasted_iota(jnp.int32, cum.shape, cum.ndim - 1)
        hit = cum.astype(jnp.int32) * jnp.int32(PCT_SCALE) >= \
            thr_scaled[..., None]
        return jnp.min(jnp.where(hit, iota, n_bins), axis=-1)
    rows_shape = cum.shape[:-1]
    lo = jnp.zeros(rows_shape, jnp.int32)
    hi = jnp.full(rows_shape, n_bins, jnp.int32)
    # search space is [0, n_bins] — n_bins + 1 candidate answers
    for _ in range(int(np.ceil(np.log2(n_bins + 1)))):
        mid = (lo + hi) // 2
        v = jnp.take_along_axis(
            cum, jnp.minimum(mid, n_bins - 1)[..., None],
            axis=-1)[..., 0].astype(jnp.int32)
        ge = (v * jnp.int32(PCT_SCALE) >= thr_scaled) & (mid < n_bins)
        hi = jnp.where(ge, mid, hi)
        lo = jnp.where(ge, lo, jnp.minimum(mid + 1, hi))
    return hi


def scale_raw_threshold(threshold):
    """Lift a raw *count* threshold into the scaled domain of
    :func:`first_bin_ge_scaled`: ``threshold * PCT_SCALE``, in the int32 the
    scaled compare runs in (callers guard widths via
    :data:`MAX_SCALED_COUNT`, so this never overflows).
    """
    xp = _ns(threshold)
    if xp is np:
        return np.int64(threshold) * PCT_SCALE
    return threshold.astype(jnp.int32) * jnp.int32(PCT_SCALE)


def first_bin_ge_scaled_grouped(gcum, group, thr_scaled):
    """Per-variant percentile search over *grouped* cumulative rows.

    ``gcum`` is [G, n_apps, n_bins] — one histogram state per distinct
    histogram shape; ``group`` [W] maps each window variant to its group;
    ``thr_scaled`` is [W, n_apps]. Returns the same bins as
    ``first_bin_ge_scaled(gcum[group], thr_scaled, gather=True)`` without
    materializing the [W, n_apps, n_bins] gather: each binary-search probe
    reads one [W, n_apps] slice straight out of the group state.
    """
    n_bins = gcum.shape[-1]
    cols = jnp.arange(thr_scaled.shape[-1], dtype=jnp.int32)[None, :]
    g = group[:, None].astype(jnp.int32)
    lo = jnp.zeros(thr_scaled.shape, jnp.int32)
    hi = jnp.full(thr_scaled.shape, n_bins, jnp.int32)
    for _ in range(int(np.ceil(np.log2(n_bins + 1)))):
        mid = (lo + hi) // 2
        v = gcum[g, cols, jnp.minimum(mid, n_bins - 1)].astype(jnp.int32)
        ge = (v * jnp.int32(PCT_SCALE) >= thr_scaled) & (mid < n_bins)
        hi = jnp.where(ge, mid, hi)
        lo = jnp.where(ge, lo, jnp.minimum(mid + 1, hi))
    return hi


def margin_factors(margin: float) -> Tuple[np.float32, np.float32]:
    """The float32 margin factors the decision layer multiplies by.

    Precomputed host-side (``1 ± margin`` rounds once, in float64, before
    the float32 cast) so a traced per-config margin axis reproduces the
    static path bit-for-bit.
    """
    return np.float32(1.0 - margin), np.float32(1.0 + margin)


def window_values(head_bin, tail_bin, bin_minutes: float,
                  range_minutes: float, margin: float):
    """(load_at, unload_at) in minutes from percentile bin indices.

    load_at   = head bin lower edge, reduced by the margin;
    unload_at = tail bin upper edge (clamped to the range), increased by
                the margin — never below load_at.
    Always computed AND returned in float32: window values are decisions,
    and float32 keeps them identical across engines (they widen to float64
    exactly).
    """
    lo, hi = margin_factors(margin)
    return window_values_from_factors(head_bin, tail_bin,
                                      np.float32(bin_minutes),
                                      np.float32(range_minutes), lo, hi)


def window_values_from_factors(head_bin, tail_bin, bin_f32, range_f32,
                               margin_lo, margin_hi):
    """:func:`window_values` from precomputed float32 knobs; all four knobs
    may be traced per-config arrays (the sweep window-variant axis)."""
    xp = _ns(head_bin, tail_bin, bin_f32, margin_lo)
    f = np.float32
    head = xp.asarray(head_bin, f) if xp is np else head_bin.astype(f)
    tail = xp.asarray(tail_bin, f) if xp is np else tail_bin.astype(f)
    load_at = head * bin_f32 * margin_lo
    unload_at = xp.minimum(tail * bin_f32, range_f32) * margin_hi
    return load_at, xp.maximum(unload_at, load_at)


def standard_window_bounds(standard_keep):
    """The fallback windows: never unload early, keep for the full range."""
    return np.float32(0.0), _f32(standard_keep)


# --------------------------------------------------------------------------
# Decision gates (Fig. 10)
# --------------------------------------------------------------------------


def oob_heavy(total, oob, oob_fraction_threshold):
    """Mostly-out-of-bounds check routing an app to the time-series path."""
    f = np.float32
    if isinstance(total, int):             # scalar control-plane fast path
        return bool(f(oob) > f(oob_fraction_threshold) * f(max(total + oob, 1)))
    return oob.astype(f) > _f32(oob_fraction_threshold) * \
        jnp.maximum(total + oob, 1).astype(f)


def use_histogram_gate(total, oob, cv_sum, cv_sum_sq, n_bins,
                       min_samples, cv_threshold, oob_fraction_threshold):
    """Whether the histogram windows govern the next gap (else fall back to
    the standard keep-alive / time-series path). Evaluated in int/float32
    so every engine takes the same branch."""
    if isinstance(total, int):             # scalar control-plane fast path
        return bool(
            total + oob >= min_samples and total > 0
            and not oob_heavy(total, oob, oob_fraction_threshold)
            and bin_count_cv(float(cv_sum), float(cv_sum_sq), n_bins,
                             np.float32) >= np.float32(cv_threshold))
    cv = bin_count_cv(cv_sum, cv_sum_sq, n_bins, np.float32)
    return use_histogram_gate_from_cv(total, oob, cv, min_samples,
                                      cv_threshold, oob_fraction_threshold)


def use_histogram_gate_from_cv(total, oob, cv, min_samples, cv_threshold,
                               oob_fraction_threshold):
    """Traced-path gate from a precomputed float32 CV — the sweep engine
    computes CV once per histogram group and gates once per distinct
    (min_samples, cv_threshold, oob_threshold) variant."""
    seen = total + oob
    return (seen >= min_samples) & (cv >= _f32(cv_threshold)) \
        & (total > 0) & ~oob_heavy(total, oob, oob_fraction_threshold)


def arima_window(predicted_it: float, margin: float) -> Tuple[float, float]:
    """§4.3: (prewarm, keep_alive) around a forecast idle time — pre-warm
    just before the prediction, keep alive across a 2-margin band."""
    return predicted_it * (1.0 - margin), 2.0 * margin * predicted_it


# --------------------------------------------------------------------------
# SPES-style next-idle predictor (the PolicySpec predictor family)
# --------------------------------------------------------------------------


class SpesStepConfig(NamedTuple):
    """One SPES-predictor configuration in the dtypes the decision layer
    consumes. Leaves may be host scalars (the scalar policy) or traced
    ``[S, 1]`` arrays broadcast against the app axis (the sweep config
    axis), like :class:`HybridStepConfig`."""
    alpha: object          # f32 — exponential smoothing weight
    om_alpha: object       # f32 — (1 - alpha), rounded once on the host
    band_margin: object    # f32 — relative half-band around the forecast
    band_sigma: object     # f32 — residual-std multiplier widening the band
    min_samples: object    # i32 — observed ITs before the forecast governs
    standard_keep: object  # f32 — fallback keep-alive until warmed up

    @classmethod
    def from_host(cls, *, alpha: float, band_margin: float,
                  band_sigma: float, min_samples: int,
                  standard_keep: float) -> "SpesStepConfig":
        return cls(alpha=np.float32(alpha), om_alpha=np.float32(1.0 - alpha),
                   band_margin=np.float32(band_margin),
                   band_sigma=np.float32(band_sigma),
                   min_samples=np.int32(min_samples),
                   standard_keep=np.float32(standard_keep))


def spes_update(mean, var, n_obs, it32, active, alpha, om_alpha):
    """One exponentially-weighted update of the next-idle forecast state.

    State is ``(mean, var, n_obs)``: EW mean of the observed inter-arrival
    times, EW variance of the one-step forecast residuals (West's update:
    ``var' = (1 - a) * (var + a * err^2)``), and the observation count.
    The carried state is always float32 — like the histogram decision
    layer, the predictor state is a *decision* input, so every engine (the
    float64 fused scan, the scalar control-plane policy) holds identical
    values. The update itself is computed in float64 and rounded ONCE to
    float32: a float32 op-by-op pipeline is not engine-invariant (XLA
    freely contracts mul+add into FMA, numpy never does), while one wide
    computation with a single final rounding agrees across fusion choices
    except on the measure-zero float32 rounding boundary. The first
    observation seeds ``mean`` directly with zero variance; ``active``
    masks padding/first-event columns.
    """
    xp = _ns(mean, it32, active)
    first = n_obs == 0
    m, v = _f64(mean), _f64(var)
    err = _f64(it32) - m
    incr = _f64(alpha) * err
    upd_mean = xp.where(first, _f64(it32), m + incr)
    upd_var = xp.where(first, np.float64(0.0),
                       _f64(om_alpha) * (v + err * incr))
    new_mean = _f32(xp.where(active, upd_mean, m))
    new_var = _f32(xp.where(active, upd_var, v))
    return new_mean, new_var, n_obs + active


def spes_window_from_counts(mean, var, n_obs, min_samples, band_margin,
                            band_sigma, standard_keep):
    """(load_at, unload_at) residency bounds from the forecast state.

    The point forecast of the next idle time is the EW ``mean``; the
    confidence band around it is a relative margin plus ``band_sigma``
    residual standard deviations, so a perfectly regular app (var -> 0)
    converges to a tight window while an erratic one keeps a wide net.
    Below ``min_samples`` observations the standard keep-alive governs.
    Computed in float64 from the float32 state and rounded once to float32
    (the same FMA-invariance rationale as :func:`spes_update`); the
    returned float32 bounds widen to float64 exactly, so verdicts agree
    across engines.
    """
    xp = _ns(mean, var, n_obs)
    m = _f64(mean)
    half = _f64(band_margin) * m + _f64(band_sigma) * xp.sqrt(_f64(var))
    load = xp.maximum(m - half, np.float64(0.0))
    unload = xp.maximum(m + half, load)
    ready = n_obs >= _i32(min_samples)
    std_load, std_unload = standard_window_bounds(standard_keep)
    return (xp.where(ready, _f32(load), std_load),
            xp.where(ready, _f32(unload), std_unload))


def fused_spes_step_math(t_now, prev_t, mean, var, n_obs, load_at,
                         unload_at, cold, waste, *, cfg: SpesStepConfig):
    """One fused SPES-predictor step: warm/cold + waste verdict under the
    previously decided bounds, the EW forecast-state update, and the
    banded window decision for the next gap.

    Mirrors :func:`fused_hybrid_step_math`'s carry discipline: residency
    *bounds* are carried in the engine's time dtype, the forecast state
    stays float32, and the shared clock/observation count are
    config-independent (``mean``/``var``/bounds broadcast against a
    ``[S, 1]``-leaved ``cfg`` for the sweep engines).
    """
    wdtype = t_now.dtype
    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    # Verdict for the gap that just closed.
    is_cold = valid & (first | ~warm_from_bounds(it, load_at, unload_at))
    gap_waste = jnp.where(valid & ~first,
                          idle_from_bounds(it, load_at, unload_at),
                          jnp.zeros((), wdtype))

    # Forecast-state update (float32 decision layer).
    rec = valid & ~first
    mean, var, n_obs = spes_update(mean, var, n_obs,
                                   it.astype(jnp.float32), rec,
                                   cfg.alpha, cfg.om_alpha)
    new_load, new_unload = spes_window_from_counts(
        mean, var, n_obs, cfg.min_samples, cfg.band_margin, cfg.band_sigma,
        cfg.standard_keep)

    # Windows decided now govern the next gap of apps that saw an event.
    load_at = jnp.where(valid, new_load.astype(wdtype), load_at)
    unload_at = jnp.where(valid, new_unload.astype(wdtype), unload_at)
    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, mean, var, n_obs, load_at, unload_at,
            cold + is_cold, waste + gap_waste)


# --------------------------------------------------------------------------
# The fused simulator step (one invocation column for the whole fleet)
# --------------------------------------------------------------------------


class HybridStepConfig(NamedTuple):
    """One hybrid-policy configuration, precomputed into the exact dtypes
    the decision layer consumes.

    Leaves may be python/numpy scalars (static single-config paths) or
    traced scalars/arrays broadcastable against the app axis (the sweep
    config axis; the Pallas sweep kernel reads them out of SMEM). Being a
    NamedTuple, it is a pytree: it flows through ``jax.jit``/``lax.scan``
    as data, so a new grid point never retraces an engine.
    """
    n_bins: object        # i32 — effective bin count (<= allocated bins)
    head_numer: object    # i32 — head percentile numerator over PCT_SCALE
    tail_numer: object    # i32 — tail percentile numerator over PCT_SCALE
    margin_lo: object     # f32 — (1 - margin), rounded once on the host
    margin_hi: object     # f32 — (1 + margin)
    bin_minutes: object   # engine time dtype — IT binning divisor
    bin_f32: object       # f32 — bin width as the window values consume it
    range_f32: object     # f32 — histogram range for the window clamp
    cv_threshold: object  # f32
    min_samples: object   # i32
    oob_threshold: object  # f32
    standard_keep: object  # f32 — fallback keep-alive (== range)

    @classmethod
    def from_host(cls, *, n_bins: int, head_pct: float, tail_pct: float,
                  margin: float, bin_minutes: float, range_minutes: float,
                  cv_threshold: float, min_samples: int, oob_threshold: float,
                  standard_keep: float) -> "HybridStepConfig":
        lo, hi = margin_factors(margin)
        return cls(
            n_bins=int(n_bins), head_numer=pct_numer(head_pct),
            tail_numer=pct_numer(tail_pct), margin_lo=lo, margin_hi=hi,
            bin_minutes=float(bin_minutes), bin_f32=np.float32(bin_minutes),
            range_f32=np.float32(range_minutes),
            cv_threshold=np.float32(cv_threshold),
            min_samples=int(min_samples),
            oob_threshold=np.float32(oob_threshold),
            standard_keep=np.float32(standard_keep))


def fused_hybrid_step_math(t_now, prev_t, cum, oob, cv_sum, cv_sum_sq,
                           prewarm, unload_at, cold, waste, *,
                           cfg: HybridStepConfig, gather: bool):
    """One fused hybrid-policy step: warm/cold + waste verdict under the
    previously decided windows, histogram suffix-add update, Welford CV
    accumulation, and the percentile-window decision for the next gap.

    Carries (prewarm, unload_at) residency *bounds* — not (prewarm, keep)
    — so no engine ever re-derives ``prewarm + keep`` in its own dtype.
    Works identically inside ``lax.scan`` bodies (``gather=True``) and
    Pallas kernel bodies (``gather=False``); the time dtype (float64 on
    CPU, float32 on TPU) is taken from ``t_now``. ``cfg`` leaves may be
    static scalars or traced values (per-config SMEM scalars on TPU).
    """
    wdtype = t_now.dtype
    valid = jnp.isfinite(t_now)
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t

    # Verdict for the gap that just closed.
    is_cold = valid & (first | ~warm_from_bounds(it, prewarm, unload_at))
    gap_waste = jnp.where(valid & ~first,
                          idle_from_bounds(it, prewarm, unload_at),
                          jnp.zeros((), wdtype))

    # Histogram + CV update on the cumulative representation.
    rec = valid & ~first
    safe, in_b, oob_hit = classify_idle_time(it, rec, cfg.bin_minutes,
                                             cfg.n_bins)
    old = raw_count_at(cum, safe, gather=gather)
    new_cum = suffix_add(cum, safe, in_b)
    # last prefix sum == total in-bounds count (cum is nondecreasing; the
    # reduction form avoids a lane slice inside Pallas)
    total = (new_cum[..., -1] if gather else jnp.max(new_cum, axis=-1)) \
        .astype(jnp.int32)
    oob = oob + oob_hit.astype(jnp.int32)
    cv_sum, cv_sum_sq = welford_update(cv_sum, cv_sum_sq, in_b, old)

    # Decision layer (int/float32 — dtype-invariant across engines).
    head_thr = percentile_threshold_scaled_numer(total, cfg.head_numer)
    tail_thr = percentile_threshold_scaled_numer(total, cfg.tail_numer)
    head_bin = first_bin_ge_scaled(new_cum, head_thr, gather=gather)
    tail_bin = first_bin_ge_scaled(new_cum, tail_thr, gather=gather) + 1
    new_load, new_unload = window_values_from_factors(
        head_bin, tail_bin, cfg.bin_f32, cfg.range_f32, cfg.margin_lo,
        cfg.margin_hi)
    use_hist = use_histogram_gate(total, oob, cv_sum, cv_sum_sq, cfg.n_bins,
                                  cfg.min_samples, cfg.cv_threshold,
                                  cfg.oob_threshold)
    std_load, std_unload = standard_window_bounds(cfg.standard_keep)
    new_load = jnp.where(use_hist, new_load, std_load).astype(wdtype)
    new_unload = jnp.where(use_hist, new_unload, std_unload).astype(wdtype)

    # Windows decided now govern the next gap of apps that saw an event.
    prewarm = jnp.where(valid, new_load, prewarm)
    unload_at = jnp.where(valid, new_unload, unload_at)
    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, new_cum, oob, cv_sum, cv_sum_sq, prewarm, unload_at,
            cold + is_cold, waste + gap_waste)


# --------------------------------------------------------------------------
# The sweep step: S configurations over one trace column, factored
# --------------------------------------------------------------------------


class HybridSweepBlock(NamedTuple):
    """A whole hybrid-policy grid, factored into its distinct layers.

    The S stacked configurations of one ``experiment.sweep`` call usually
    differ in only one or two knobs (the paper's Figs. 15-17 sweep one knob
    at a time), so the sweep step deduplicates shared work:

      * group layer ``[G, ...]`` — distinct (bin_minutes, n_bins): the
        histogram sufficient statistics (cumulative counts, OOB, Welford CV
        accumulators) are carried and updated once per group;
      * window layer ``[W, ...]`` — distinct (group, percentiles, margin,
        range): percentile searches + window values once per variant;
      * gate layer ``[T, ...]`` — distinct (group, min_samples,
        cv_threshold, oob_threshold): the representativeness gate once per
        variant;
      * config layer ``[S, ...]`` — every config just *selects* its
        (window, gate) pair; the per-config scan state is cold counts,
        waste, and the carried residency bounds (refreshed from group
        state at each app's events, see :func:`hybrid_sweep_decide`).

    All index leaves are i32 arrays; knob leaves follow the same dtype
    discipline as :class:`HybridStepConfig`, with shapes ``[layer, 1]`` so
    they broadcast against ``[layer, n_apps]`` state.
    """
    # group layer
    g_bin_minutes: object   # [G, 1] time dtype
    g_n_bins: object        # [G, 1] i32 (effective bins; allocation is max)
    # window-variant layer
    w_group: object         # [W] i32 — variant -> group row
    w_head_numer: object    # [W, 1] i32
    w_tail_numer: object    # [W, 1] i32
    w_bin_f32: object       # [W, 1] f32
    w_range_f32: object     # [W, 1] f32
    w_margin_lo: object     # [W, 1] f32
    w_margin_hi: object     # [W, 1] f32
    # gate-variant layer
    t_group: object         # [T] i32 — variant -> group row
    t_min_samples: object   # [T, 1] i32
    t_cv_threshold: object  # [T, 1] f32
    t_oob_threshold: object  # [T, 1] f32
    # standard-keep layer (fallback windows, one per distinct keep-alive)
    d_standard_keep: object  # [D, 1] f32
    # config layer
    c_window: object        # [S] i32 — config -> window variant
    c_gate: object          # [S] i32 — config -> gate variant
    c_std: object           # [S] i32 — config -> standard-keep row


class SweepIdentities(NamedTuple):
    """Static structure flags for a :class:`HybridSweepBlock`.

    Each flag asserts that a selector index array is the identity mapping
    (known host-side when the block is built), letting the traced decision
    layers skip the corresponding gather — on CPU, per-step gathers cost
    more than the whole verdict math they route, and for a single-config
    run EVERY selector is the identity, so the S=1 path keeps the pre-sweep
    engine's gather-free form. Results are identical either way.
    """
    w: bool = False        # window variant w reads group w
    t: bool = False        # gate variant t reads group t
    c_window: bool = False  # config s uses window variant s
    c_gate: bool = False   # config s uses gate variant s
    c_std: bool = False    # config s uses standard-keep row s


def _sweep_decision_layers(gcum, goob, gcv_sum, gcv_sum_sq,
                           blk: HybridSweepBlock, ids: SweepIdentities):
    """The shared decision sub-layers from the current group state.

    Returns (w_load, w_unload) [W, n] float32 window-variant bounds and
    ``use_c`` [S, n] bool (per-config histogram-vs-standard gate verdict).

      * window layer: percentile searches once per distinct window variant;
      * gate layer: CV once per group, gate once per threshold tuple;
      * config layer: a gather (elided where ``ids`` proves it identity).
    """
    gtotal = gcum[..., -1].astype(jnp.int32)
    total_w = gtotal if ids.w else gtotal[blk.w_group]
    head_thr = percentile_threshold_scaled_numer(total_w, blk.w_head_numer)
    tail_thr = percentile_threshold_scaled_numer(total_w, blk.w_tail_numer)
    if ids.w:
        head_bin = first_bin_ge_scaled(gcum, head_thr, gather=True)
        tail_bin = first_bin_ge_scaled(gcum, tail_thr, gather=True) + 1
    else:
        head_bin = first_bin_ge_scaled_grouped(gcum, blk.w_group, head_thr)
        tail_bin = first_bin_ge_scaled_grouped(gcum, blk.w_group,
                                               tail_thr) + 1
    w_load, w_unload = window_values_from_factors(
        head_bin, tail_bin, blk.w_bin_f32, blk.w_range_f32, blk.w_margin_lo,
        blk.w_margin_hi)

    gcv = bin_count_cv(gcv_sum, gcv_sum_sq, blk.g_n_bins, np.float32)
    sel_t = (lambda x: x) if ids.t else (lambda x: x[blk.t_group])
    use_hist = use_histogram_gate_from_cv(
        sel_t(gtotal), sel_t(goob), sel_t(gcv),
        blk.t_min_samples, blk.t_cv_threshold, blk.t_oob_threshold)
    return w_load, w_unload, (use_hist if ids.c_gate
                              else use_hist[blk.c_gate])


def hybrid_sweep_decide(gcum, goob, gcv_sum, gcv_sum_sq,
                        blk: HybridSweepBlock,
                        ids: SweepIdentities = SweepIdentities()):
    """Per-config residency bounds from the current group state.

    Every decision input (cumulative counts, OOB, Welford accumulators)
    only changes when an app sees an event, so the windows an app carries
    between events are a *pure function* of group state — which is what
    lets the sweep step carry them (refreshed only at events) and still
    match a fresh decide from the same state. Returns float32
    (load_at, unload_at), each [S, n_apps] (decision-layer dtype; widening
    to the engine's time dtype is exact).
    """
    w_load, w_unload, use_c = _sweep_decision_layers(
        gcum, goob, gcv_sum, gcv_sum_sq, blk, ids)
    std_load, std_unload = standard_window_bounds(
        blk.d_standard_keep if ids.c_std
        else blk.d_standard_keep[blk.c_std])
    load_c = jnp.where(use_c, w_load if ids.c_window
                       else w_load[blk.c_window], std_load)
    unload_c = jnp.where(use_c, w_unload if ids.c_window
                         else w_unload[blk.c_window], std_unload)
    return load_c, unload_c


def fused_hybrid_sweep_step_math(t_now, prev_t, gcum, goob, gcv_sum,
                                 gcv_sum_sq, load_c, unload_c, cold,
                                 waste, *, blk: HybridSweepBlock,
                                 ids: SweepIdentities = SweepIdentities()):
    """One sweep step: S configurations advance together over one trace
    column, sharing the time layer and the per-group histogram update.

    Shapes: ``t_now``/``prev_t`` [n]; group state [G, n(, n_bins)];
    per-config state [S, n] — cold counts, waste, and the carried
    residency bounds ``(load_c, unload_c)`` in the engine's time dtype.
    The bounds are CARRIED, not recomputed at step start: the step
    verdicts the closing gap under them, updates the group state, then
    re-decides from the post-update state — the same carried-windows
    dataflow as the single-config :func:`fused_hybrid_step_math`, which
    lets XLA fuse the decision into the step that produced its state
    instead of stranding it on the next verdict's critical path (this is
    what restores the pre-sweep engine's S=1 step throughput, ROADMAP's
    fused-run regression).

    The carry is bit-identical to re-deriving ``hybrid_sweep_decide`` from
    the pre-update state each step: group state only changes at an app's
    events (the carry is refreshed exactly then, per app), and the init
    carry must equal decide(zero state) — ``(0, standard_keep)``, the
    ``use_histogram_gate`` total>0 fallback arm (float32 decision values
    widen to the time dtype exactly). Every value each config sees is,
    element for element, the same primitive sequence the single-config
    step computes — the layers only deduplicate and gather, so sweep rows
    are bit-identical to single-config runs (asserted by
    ``tests/test_experiment_api.py``).
    """
    wdtype = t_now.dtype
    valid = jnp.isfinite(t_now)        # [n] — shared across the whole grid
    first = ~jnp.isfinite(prev_t)
    it = t_now - prev_t
    account = valid & ~first           # gaps that actually closed

    # Verdict for the gap that just closed, under the carried windows. The
    # verdict math itself stays per-config [S, n]: on CPU the alternative
    # (verdicts per variant + per-config gathers) loses — XLA gathers cost
    # more than the elementwise compare/min/max they would save.
    is_cold = valid & (first | ~warm_from_bounds(it, load_c, unload_c))
    gap_waste = jnp.where(account,
                          idle_from_bounds(it, load_c, unload_c),
                          jnp.zeros((), wdtype))

    # Group layer: one histogram + CV update per distinct histogram shape.
    safe, in_b, oob_hit = classify_idle_time(it, account, blk.g_bin_minutes,
                                             blk.g_n_bins)
    old = raw_count_at(gcum, safe, gather=True)
    new_gcum = suffix_add(gcum, safe, in_b)
    new_goob = goob + oob_hit.astype(jnp.int32)
    gcv_sum, gcv_sum_sq = welford_update(gcv_sum, gcv_sum_sq, in_b, old)

    # Windows governing the next gap, from the post-update state. Apps
    # without an event this step keep their carried bounds — the state
    # they would decide from is unchanged.
    new_load, new_unload = hybrid_sweep_decide(new_gcum, new_goob, gcv_sum,
                                               gcv_sum_sq, blk, ids)
    load_c = jnp.where(valid, new_load.astype(wdtype), load_c)
    unload_c = jnp.where(valid, new_unload.astype(wdtype), unload_c)

    prev_t = jnp.where(valid, t_now, prev_t)
    return (prev_t, new_gcum, new_goob, gcv_sum, gcv_sum_sq, load_c,
            unload_c, cold + is_cold, waste + gap_waste)
