"""Deprecation shims over :mod:`repro.forecast` (the batched ARIMA engine).

This module used to hold the scalar scipy CSS ARIMA implementation the
hybrid policy's out-of-bounds fallback was built on. That implementation
is gone: fitting now runs through the vectorized grid fit in
:mod:`repro.forecast.arima_batched` (one compiled program, ``vmap``-ed over
apps and orders), and the streaming front-end lives in
:mod:`repro.forecast.forecaster`. The scipy reference fit survives only as
a test oracle (``tests/arima_oracle.py``) and a benchmark baseline
(``benchmarks/forecast.py``); scipy itself is a dev-only dependency and is
never imported from library code.

Every public name here is a :class:`DeprecationWarning` shim:

  * :func:`fit_arima` / :func:`auto_arima` fit through the batched grid
    (trailing ``MAX_OBS``-observation window, like the forecaster) and
    re-package the selected order as a legacy :class:`ArimaModel`;
  * :class:`ArimaForecaster` is an alias of
    :class:`repro.forecast.forecaster.ArimaForecaster`.

They will be removed after one deprecation cycle, exactly like the
``simulate*`` entry points that ``repro.core.simulator`` tombstoned in
PR 5 — import from :mod:`repro.forecast` instead.
"""
from __future__ import annotations

import math
import warnings
from typing import Optional, Sequence, Tuple

import numpy as np

__all__ = ["fit_arima", "ArimaModel", "ArimaForecaster", "auto_arima"]

_MAX_OBS = 64  # re-exported legacy constant (== repro.forecast.MAX_OBS)

_DEPRECATED = {
    "fit_arima": "repro.forecast.fit_arima_grid",
    "auto_arima": "repro.forecast.fit_window + select_order_step",
    "ArimaModel": "repro.forecast.GridFit",
    "ArimaForecaster": "repro.forecast.ArimaForecaster",
}


class _ArimaModel:
    """Legacy fitted-model container (deprecated; see module docstring).

    Reconstructed from one row/order of the batched :class:`GridFit`:
    coefficients are the triangle-projected Gauss-Newton optimum, the
    intercept keeps the legacy ``c = mu * (1 - sum(ar))`` convention, and
    :meth:`forecast` replays the zero-pre-sample CSS recursion with the
    stored coefficients on whatever series it is handed.
    """

    def __init__(self, order: Tuple[int, int, int], ar: np.ndarray,
                 ma: np.ndarray, c: float, sigma2: float, aic: float,
                 mu: float = 0.0):
        self.order = order
        self.ar = ar
        self.ma = ma
        self.c = c
        self.sigma2 = sigma2
        self.aic = aic
        self.mu = mu

    def forecast(self, y_orig: Sequence[float]) -> float:
        """One-step-ahead forecast given the original (undifferenced)
        series — the centered-series recursion the batched fit uses."""
        p, d, q = self.order
        if d > 1:
            raise NotImplementedError("d > 1 not supported")
        y = np.asarray(y_orig, float)[-_MAX_OBS:]
        w = np.diff(y, n=d) if d > 0 else y
        wc = w - self.mu
        ar = np.zeros(2)
        ar[:len(self.ar)] = self.ar
        ma = np.zeros(2)
        ma[:len(self.ma)] = self.ma
        w1 = w2 = e1 = e2 = 0.0
        for x in wc:
            e = x - (ar[0] * w1 + ar[1] * w2 + ma[0] * e1 + ma[1] * e2)
            w1, w2 = x, w1
            e1, e2 = e, e1
        pred_w = self.mu + ar[0] * w1 + ar[1] * w2 + ma[0] * e1 + ma[1] * e2
        return float(y[-1] + pred_w) if d == 1 else float(pred_w)


def _model_from_fit(fit, row: int, idx: int) -> Optional[_ArimaModel]:
    from ..forecast.arima_batched import ORDER_GRID

    if not bool(fit.valid[row, idx]):
        return None
    p, d, q = ORDER_GRID[idx]
    coef = np.asarray(fit.coef[row, idx], float)
    ar = coef[:2][:p]
    ma = coef[2:][:q]
    mu = float(fit.mu[row, idx])
    aic = float(fit.aic[row, idx])
    return _ArimaModel((p, d, q), ar, ma, mu * (1.0 - float(np.sum(ar))),
                       math.nan, aic, mu=mu)


def _fit_arima(y: Sequence[float],
               order: Tuple[int, int, int]) -> Optional[_ArimaModel]:
    """CSS fit of one ARIMA(p,d,q) order via the batched grid (deprecated).

    Fits the trailing ``MAX_OBS`` observations — the same window contract
    as the streaming forecaster. Returns ``None`` when the batched fit
    marks the (series, order) pair unusable (too short, non-finite input,
    zero variance).
    """
    from ..forecast.arima_batched import ORDER_GRID, fit_window

    p, d, q = (int(v) for v in order)
    try:
        idx = ORDER_GRID.index((p, d, q))
    except ValueError:
        raise ValueError(f"order {(p, d, q)} outside the supported grid "
                         f"(p <= 2, d <= 1, q <= 2, not all zero)")
    y = np.asarray(y, float)
    fit = fit_window(y)
    m = _model_from_fit(fit, 0, idx)
    if m is not None:
        # Invert the AIC definition for the legacy sigma2 field
        # (aic = n*log(sigma2) + 2k over the differenced length).
        n = min(len(y), _MAX_OBS) - d
        m.sigma2 = math.exp((m.aic - 2.0 * (p + q + 1)) / max(n, 1))
    return m


def _auto_arima(y: Sequence[float], max_p: int = 2, max_d: int = 1,
                max_q: int = 2) -> Optional[_ArimaModel]:
    """Small-grid AIC search via one batched grid fit (deprecated).

    First-wins argmin over the valid grid entries within the requested
    order bounds — the same tie-breaking as the shared
    :func:`repro.forecast.select_order_step`.
    """
    from ..forecast.arima_batched import ORDER_GRID, fit_window

    fit = fit_window(np.asarray(y, float))
    best: Optional[int] = None
    best_aic = math.inf
    for i, (p, d, q) in enumerate(ORDER_GRID):
        if p > max_p or d > max_d or q > max_q:
            continue
        if bool(fit.valid[0, i]) and float(fit.aic[0, i]) < best_aic:
            best = i
            best_aic = float(fit.aic[0, i])
    return None if best is None else _model_from_fit(fit, 0, best)


def __getattr__(name: str):
    if name in _DEPRECATED:
        warnings.warn(
            f"repro.core.arima.{name} is deprecated; use "
            f"{_DEPRECATED[name]} (repro.core.arima is now a shim over "
            f"the batched forecast subsystem and will be removed)",
            DeprecationWarning, stacklevel=2)
        if name == "ArimaForecaster":
            from ..forecast.forecaster import ArimaForecaster
            return ArimaForecaster
        return {"fit_arima": _fit_arima, "auto_arima": _auto_arima,
                "ArimaModel": _ArimaModel}[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
