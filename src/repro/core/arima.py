"""ARIMA(p, d, q) modeling for idle-time forecasting.

The paper uses ``pmdarima.auto_arima`` to forecast the next idle time of
applications whose ITs are mostly out of histogram bounds (very infrequently
invoked). pmdarima is not available offline, so this is a self-contained
implementation:

  * differencing of order ``d``;
  * ARMA(p, q) fitting by conditional sum of squares (CSS) — residuals are
    computed recursively with zero pre-sample values and the squared-error
    objective is minimized with a damped Gauss–Newton/Nelder–Mead hybrid
    (scipy.optimize);
  * auto-order search over a small grid (p, q <= 2, d <= 1) scored by AIC;
  * one-step-ahead forecasting with un-differencing.

The paper notes the initial fit takes ~27 ms and updates ~5 ms; our refit is
similar in spirit (full CSS refit after every observation, which is fine
because ARIMA apps see invocations hours apart and the fit is off the
critical path).
"""
from __future__ import annotations

import itertools
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np
from scipy import optimize

__all__ = ["fit_arima", "ArimaModel", "ArimaForecaster", "auto_arima"]

_MAX_OBS = 64  # rolling window — these apps have hours-long ITs; keep it small


def _css_residuals(y: np.ndarray, ar: np.ndarray, ma: np.ndarray, c: float) -> np.ndarray:
    """Conditional-sum-of-squares residuals for an ARMA(p,q) with intercept."""
    p, q = len(ar), len(ma)
    n = len(y)
    e = np.zeros(n)
    for t in range(n):
        pred = c
        for i in range(p):
            if t - 1 - i >= 0:
                pred += ar[i] * y[t - 1 - i]
        for j in range(q):
            if t - 1 - j >= 0:
                pred += ma[j] * e[t - 1 - j]
        e[t] = y[t] - pred
    return e


class ArimaModel:
    def __init__(self, order: Tuple[int, int, int], ar: np.ndarray, ma: np.ndarray,
                 c: float, sigma2: float, aic: float):
        self.order = order
        self.ar = ar
        self.ma = ma
        self.c = c
        self.sigma2 = sigma2
        self.aic = aic

    def forecast(self, y_orig: Sequence[float]) -> float:
        """One-step-ahead forecast given the original (undifferenced) series."""
        p, d, q = self.order
        y = np.asarray(y_orig, float)
        w = np.diff(y, n=d) if d > 0 else y
        e = _css_residuals(w, self.ar, self.ma, self.c)
        pred = self.c
        for i in range(p):
            if len(w) - 1 - i >= 0:
                pred += self.ar[i] * w[len(w) - 1 - i]
        for j in range(q):
            if len(e) - 1 - j >= 0:
                pred += self.ma[j] * e[len(e) - 1 - j]
        # Un-difference: forecast of y_{n+1} = pred + sum of last values.
        if d == 0:
            return float(pred)
        if d == 1:
            return float(y[-1] + pred)
        # general d via cumulative reconstruction
        tail = y.copy()
        for _ in range(d):
            tail = np.diff(tail)
        raise NotImplementedError("d > 1 not supported")


def fit_arima(y: Sequence[float], order: Tuple[int, int, int]) -> Optional[ArimaModel]:
    """CSS fit of ARIMA(p,d,q); returns None if the series is too short."""
    p, d, q = order
    y = np.asarray(y, float)
    if len(y) < d + max(p, q) + 2:
        return None
    w = np.diff(y, n=d) if d > 0 else y.copy()
    n = len(w)
    if n < p + q + 1:
        return None

    # Fit on the centered series (CSS is far better conditioned this way);
    # the intercept is then c = mean * (1 - sum(ar)).
    mu = float(np.mean(w))
    wc = w - mu

    def unpack(theta):
        return theta[:p], theta[p:p + q]

    def objective(theta):
        ar, ma = unpack(theta)
        # soft stationarity/invertibility guard
        if np.any(np.abs(ar) > 1.5) or np.any(np.abs(ma) > 1.5):
            return 1e12
        e = _css_residuals(wc, ar, ma, 0.0)
        return float(np.sum(e * e))

    x0 = np.zeros(p + q)
    if p + q > 0:
        res = optimize.minimize(objective, x0, method="Nelder-Mead",
                                options={"maxiter": 300 * (p + q),
                                         "xatol": 1e-5, "fatol": 1e-8})
        theta = res.x
    else:
        theta = x0
    ar, ma = unpack(theta)
    c = mu * (1.0 - float(np.sum(ar)))
    sse = objective(theta)
    sse = max(sse, 1e-12)
    sigma2 = sse / n
    k = p + q + 1
    aic = n * math.log(sigma2) + 2 * k
    return ArimaModel(order, np.asarray(ar), np.asarray(ma), float(c), sigma2, aic)


def auto_arima(y: Sequence[float], max_p: int = 2, max_d: int = 1,
               max_q: int = 2) -> Optional[ArimaModel]:
    """Small-grid AIC search mirroring pmdarima.auto_arima's role."""
    best: Optional[ArimaModel] = None
    for p, d, q in itertools.product(range(max_p + 1), range(max_d + 1), range(max_q + 1)):
        if p == 0 and q == 0 and d == 0:
            continue
        m = fit_arima(y, (p, d, q))
        if m is None or not math.isfinite(m.aic):
            continue
        if best is None or m.aic < best.aic:
            best = m
    return best


class ArimaForecaster:
    """Rolling per-app forecaster: observe ITs, forecast the next one.

    Refits (auto-order every ``refit_every`` observations, otherwise reuse the
    last order) — mirroring the paper's 'build once (~27 ms), update (~5 ms)'
    split.
    """

    def __init__(self, refit_every: int = 8):
        self._obs: List[float] = []
        self._model: Optional[ArimaModel] = None
        self._refit_every = refit_every
        self._since_auto = 0

    @property
    def n_obs(self) -> int:
        return len(self._obs)

    def observe(self, it_minutes: float) -> None:
        self._obs.append(float(it_minutes))
        if len(self._obs) > _MAX_OBS:
            self._obs = self._obs[-_MAX_OBS:]
        self._model = None  # lazily refit on next forecast

    def forecast(self) -> Optional[float]:
        if len(self._obs) < 3:
            return None
        if self._model is None:
            self._since_auto += 1
            if self._since_auto >= self._refit_every or self._model is None:
                self._model = auto_arima(self._obs)
                self._since_auto = 0
        if self._model is None:
            return None
        try:
            pred = self._model.forecast(self._obs)
        except Exception:
            return None
        if not math.isfinite(pred):
            return None
        # An IT forecast below zero is meaningless; clamp to a small positive.
        return max(pred, 0.5)

    def state_dict(self) -> dict:
        return {"obs": list(self._obs)}

    def load_state_dict(self, state: dict) -> None:
        self._obs = [float(x) for x in state["obs"]][-_MAX_OBS:]
        self._model = None
