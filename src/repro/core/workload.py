"""Azure-Functions-like synthetic workload generation.

The real trace is released at github.com/Azure/AzurePublicDataset; offline we
generate traces *from the paper's published distributions* so every figure of
Section 5 can be reproduced in trend:

  * invocations/day per app: 8-order-of-magnitude piecewise-log-linear CDF
    anchored at the paper's Fig. 5(a) markers (45% of apps <= 1/hour,
    81% <= 1/minute);
  * arrival patterns calibrated to the Fig. 6 CV classes: ~20% of apps
    CV ~ 0 (periodic timers), a band between 0 and 1 (multi-timer mixtures),
    a Poisson band (CV ~ 1), and ~40% with CV > 1 (bursty);
  * diurnal modulation with a ~50% constant baseline (Fig. 4);
  * execution times ~ lognormal(mu=-0.38, sigma=2.36) seconds (Fig. 7 MLE fit);
  * allocated memory ~ Burr XII (c=11.652, k=0.221, lambda=107.083) MB (Fig. 8);
  * functions per app from the Fig. 1 CDF (54% single-function,
    95% <= 10 functions);
  * trigger mix from Fig. 2/3.

Invocation times are produced in **minutes** (float). Apps whose average rate
exceeds 1/minute are capped to one invocation per minute-bin: the dataset
itself is 1-minute binned, and for cold-start simulation any such app is
permanently warm under every policy considered, so the cap changes no result
while bounding trace size.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["AppSpec", "Trace", "sample_apps", "generate_trace", "PATTERNS"]


class _RemovedSynthesize:
    """Tombstone for the removed ``Trace.synthesize`` shim (deprecated in
    PR 5, removed after its one-cycle grace period). Any access — including
    ``hasattr`` probes — raises with the replacement spelled out."""

    def __get__(self, obj, objtype=None):
        raise AttributeError(
            "Trace.synthesize was removed after its deprecation cycle; use "
            "repro.core.workload_spec.WorkloadSpec.uniform(n_apps, days=..., "
            "seed=..., max_events=..., min_events=1).materialize() instead")

MINUTES_PER_DAY = 1440.0

# Fig. 5(a) CDF anchors: (fraction of apps, log10(invocations/day)).
_RATE_CDF = np.array([
    (0.00, -1.00),   # ~1 invocation / 10 days
    (0.10, 0.00),    # 1 / day
    (0.45, np.log10(24.0)),     # 1 / hour   (paper: 45% of apps)
    (0.65, 2.30),
    (0.81, np.log10(1440.0)),   # 1 / minute (paper: 81% of apps)
    (0.92, 4.50),
    (0.98, 6.00),
    (1.00, 7.00),    # 1e7 / day — 8 orders of magnitude total
])

# Fig. 1 CDF anchors: (fraction of apps, log10(functions/app)).
_FUNC_CDF = np.array([
    (0.54, 0.0),                 # 54% single-function
    (0.80, np.log10(3.0)),
    (0.95, 1.0),                 # 95% <= 10 functions
    (0.9996, 2.0),               # 0.04% > 100
    (1.0, np.log10(2000.0)),
])

# Arrival pattern classes calibrated against Fig. 6:
#   periodic     CV ~ 0   (single timers; ~20% of all apps have CV ~ 0)
#   multi_timer  CV in (0, 1)  (merged timers)
#   regular      CV ~ 0.5 (Erlang IATs — sub-Poisson variability)
#   poisson      CV ~ 1
#   bursty       CV > 1   (~40% of apps; bursts of closely spaced calls)
# Pattern probabilities are conditioned on the app's rate class: low-rate
# apps are predominantly human/event driven (bursty HTTP), high-rate apps are
# machine generated (closer to Poisson), mirroring Sections 3.2-3.3.
PATTERNS = ("periodic", "multi_timer", "regular", "poisson", "bursty")
#                          periodic  multi  regular poisson bursty
_PATTERN_PROBS_LOW = (0.12, 0.06, 0.04, 0.12, 0.66)   # rate <= 1/hour
_PATTERN_PROBS_MID = (0.20, 0.10, 0.10, 0.15, 0.45)   # 1/hour - 1/minute
_PATTERN_PROBS_HIGH = (0.15, 0.05, 0.15, 0.40, 0.25)  # >= 1/minute

# Round timer periods, minutes (1 min ... 1 week).
_ROUND_PERIODS = np.array([1., 2., 5., 10., 15., 30., 60., 120., 240., 480.,
                           720., 1440., 2880., 10080.])

# Fig. 3(b): most common trigger combinations.
_TRIGGER_COMBOS = (
    ("http",), ("timer",), ("queue",), ("http", "timer"), ("http", "queue"),
    ("event",), ("storage",), ("timer", "queue"), ("http", "timer", "queue"),
    ("http", "other"), ("http", "storage"), ("http", "orchestration"),
)
_TRIGGER_PROBS = np.array([43.27, 13.36, 9.47, 4.59, 4.22, 3.01, 2.80, 2.57,
                           2.48, 1.69, 1.05, 1.03])

# Fig. 7 lognormal fit of average execution time (seconds, natural log).
EXEC_LOG_MEAN = -0.38
EXEC_LOG_SIGMA = 2.36

# Fig. 8 Burr XII fit of average allocated memory (MB).
MEM_BURR_C = 11.652
MEM_BURR_K = 0.221
MEM_BURR_LAMBDA = 107.083


@dataclasses.dataclass(frozen=True)
class AppSpec:
    app_id: str
    pattern: str                 # one of PATTERNS
    rate_per_day: float          # average invocations / day
    period_minutes: float        # base period for timer patterns
    exec_time_s: float           # average function execution time
    memory_mb: float             # average allocated memory
    n_functions: int
    triggers: Tuple[str, ...]


@dataclasses.dataclass
class Trace:
    specs: Optional[List[AppSpec]]
    times: Optional[List[np.ndarray]]  # per-app invocation times, minutes, sorted
    duration_minutes: float
    # Cached/primary padded representation. Fleet-scale generated traces
    # (``WorkloadSpec.materialize()``) carry ONLY this form — no per-app
    # python objects.
    _padded: Optional[Tuple[np.ndarray, np.ndarray]] = \
        dataclasses.field(default=None, repr=False)

    @property
    def n_apps(self) -> int:
        if self.times is not None:
            return len(self.times)
        return int(self._padded[0].shape[0])

    def app_id(self, i: int) -> str:
        return self.specs[i].app_id if self.specs is not None else f"app-{i:06d}"

    def events(self, i: int) -> np.ndarray:
        """Invocation times of app ``i`` (works for padded-only traces)."""
        if self.times is not None:
            return self.times[i]
        padded, counts = self._padded
        return padded[i, : int(counts[i])]

    def to_padded(self) -> Tuple[np.ndarray, np.ndarray]:
        """Return (times [n_apps, max_ev] padded with +inf, counts [n_apps]).

        The time dtype of the source arrays is preserved (float64 for
        generated traces) so the float64 simulator scans see full-precision
        inter-arrival times. List-backed traces build a fresh array per
        call (so ``times`` edits are always honored); padded-only traces
        (``WorkloadSpec.materialize()``) return their shared primary arrays —
        treat those as read-only, a fleet-scale trace cannot afford a copy
        per call.
        """
        if self._padded is not None:
            return self._padded
        counts = np.array([len(t) for t in self.times], np.int32)
        max_ev = max(int(counts.max()), 1) if len(counts) else 1
        dtype = self.times[0].dtype if self.times else np.float64
        out = np.full((self.n_apps, max_ev), np.inf, dtype)
        for i, t in enumerate(self.times):
            out[i, : len(t)] = t
        return out, counts

    def iats(self, i: int) -> np.ndarray:
        return np.diff(self.events(i))

    # ``Trace.synthesize`` was removed after its PR 5 deprecation cycle.
    # ``_RemovedSynthesize`` below turns any access into an actionable
    # AttributeError (class attribute, not a dataclass field).
    synthesize = _RemovedSynthesize()


def _inv_cdf(anchors: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Piecewise-linear inverse CDF in the anchors' y-units."""
    return np.interp(u, anchors[:, 0], anchors[:, 1])


def _sample_rates(rng: np.random.Generator, n: int) -> np.ndarray:
    return 10.0 ** _inv_cdf(_RATE_CDF, rng.uniform(0.0, 1.0, n))


def _sample_n_functions(rng: np.random.Generator, n: int) -> np.ndarray:
    u = rng.uniform(0.0, 1.0, n)
    # below the first anchor everything is a single function
    vals = np.where(u <= _FUNC_CDF[0, 0], 0.0, _inv_cdf(_FUNC_CDF, u))
    return np.maximum(np.round(10.0 ** vals), 1).astype(np.int64)


def _sample_memory_mb(rng: np.random.Generator, n: int) -> np.ndarray:
    """Burr XII sampling by inverse CDF: F(x) = 1 - [1+(x/l)^c]^{-k}."""
    u = rng.uniform(0.0, 1.0, n)
    x = MEM_BURR_LAMBDA * ((1.0 - u) ** (-1.0 / MEM_BURR_K) - 1.0) ** (1.0 / MEM_BURR_C)
    return np.clip(x, 1.0, 16384.0)


def _sample_exec_s(rng: np.random.Generator, n: int) -> np.ndarray:
    return np.exp(rng.normal(EXEC_LOG_MEAN, EXEC_LOG_SIGMA, n))


def sample_apps(n_apps: int, seed: int = 0) -> List[AppSpec]:
    rng = np.random.default_rng(seed)
    rates = _sample_rates(rng, n_apps)
    mems = _sample_memory_mb(rng, n_apps)
    execs = _sample_exec_s(rng, n_apps)
    nfuncs = _sample_n_functions(rng, n_apps)
    trig_p = _TRIGGER_PROBS / _TRIGGER_PROBS.sum()
    trig_idx = rng.choice(len(_TRIGGER_COMBOS), n_apps, p=trig_p)
    specs = []
    for i in range(n_apps):
        rate = float(rates[i])
        if rate <= 24.0:
            probs = _PATTERN_PROBS_LOW
        elif rate <= MINUTES_PER_DAY:
            probs = _PATTERN_PROBS_MID
        else:
            probs = _PATTERN_PROBS_HIGH
        pattern = PATTERNS[rng.choice(len(PATTERNS), p=probs)]
        # timer apps: 95% fire at most once per minute (paper Sec. 3.2), and
        # real timers use round periods (1/5/15/30 min, hourly, daily...)
        if pattern in ("periodic", "multi_timer"):
            rate = min(rate, MINUTES_PER_DAY)  # at most 1/minute
            raw_period = MINUTES_PER_DAY / max(rate, 1e-9)
            snapped = _ROUND_PERIODS[np.argmin(np.abs(np.log(_ROUND_PERIODS)
                                                      - np.log(raw_period)))]
            rate = MINUTES_PER_DAY / snapped
        period = MINUTES_PER_DAY / max(rate, 1e-9)
        specs.append(AppSpec(
            app_id=f"app-{i:06d}",
            pattern=pattern,
            rate_per_day=rate,
            period_minutes=float(max(period, 1.0)),
            exec_time_s=float(execs[i]),
            memory_mb=float(mems[i]),
            n_functions=int(nfuncs[i]),
            triggers=_TRIGGER_COMBOS[trig_idx[i]],
        ))
    return specs


def _diurnal_accept(rng: np.random.Generator, t_minutes: np.ndarray) -> np.ndarray:
    """Thinning mask for the Fig. 4 shape: ~50% constant baseline + diurnal."""
    phase = 2.0 * np.pi * (t_minutes % MINUTES_PER_DAY) / MINUTES_PER_DAY
    p = 0.55 + 0.45 * 0.5 * (1.0 + np.sin(phase - 0.5 * np.pi))
    return rng.uniform(0.0, 1.0, len(t_minutes)) < p


def _gen_periodic(rng, spec: AppSpec, duration: float) -> np.ndarray:
    phase = rng.uniform(0.0, spec.period_minutes)
    return np.arange(phase, duration, spec.period_minutes)


def _gen_multi_timer(rng, spec: AppSpec, duration: float) -> np.ndarray:
    # two timers with co-prime-ish periods; combined CV lands in (0, 1)
    p1 = spec.period_minutes * 2.0
    p2 = p1 * rng.uniform(1.2, 3.0)
    t1 = np.arange(rng.uniform(0, p1), duration, p1)
    t2 = np.arange(rng.uniform(0, p2), duration, p2)
    return np.unique(np.concatenate([t1, t2]))


def _gen_poisson(rng, spec: AppSpec, duration: float) -> np.ndarray:
    mean_iat = spec.period_minutes
    n = int(duration / mean_iat * 2.5) + 16
    iats = rng.exponential(mean_iat / 0.775, n)  # 1/0.775 ~ mean diurnal accept
    t = np.cumsum(iats)
    t = t[t < duration]
    return t[_diurnal_accept(rng, t)]


def _gen_regular(rng, spec: AppSpec, duration: float) -> np.ndarray:
    """Erlang-4 IATs: CV = 0.5 — more regular than Poisson (Fig. 6 mid-band:
    machine traffic with some jitter, e.g. periodic sensors over a network)."""
    mean_iat = spec.period_minutes
    k = 4
    n = int(duration / mean_iat * 1.5) + 16
    iats = rng.gamma(k, mean_iat / k, n)
    t = np.cumsum(iats)
    return t[t < duration]


def _gen_bursty(rng, spec: AppSpec, duration: float) -> np.ndarray:
    """Explicit burst structure: runs of closely spaced invocations separated
    by long idle gaps. This is what produces CV >> 1 (Fig. 6) and, crucially,
    the paper's observed cold-start profile: an app averaging 1/hour that
    arrives in bursts of ~B calls suffers only ~1/B cold starts under a short
    keep-alive, unlike a Poisson app of equal rate."""
    mean_iat = spec.period_minutes
    if mean_iat <= 2.0:
        # effectively continuous traffic; bursts are meaningless
        return _gen_poisson(rng, spec, duration)
    burst_mean = rng.uniform(6.0, 30.0)           # mean invocations per burst
    intra_mean = rng.uniform(0.8, 2.5)            # minutes between calls in a burst
    cycle = burst_mean * mean_iat                 # preserve the average rate
    times = []
    t = rng.uniform(0.0, cycle)
    while t < duration:
        size = 1 + rng.poisson(burst_mean - 1.0)
        bt = t
        for _ in range(size):
            times.append(bt)
            bt += rng.exponential(intra_mean)
        gap = rng.exponential(max(cycle - size * intra_mean, mean_iat))
        t = bt + gap
    t_arr = np.asarray(times)
    t_arr = t_arr[t_arr < duration]
    return t_arr[_diurnal_accept(rng, t_arr)]


_GEN = {
    "periodic": _gen_periodic,
    "multi_timer": _gen_multi_timer,
    "regular": _gen_regular,
    "poisson": _gen_poisson,
    "bursty": _gen_bursty,
}


def generate_invocations(spec: AppSpec, duration_minutes: float,
                         rng: np.random.Generator) -> np.ndarray:
    t = _GEN[spec.pattern](rng, spec, duration_minutes)
    t = np.sort(t)
    if len(t) > 1:
        # cap at one invocation per minute-bin (dataset granularity; see module doc)
        keep = np.ones(len(t), bool)
        last = t[0]
        for i in range(1, len(t)):
            if t[i] - last < 1.0:
                keep[i] = False
            else:
                last = t[i]
        t = t[keep]
    return t.astype(np.float64)


def generate_trace(n_apps: int, days: float = 7.0, seed: int = 0,
                   specs: Optional[Sequence[AppSpec]] = None) -> Trace:
    """Eager §3-faithful trace: ``AppSpec`` objects + per-app float64 times.

    A thin wrapper over the vectorized scenario engine
    (:func:`repro.core.workload_spec.azure_like` in eager mode) — one
    sampling pass per cohort block, no per-app generation loop. The paper's
    dataset guarantees every app at least one invocation, so ``min_events=1``
    and the event budget is left uncapped (minute-bin bound).

    Passing explicit ``specs`` keeps the legacy per-app path: arbitrary
    ``AppSpec`` lists are honored app-by-app via
    :func:`generate_invocations` (the callers that build custom specs are
    small-n tests and the cluster sim).
    """
    duration = days * MINUTES_PER_DAY
    if specs is not None:
        rng = np.random.default_rng(seed + 1)
        times = [generate_invocations(s, duration, rng) for s in specs]
        # Paper: every app in the dataset has at least one invocation.
        for i, t in enumerate(times):
            if len(t) == 0:
                times[i] = np.array([rng.uniform(0.0, duration)])
        return Trace(specs=list(specs), times=times, duration_minutes=duration)
    from .workload_spec import azure_like
    return azure_like(n_apps, days=days, seed=seed, max_events=None,
                      min_events=1).materialize(eager=True)
