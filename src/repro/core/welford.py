"""Welford-style O(1) tracking of the CV of histogram bin counts.

The paper (Section 4.2) decides whether a histogram is *representative* by
computing the coefficient of variation (CV = std / mean) of its bin counts:
a histogram with mass concentrated in few bins has a high CV and is useful;
a flat histogram has CV ~ 0 and is not. Recomputing the CV from scratch is
O(n_bins) per invocation; the paper cites Welford's online algorithm [37] to
make the update O(1).

Incrementing a single bin ``b`` from count ``c`` to ``c+1`` changes the sum of
counts by 1 and the sum of squared counts by ``2c+1``, so we track
``sum_counts`` and ``sum_sq_counts`` and derive::

    mean = sum / n_bins
    var  = sum_sq / n_bins - mean**2          (population variance)
    cv   = sqrt(var) / mean                   (0 when mean == 0)

This module provides both a scalar (host/control-plane) implementation and a
batched JAX implementation operating on ``[n_apps]`` state vectors. The
update/derivation formulas are the single-source helpers in
:mod:`repro.core.policy_math` (``welford_update`` / ``bin_count_cv``); only
the ``cv_from_counts`` test oracle recomputes from scratch.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from . import policy_math

__all__ = ["CVState", "cv_init", "cv_update", "cv_value", "cv_from_counts"]


@dataclasses.dataclass
class CVState:
    """Scalar O(1) CV tracker for one histogram (host-side path)."""

    n_bins: int
    sum_counts: float = 0.0
    sum_sq_counts: float = 0.0

    def update(self, old_count: float) -> None:
        """Record that one bin went from ``old_count`` to ``old_count + 1``."""
        s, ss = policy_math.welford_update(self.sum_counts, self.sum_sq_counts,
                                           True, old_count)
        self.sum_counts, self.sum_sq_counts = float(s), float(ss)

    def remove(self, old_count: float) -> None:
        """Record that one bin went from ``old_count`` to ``old_count - 1``."""
        self.sum_counts -= 1.0
        self.sum_sq_counts -= 2.0 * old_count - 1.0

    @property
    def cv(self) -> float:
        return float(policy_math.bin_count_cv(self.sum_counts,
                                              self.sum_sq_counts,
                                              self.n_bins, np.float64))


# --- Batched JAX path (state = dict of [n_apps] vectors) -------------------


def cv_init(n_apps: int, dtype=jnp.float32) -> dict:
    return {
        "sum": jnp.zeros((n_apps,), dtype),
        "sum_sq": jnp.zeros((n_apps,), dtype),
    }


def cv_update(state: dict, old_count: jnp.ndarray, active: jnp.ndarray) -> dict:
    """Batched O(1) update: per app, one bin went old_count -> old_count+1.

    ``active`` masks apps that actually recorded an in-bounds IT this step.
    """
    s, ss = policy_math.welford_update(state["sum"], state["sum_sq"],
                                       active != 0, old_count)
    return {"sum": s, "sum_sq": ss}


def cv_value(state: dict, n_bins: int) -> jnp.ndarray:
    return policy_math.bin_count_cv(state["sum"], state["sum_sq"], n_bins,
                                    state["sum"].dtype)


def cv_from_counts(counts: jnp.ndarray) -> jnp.ndarray:
    """Direct CV of bin counts along the last axis (reference for tests)."""
    counts = counts.astype(jnp.float32)
    mean = counts.mean(axis=-1)
    var = jnp.maximum((counts * counts).mean(axis=-1) - mean * mean, 0.0)
    return jnp.where(mean > 0.0, jnp.sqrt(var) / jnp.maximum(mean, 1e-9), 0.0)
