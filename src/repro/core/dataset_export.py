"""Sanitized-trace export in the AzurePublicDataset format.

The paper's fourth contribution is the released dataset
(github.com/Azure/AzurePublicDataset: `invocations_per_function_md.anon`,
`function_durations_percentiles.anon`, `app_memory_percentiles.anon`). This
module writes generated traces in the same schema so downstream tools built
against the real dataset run unchanged on our synthetic ones — and so our
generator can be validated field-by-field against the published schema.

Schema (per the dataset documentation):
  * invocations:  HashOwner, HashApp, HashFunction, Trigger, 1..1440 columns
    of per-minute counts (one file per day);
  * durations:    HashOwner, HashApp, HashFunction, Average, Count, Minimum,
    Maximum, percentile_Average_{0,1,25,50,75,99,100};
  * memory:       HashOwner, HashApp, SampleCount, AverageAllocatedMb,
    AverageAllocatedMb_pct{1,5,25,50,75,95,99,100}.
"""
from __future__ import annotations

import csv
import hashlib
import os
from typing import List

import numpy as np

from .workload import MINUTES_PER_DAY, Trace

_PCT_DUR = (0, 1, 25, 50, 75, 99, 100)
_PCT_MEM = (1, 5, 25, 50, 75, 95, 99, 100)


def _hash(s: str) -> str:
    return hashlib.sha1(s.encode()).hexdigest()[:32]


def export(trace: Trace, out_dir: str, owner: str = "repro") -> List[str]:
    """Write the three dataset files; returns the paths.

    Requires an eager trace (``AppSpec`` metadata feeds the trigger,
    duration, and memory columns): ``generate_trace(...)`` or
    ``WorkloadSpec.materialize(eager=True)``.
    """
    if trace.specs is None:
        raise ValueError(
            "dataset export needs an eager trace with AppSpecs; use "
            "generate_trace(...) or spec.materialize(eager=True)")
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    n_days = max(int(np.ceil(trace.duration_minutes / MINUTES_PER_DAY)), 1)

    # --- invocations per function per minute, one file per day -------------
    for day in range(n_days):
        path = os.path.join(out_dir,
                            f"invocations_per_function_md.anon.d{day + 1:02d}.csv")
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["HashOwner", "HashApp", "HashFunction", "Trigger"]
                       + [str(i) for i in range(1, 1441)])
            lo = day * MINUTES_PER_DAY
            for i, spec in enumerate(trace.specs):
                t = trace.events(i)
                in_day = t[(t >= lo) & (t < lo + MINUTES_PER_DAY)] - lo
                counts = np.bincount(in_day.astype(int),
                                     minlength=1440)[:1440]
                if counts.sum() == 0:
                    continue
                w.writerow([_hash(owner), _hash(spec.app_id),
                            _hash(spec.app_id + "/f0"), spec.triggers[0]]
                           + counts.tolist())
        paths.append(path)

    # --- duration percentiles ------------------------------------------------
    path = os.path.join(out_dir, "function_durations_percentiles.anon.csv")
    rng = np.random.default_rng(0)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["HashOwner", "HashApp", "HashFunction", "Average",
                    "Count", "Minimum", "Maximum"]
                   + [f"percentile_Average_{p}" for p in _PCT_DUR])
        for i, spec in enumerate(trace.specs):
            n = max(len(trace.events(i)), 1)
            # per-invocation durations ~ lognormal around the app average
            samples = spec.exec_time_s * np.exp(rng.normal(0, 0.4, min(n, 256)))
            ms = samples * 1e3
            w.writerow([_hash(owner), _hash(spec.app_id),
                        _hash(spec.app_id + "/f0"),
                        round(float(ms.mean()), 2), n,
                        round(float(ms.min()), 2), round(float(ms.max()), 2)]
                       + [round(float(np.percentile(ms, p)), 2)
                          for p in _PCT_DUR])
    paths.append(path)

    # --- memory percentiles ----------------------------------------------------
    path = os.path.join(out_dir, "app_memory_percentiles.anon.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["HashOwner", "HashApp", "SampleCount",
                    "AverageAllocatedMb"]
                   + [f"AverageAllocatedMb_pct{p}" for p in _PCT_MEM])
        for i, spec in enumerate(trace.specs):
            n = max(len(trace.events(i)), 1)
            samples = spec.memory_mb * np.exp(rng.normal(0, 0.15, 64))
            w.writerow([_hash(owner), _hash(spec.app_id), n,
                        round(float(samples.mean()), 2)]
                       + [round(float(np.percentile(samples, p)), 2)
                          for p in _PCT_MEM])
    paths.append(path)
    return paths


def load_invocations(path: str):
    """Parse an invocations file back into (app_hashes, counts [n, 1440])."""
    apps, rows = [], []
    with open(path) as f:
        r = csv.reader(f)
        header = next(r)
        for row in r:
            apps.append(row[1])
            rows.append(np.asarray(row[4:], dtype=np.int64))
    return apps, (np.stack(rows) if rows else np.zeros((0, 1440), np.int64))
