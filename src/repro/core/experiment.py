"""The experiment front door: declarative policy specs, one ``run()``, and
a vectorized ``sweep()`` that computes a whole policy grid in one pass.

The paper's core results (Figs. 14-18) are *sweeps* — fixed keep-alive x
{10..240}m, histogram range x {60..480}m, CV-threshold and cutoff
ablations. This module makes a configuration grid a first-class input:

    from repro.core.experiment import FixedSpec, HybridSpec, sweep

    grid = [FixedSpec(ka) for ka in (10, 20, 30, 60, 120, 240)]
    result = sweep(trace, grid)               # Fig. 14 in one call
    for spec, row in zip(result.specs, result):
        print(spec.name, row.cold_pct_percentile(75), row.total_wasted)

Workloads are specs too: everywhere a :class:`~repro.core.workload.Trace`
is accepted, a declarative :class:`~repro.core.workload_spec.WorkloadSpec`
(scenario) is accepted and materialized on entry — and ``sweep`` has a
*trace axis*, making "Fig. 14 across five workload regimes" one call:

    from repro.core.workload_spec import azure_like, bursty, timer_heavy

    grid_2d = sweep(traces=[azure_like(10_000), bursty(10_000),
                            timer_heavy(10_000)], specs=grid)
    for t, res in enumerate(grid_2d):          # (T, S) SweepGrid
        print(grid_2d.trace_name(t), res.row(0).cold_pct_percentile(75))

Each trace is bucketed/chunked/rebased ONCE (``to_padded`` hoisted out of
the per-family engines) and reused across every policy configuration; rows
of the (T, S) grid are bit-identical to the corresponding single-trace
``run()`` calls on every engine.

Specs are frozen dataclasses registered as JAX pytrees (they flatten into
their numeric knobs), each ``.build()``-able into the stateful
:class:`repro.core.policy.Policy` objects the scalar oracle and the serving
layer consume. ``sweep`` stacks same-family specs into a traced config axis
and drives the factored sweep engines in :mod:`repro.core.simulator`: the
trace is bucketed, chunked, rebased, and scanned ONCE for all S configs
instead of S times, with histogram sufficient statistics shared across
configs that agree on the histogram shape (see
:class:`repro.core.policy_math.HybridSweepBlock`).

Engines (``engine=`` on both ``run`` and ``sweep``):

  * ``"auto"``      — Pallas sweep kernel on TPU, float64 fused sweep
    elsewhere (the default).
  * ``"scalar"``    — the float64 event-driven oracle, one config at a
    time (handles everything, including exotic ``Policy`` subclasses via
    ``spec.build()``).
  * ``"fused"``     — the float64 ``lax.scan`` sweep engine.
  * ``"pallas"``    — the float32 TPU sweep kernel (interpret mode off
    TPU), per-chunk time rebasing, SMEM config block via scalar prefetch.
  * ``"reference"`` — the pre-sweep per-step-cumsum float32 engine, one
    config at a time (the benchmark baseline).

The fixed/no-unload family has no histogram state; its ``"pallas"`` and
``"reference"`` engines alias the (already exact) float64 fused sweep.

Every engine's rows are bit-identical on cold counts, invocations, and
final windows to single-config ``run()`` and to the float64 scalar oracle
— ``tests/test_experiment_api.py`` and the conformance/golden suites
enforce it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Union

import jax
import numpy as np

from .histogram import HistogramConfig
from .policy import (FixedKeepAlivePolicy, HybridConfig, HybridHistogramPolicy,
                     NoUnloadingPolicy, Policy, SpesConfig, SpesPolicy)
from .simulator import (SimResult, _run_fixed_sweep, _run_hybrid_sweep,
                        _run_spes_sweep, _simulate_hybrid_batch_reference,
                        simulate_scalar)
from .workload import Trace
from .workload_spec import WorkloadSpec, _register_pytree

__all__ = [
    "ENGINES", "PolicySpec", "FixedSpec", "NoUnloadSpec", "HybridSpec",
    "SpesSpec", "EngineOptions", "SweepResult", "SweepGrid", "as_spec",
    "as_trace", "run", "sweep",
]

ENGINES = ("auto", "scalar", "fused", "pallas", "reference")


# PolicySpec and WorkloadSpec families share one pytree-registration
# contract — the helper lives in workload_spec (the import direction).

@dataclasses.dataclass(frozen=True)
class FixedSpec:
    """The provider state of practice: ``prewarm=0``, constant keep-alive
    (AWS 10 min / Azure 20 min / OpenWhisk 10 min)."""
    keep_alive: float = 10.0
    label: Optional[str] = None

    @property
    def name(self) -> str:
        return self.label or f"fixed-{self.keep_alive:g}m"

    def build(self) -> FixedKeepAlivePolicy:
        return FixedKeepAlivePolicy(float(self.keep_alive))


@dataclasses.dataclass(frozen=True)
class NoUnloadSpec:
    """Infinite keep-alive: lower bound on cold starts, upper bound on
    waste (Fig. 14's right edge)."""
    label: Optional[str] = None

    @property
    def keep_alive(self) -> float:
        return float("inf")

    @property
    def name(self) -> str:
        return self.label or "no-unloading"

    def build(self) -> NoUnloadingPolicy:
        return NoUnloadingPolicy()


@dataclasses.dataclass(frozen=True)
class HybridSpec:
    """The paper's hybrid histogram policy, flattened to its knobs.

    Mirrors :class:`repro.core.policy.HybridConfig` /
    :class:`repro.core.histogram.HistogramConfig` field-for-field (same
    defaults, including ``use_arima=True``), but as a flat pytree whose
    leaves are exactly the axes the paper sweeps.
    """
    bin_minutes: float = 1.0          # paper: 1-minute bins
    range_minutes: float = 240.0      # paper: 4-hour default range
    head_percentile: float = 5.0      # paper: 5th percentile -> pre-warm
    tail_percentile: float = 99.0     # paper: 99th percentile -> keep-alive
    margin: float = 0.10              # paper: 10% margin both sides
    cv_threshold: float = 2.0         # paper: CV=2 default (Fig. 17)
    min_samples: int = 5              # too few ITs -> standard keep-alive
    oob_fraction_threshold: float = 0.5   # most ITs OOB -> ARIMA
    arima_min_samples: int = 4
    arima_margin: float = 0.15        # paper: 15% margin
    use_arima: bool = True
    label: Optional[str] = None

    @property
    def name(self) -> str:
        return self.label or f"hybrid-{self.range_minutes:g}m"

    def to_config(self) -> HybridConfig:
        return HybridConfig(
            histogram=HistogramConfig(
                bin_minutes=float(self.bin_minutes),
                range_minutes=float(self.range_minutes),
                head_percentile=float(self.head_percentile),
                tail_percentile=float(self.tail_percentile),
                margin=float(self.margin)),
            cv_threshold=float(self.cv_threshold),
            min_samples=int(self.min_samples),
            oob_fraction_threshold=float(self.oob_fraction_threshold),
            arima_min_samples=int(self.arima_min_samples),
            arima_margin=float(self.arima_margin),
            use_arima=bool(self.use_arima))

    @classmethod
    def from_config(cls, cfg: HybridConfig,
                    label: Optional[str] = None) -> "HybridSpec":
        h = cfg.histogram
        return cls(bin_minutes=h.bin_minutes, range_minutes=h.range_minutes,
                   head_percentile=h.head_percentile,
                   tail_percentile=h.tail_percentile, margin=h.margin,
                   cv_threshold=cfg.cv_threshold,
                   min_samples=cfg.min_samples,
                   oob_fraction_threshold=cfg.oob_fraction_threshold,
                   arima_min_samples=cfg.arima_min_samples,
                   arima_margin=cfg.arima_margin, use_arima=cfg.use_arima,
                   label=label)

    def build(self) -> HybridHistogramPolicy:
        return HybridHistogramPolicy(self.to_config())


@dataclasses.dataclass(frozen=True)
class SpesSpec:
    """SPES-style next-idle predictor policy, flattened to its knobs.

    A pure forecast policy (no histogram): a streaming exponentially-
    weighted point forecast of each app's next idle interval, with a
    confidence band that widens with the forecast residual variance —
    mapped to (prewarm, keep-alive) windows through the same
    ``policy_math`` bound helpers as every other family. Mirrors
    :class:`repro.core.policy.SpesConfig` field-for-field.
    """
    alpha: float = 0.3               # EW smoothing weight per observation
    band_margin: float = 0.10        # relative half-band around the forecast
    band_sigma: float = 1.0          # residual-std multiplier for the band
    min_samples: int = 4             # ITs before the forecast governs
    standard_keep_alive: float = 240.0   # fallback until warmed up
    label: Optional[str] = None

    @property
    def name(self) -> str:
        return self.label or f"spes-{self.alpha:g}"

    def to_config(self) -> SpesConfig:
        return SpesConfig(
            alpha=float(self.alpha), band_margin=float(self.band_margin),
            band_sigma=float(self.band_sigma),
            min_samples=int(self.min_samples),
            standard_keep_alive=float(self.standard_keep_alive))

    @classmethod
    def from_config(cls, cfg: SpesConfig,
                    label: Optional[str] = None) -> "SpesSpec":
        return cls(alpha=cfg.alpha, band_margin=cfg.band_margin,
                   band_sigma=cfg.band_sigma, min_samples=cfg.min_samples,
                   standard_keep_alive=cfg.standard_keep_alive, label=label)

    def build(self) -> SpesPolicy:
        return SpesPolicy(self.to_config())


_register_pytree(FixedSpec, meta=("label",))
_register_pytree(NoUnloadSpec, meta=("label",))
_register_pytree(HybridSpec, meta=("use_arima", "label"))
_register_pytree(SpesSpec, meta=("label",))

PolicySpec = Union[FixedSpec, NoUnloadSpec, HybridSpec, SpesSpec]
_SPEC_TYPES = (FixedSpec, NoUnloadSpec, HybridSpec, SpesSpec)


def as_spec(obj) -> PolicySpec:
    """Coerce legacy policy objects/configs to the declarative spec form.

    Accepts a ``PolicySpec`` (returned as-is), a ``HybridConfig``, or one of
    the three built-in ``Policy`` classes. Raises ``TypeError`` for
    arbitrary policies — those stay on the scalar oracle via
    ``simulate_scalar(trace, policy)``.
    """
    if isinstance(obj, _SPEC_TYPES):
        return obj
    if isinstance(obj, HybridConfig):
        return HybridSpec.from_config(obj)
    if isinstance(obj, HybridHistogramPolicy):
        return HybridSpec.from_config(obj.cfg)
    if isinstance(obj, SpesConfig):
        return SpesSpec.from_config(obj)
    if isinstance(obj, SpesPolicy):
        return SpesSpec.from_config(obj.cfg)
    if isinstance(obj, FixedKeepAlivePolicy):
        return FixedSpec(obj.keep_alive)
    if isinstance(obj, NoUnloadingPolicy):
        return NoUnloadSpec()
    raise TypeError(
        f"cannot express {type(obj).__name__} as a PolicySpec; build a "
        f"FixedSpec/NoUnloadSpec/HybridSpec/SpesSpec, or use "
        f"simulate_scalar for arbitrary Policy objects")


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """Execution knobs shared by ``run`` and ``sweep`` (engine-semantic
    knobs live on the spec; these only shape *how* the engines execute)."""
    include_trailing: bool = True     # account waste after the last event
    app_chunk: Optional[int] = None   # apps per device chunk (None: auto,
    #                                   scaled down by the config-axis size)
    tile_apps: int = 512              # Pallas kernel app-tile
    interpret: Optional[bool] = None  # Pallas interpret (None: off-TPU only)
    devices: Union[None, int, str] = None   # shard the app axis: None (off),
    #                                   an int device count (1 exercises the
    #                                   sharded path), or "auto" (every
    #                                   local device). Results stay
    #                                   bit-identical — see
    #                                   repro.distributed.scaleout. Applies
    #                                   to the vectorized sweep engines and
    #                                   the cluster policy-window scan;
    #                                   "scalar"/"reference" ignore it.
    max_eviction_rounds: Optional[int] = None   # cluster cells only: cap
    #                                   the HBM-eviction fixed point; past
    #                                   it the cell falls back to the
    #                                   scalar oracle with a warning


@dataclasses.dataclass
class SweepResult:
    """S policy configurations evaluated over one trace.

    Row-major over the input spec order; ``row(s)`` materializes the
    familiar :class:`~repro.core.simulator.SimResult` view of config ``s``
    (the arrays are shared, not copied).
    """
    specs: List[PolicySpec]
    engine: str                    # the engine that ran ("auto" resolved)
    cold: np.ndarray               # [S, n_apps] int64
    invocations: np.ndarray        # [n_apps] int64 (trace property)
    wasted_minutes: np.ndarray     # [S, n_apps] float64
    final_prewarm: np.ndarray      # [S, n_apps] float64
    final_keep_alive: np.ndarray   # [S, n_apps] float64

    def __len__(self) -> int:
        return len(self.specs)

    def row(self, s: int) -> SimResult:
        return SimResult(self.cold[s], self.invocations,
                         self.wasted_minutes[s], self.final_prewarm[s],
                         self.final_keep_alive[s])

    def __iter__(self) -> Iterator[SimResult]:
        return (self.row(s) for s in range(len(self)))

    def points(self):
        """One :class:`~repro.core.metrics.PolicyPoint` per spec (named by
        ``spec.name``/``label``) — plug straight into ``pareto_frontier``."""
        from .metrics import evaluate
        return [evaluate(spec.name, self.row(s))
                for s, spec in enumerate(self.specs)]


@dataclasses.dataclass
class SweepGrid:
    """A (T, S) grid: S policy configurations over T workloads.

    ``results[t]`` is the full :class:`SweepResult` of trace ``t`` (rows
    bit-identical to single-trace ``sweep``/``run``); ``row(t, s)`` is the
    (t, s) cell as a :class:`~repro.core.simulator.SimResult`. ``traces``
    keeps the inputs as given (``Trace`` or ``WorkloadSpec``)."""
    traces: List[object]
    results: List[SweepResult]

    @property
    def shape(self):
        return (len(self.results),
                len(self.results[0]) if self.results else 0)

    @property
    def specs(self) -> List[PolicySpec]:
        return self.results[0].specs if self.results else []

    def __len__(self) -> int:
        return len(self.results)

    def __getitem__(self, t: int) -> SweepResult:
        return self.results[t]

    def __iter__(self) -> Iterator[SweepResult]:
        return iter(self.results)

    def row(self, t: int, s: int) -> SimResult:
        return self.results[t].row(s)

    def trace_name(self, t: int) -> str:
        obj = self.traces[t]
        return obj.name if isinstance(obj, WorkloadSpec) else f"trace-{t}"

    def points(self):
        """``points()[t]`` — the per-trace PolicyPoint lists."""
        return [res.points() for res in self.results]


def _resolve_engine(engine: str) -> str:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected one of "
                         f"{ENGINES}")
    if engine == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "fused"
    return engine


def as_trace(obj) -> Trace:
    """Coerce the workload argument: a ``Trace`` passes through, a
    declarative ``WorkloadSpec`` is materialized by the vectorized engine."""
    if isinstance(obj, Trace):
        return obj
    if isinstance(obj, WorkloadSpec):
        return obj.materialize()
    raise TypeError(
        f"expected a Trace or WorkloadSpec, got {type(obj).__name__}")


def _sweep_one(trace: Trace, specs: Sequence, eng: str,
               opts: EngineOptions) -> SweepResult:
    n = trace.n_apps
    S = len(specs)
    cold = np.zeros((S, n), np.int64)
    waste = np.zeros((S, n), np.float64)
    pre = np.zeros((S, n), np.float64)
    keep = np.zeros((S, n), np.float64)
    inv: Optional[np.ndarray] = None

    def fill(rows, out):
        nonlocal inv
        if isinstance(out, SimResult):
            out = dict(cold=out.cold, wasted_minutes=out.wasted_minutes,
                       final_prewarm=out.final_prewarm,
                       final_keep_alive=out.final_keep_alive,
                       invocations=out.invocations)
        cold[rows] = out["cold"]
        waste[rows] = out["wasted_minutes"]
        pre[rows] = out["final_prewarm"]
        keep[rows] = out["final_keep_alive"]
        inv = out["invocations"]

    if eng == "scalar":
        for s, spec in enumerate(specs):
            fill([s], simulate_scalar(trace, spec.build(),
                                      opts.include_trailing))
        return SweepResult(specs, eng, cold, inv, waste, pre, keep)

    window_idx = [s for s, sp in enumerate(specs)
                  if isinstance(sp, (FixedSpec, NoUnloadSpec))]
    hybrid_idx = [s for s, sp in enumerate(specs)
                  if isinstance(sp, HybridSpec)]
    spes_idx = [s for s, sp in enumerate(specs)
                if isinstance(sp, SpesSpec)]

    # The trace is padded ONCE for every family and config (list-backed
    # traces rebuild the padded arrays on each to_padded call).
    padded = trace.to_padded()
    if window_idx:
        # No histogram state in this family — the float64 fused sweep is
        # already oracle-exact, so "pallas"/"reference" alias it.
        out = _run_fixed_sweep(trace, [specs[s].keep_alive
                                       for s in window_idx],
                               opts.include_trailing, padded=padded,
                               devices=opts.devices)
        fill(window_idx, out)
    if hybrid_idx:
        cfgs = [specs[s].to_config() for s in hybrid_idx]
        if eng == "reference":
            for s, cfg in zip(hybrid_idx, cfgs):
                fill([s], _simulate_hybrid_batch_reference(
                    trace, cfg, opts.include_trailing, padded=padded))
        else:
            out = _run_hybrid_sweep(
                trace, cfgs, opts.include_trailing,
                app_chunk=opts.app_chunk, use_pallas=(eng == "pallas"),
                interpret=opts.interpret, tile_apps=opts.tile_apps,
                padded=padded, devices=opts.devices)
            fill(hybrid_idx, out)
    if spes_idx:
        # Like the fixed family: no per-bin state, and the float64 fused
        # scan is already oracle-exact, so "pallas"/"reference" alias it.
        out = _run_spes_sweep(
            trace, [specs[s].to_config() for s in spes_idx],
            opts.include_trailing, app_chunk=opts.app_chunk,
            padded=padded, devices=opts.devices)
        fill(spes_idx, out)
    assert inv is not None  # every spec belongs to one of the families
    return SweepResult(specs, eng, cold, inv, waste, pre, keep)


def sweep(trace=None, specs: Sequence = None, *, traces=None, clusters=None,
          engine: str = "auto", options: Optional[EngineOptions] = None):
    """Evaluate a policy grid over one workload — or a (T, S) grid.

    ``sweep(trace, specs)`` evaluates S policy configurations over one
    workload (a ``Trace`` or a ``WorkloadSpec``) in one device pass:
    ``specs`` may mix families (fixed / no-unload / hybrid); each family is
    stacked into its own traced config axis and the trace is prepared once.
    Rows come back in input order and are bit-identical (cold counts,
    invocations, final windows) to the corresponding single-config
    :func:`run`. Returns a :class:`SweepResult`.

    ``sweep(traces=[...], specs=[...])`` adds the trace axis: every
    workload (again ``Trace`` or ``WorkloadSpec``, freely mixed) is
    materialized and prepared once, swept over the whole policy grid, and
    the T :class:`SweepResult` rows come back as a :class:`SweepGrid`.

    ``sweep(..., clusters=[ClusterSpec(...), ...])`` adds the *cluster*
    axis: instead of the single-pool simulators, every cell runs the
    fleet engine (:mod:`repro.serving.cluster_vector`) and the
    trace x policy x cluster grid comes back as a
    :class:`~repro.serving.cluster_vector.ClusterSweep`. Cluster engines
    are ``"auto"``/``"vector"``/``"scalar"``.
    """
    if specs is None:
        raise TypeError("sweep() requires specs (a list of PolicySpec)")
    specs = [as_spec(s) for s in specs]
    if not specs:
        raise ValueError("sweep() needs at least one PolicySpec")
    if (trace is None) == (traces is None):
        raise TypeError("pass exactly one of trace= or traces=")
    if clusters is not None:
        from ..serving.cluster_vector import sweep_cluster
        return sweep_cluster(traces if traces is not None else trace,
                             specs, clusters, engine=engine,
                             app_chunk=(options.app_chunk
                                        if options is not None else None),
                             devices=(options.devices
                                      if options is not None else None),
                             max_eviction_rounds=(
                                 options.max_eviction_rounds
                                 if options is not None else None))
    opts = options or EngineOptions()
    eng = _resolve_engine(engine)
    if traces is None:
        return _sweep_one(as_trace(trace), specs, eng, opts)
    traces = list(traces)
    if not traces:
        raise ValueError("sweep() needs at least one trace")
    return SweepGrid(traces=traces,
                     results=[_sweep_one(as_trace(t), specs, eng, opts)
                              for t in traces])


def run(trace, spec, *, engine: str = "auto", cluster=None,
        options: Optional[EngineOptions] = None):
    """Evaluate one policy configuration (the S=1 sweep) over one workload
    (``Trace`` or ``WorkloadSpec``). With ``cluster=`` (a
    :class:`~repro.serving.cluster_vector.ClusterSpec`), the cell runs the
    fleet simulator instead and returns a
    :class:`~repro.serving.cluster_sim.ClusterResult`."""
    if cluster is not None:
        from ..serving.cluster_vector import run_cluster
        return run_cluster(trace, spec, cluster, engine=engine,
                           app_chunk=(options.app_chunk
                                      if options is not None else None),
                           devices=(options.devices
                                    if options is not None else None),
                           max_eviction_rounds=(
                               options.max_eviction_rounds
                               if options is not None else None))
    return sweep(trace, [spec], engine=engine, options=options).row(0)
