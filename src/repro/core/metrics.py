"""Policy-evaluation metrics and Pareto utilities (paper Section 5)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .simulator import SimResult

__all__ = ["PolicyPoint", "evaluate", "pareto_frontier", "normalize_waste"]


@dataclasses.dataclass(frozen=True)
class PolicyPoint:
    """One policy's position in the cold-start/memory trade-off (Fig. 15)."""

    name: str
    cold_pct_p75: float        # 75th-percentile app cold-start % (paper metric)
    wasted_memory: float       # total loaded-but-idle app-minutes
    always_cold_pct: float     # % of apps with 100% cold starts (Fig. 18)
    cold_pct_p50: float = 0.0
    cold_pct_p90: float = 0.0


def evaluate(name: str, result: SimResult) -> PolicyPoint:
    pct = result.cold_pct
    return PolicyPoint(
        name=name,
        cold_pct_p75=float(np.percentile(pct, 75)),
        wasted_memory=result.total_wasted,
        always_cold_pct=100.0 * result.always_cold_fraction,
        cold_pct_p50=float(np.percentile(pct, 50)),
        cold_pct_p90=float(np.percentile(pct, 90)),
    )


def normalize_waste(points: Sequence[PolicyPoint], baseline: str) -> Dict[str, float]:
    """Wasted memory normalized to a named baseline (paper: 10-min fixed)."""
    base = next(p for p in points if p.name == baseline).wasted_memory
    base = max(base, 1e-9)
    return {p.name: p.wasted_memory / base for p in points}


def pareto_frontier(points: Sequence[PolicyPoint]) -> List[PolicyPoint]:
    """Non-dominated points for (cold_pct_p75, wasted_memory), both minimized."""
    pts = sorted(points, key=lambda p: (p.wasted_memory, p.cold_pct_p75))
    frontier: List[PolicyPoint] = []
    best_cold = float("inf")
    for p in pts:
        if p.cold_pct_p75 < best_cold - 1e-12:
            frontier.append(p)
            best_cold = p.cold_pct_p75
    return frontier
